"""Shared-arrangement lifecycle tests: the once-per-epoch upload
discipline under 12 concurrent clients, refcounted epoch pinning (a reader
holding an old epoch while maintenance publishes two more), threaded
lease/publish races, deterministic device-memory accounting, and lease
leak detection."""
import gc
import threading

import numpy as np
import pytest

from repro.core.query.arrangement import (ArrangementItem, ArrangementLease,
                                          ArrangementStore)
from repro.core.query.engine import Query, QueryEngine
from tests.test_query_plan import DENSE_TERMS, build_ragged_world, \
    result_fingerprint

W = 4          # bitmap words per synthetic segment


def _item(sid: int, gen: int, n: int = 8):
    """Synthetic segment: token (sid, gen); every bitmap word carries the
    value ``sid * 100 + gen`` so stack contents prove WHICH epoch a reader
    observed."""
    val = np.uint32(sid * 100 + gen)
    return ArrangementItem(
        token=(sid, gen), num_records=n,
        load=lambda: np.full((n, W), val, np.uint32))


def _stack_host(arr):
    import jax
    return np.asarray(jax.device_get(arr.stack))


# -- upload discipline under concurrency ------------------------------------

def test_upload_once_per_column_under_12_clients(tmp_path):
    """The acceptance invariant: 12 concurrent clients over an overlapping
    word set cost ONE upload per touched word column per maintenance
    epoch — concurrent leases coalesce onto a single build — and results
    stay byte-identical with a single-client oracle."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=21,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend="ref")
    oracle = QueryEngine(store, mapper=mapper, backend="numpy")
    qs = [Query(terms=DENSE_TERMS, mode="count"),
          Query(terms=DENSE_TERMS, mode="copy")]
    # expected results from the numpy oracle (touches no arrangements), so
    # the 12 clients below race the shared plane's very first (cold) build
    want = [result_fingerprint(oracle.execute(q, path="fluxsieve"))
            for q in qs]
    errors = []

    def client(cid):
        try:
            for _ in range(3):
                for q, w in zip(qs, want):
                    r = engine.execute(q, path="fluxsieve")
                    assert result_fingerprint(r) == w
        except Exception as e:  # noqa: BLE001
            errors.append((cid, e))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    uploads = engine.arrangements.upload_counts()
    assert uploads, "expected pooled word-column uploads"
    assert all(v == 1 for v in uploads.values()), uploads
    assert engine.arrangements.builds == 1      # one coalesced build
    assert engine.arrangements.active_leases() == {}


def test_sharded_clients_share_one_column_pool(tmp_path):
    """Sharded execution multiplies concurrency, not device copies: each
    shard builds its own (sub-)arrangement but every word column still
    crosses H2D once — the shards lease from ONE ArrangementStore."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=22,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend="ref", shards=3)
    q = Query(terms=DENSE_TERMS, mode="count")
    want = engine.execute(q, path="fluxsieve").count
    for _ in range(3):
        assert engine.execute(q, path="fluxsieve").count == want
    uploads = engine.arrangements.upload_counts()
    assert all(v == 1 for v in uploads.values()), uploads
    # every ragged segment contributed its touched word columns exactly once
    touched = {tok[0] for tok, _ in uploads}
    assert touched == {s.segment_id for s in store.segments}
    assert engine.arrangements.active_leases() == {}


# -- epoch pinning -----------------------------------------------------------

def test_reader_pins_old_epoch_across_two_publishes():
    """A lease holding epoch E stays readable (untorn, byte-identical)
    while maintenance publishes E+1 and E+2; the retired epochs free
    deterministically — each the moment its last lease releases."""
    store = ArrangementStore()
    words = (0, 2)
    old = store.lease([_item(0, 0), _item(1, 0)], words, block_n=64,
                      owner="reader-old")
    bytes_e0 = store.device_bytes
    assert bytes_e0 > 0
    # maintenance publishes TWO more epochs while the reader is in flight
    for g in (1, 2):
        store.publish([0, 1])
        mid = store.lease([_item(0, g), _item(1, g)], words, block_n=64,
                          owner=f"reader-e{g}")
        host = _stack_host(mid.arrangement)
        assert host[0, 0] == 0 * 100 + g and host[8, 0] == 1 * 100 + g
        if g == 1:
            lease_e1 = mid
        else:
            mid.release()
    assert store.epoch == 2
    # the pinned epoch-0 image is still exactly epoch 0 — no torn swap
    host = _stack_host(old.arrangement)
    assert host[0, 0] == 0 and host[8, 0] == 100
    assert old.arrangement.retired and lease_e1.arrangement.retired
    # frees are deterministic and per-epoch: e0 drains, then e1
    held = store.device_bytes
    old.release()
    assert store.device_bytes < held
    lease_e1.release()
    store.publish()                 # retire the live e2 arrangement too
    assert store.device_bytes == 0
    assert store.live_arrangements() == 0
    assert store.active_leases() == {}


def test_engine_query_pins_epoch_under_maintenance(tmp_path):
    """Integration flavor of the pin: a lease taken through the executor's
    own plane survives two Segment.apply_update publications mid-flight,
    and the engine keeps answering correctly throughout."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=23,
                                                  num_records=2000)
    engine = QueryEngine(store, mapper=mapper, backend="ref")
    q = Query(terms=DENSE_TERMS, mode="count")
    truth = engine.execute(q, path="fluxsieve").count
    arr_store = engine.arrangements
    key = next(iter(arr_store._live))
    live = arr_store._live[key]
    live.refcount += 1              # simulate an in-flight reader
    pinned = ArrangementLease(live, "in-flight", arr_store)
    epoch0 = arr_store.epoch
    store.segments[0].apply_update(meta_updates={})
    store.segments[0].apply_update(meta_updates={})
    assert arr_store.epoch == epoch0 + 2
    assert live.retired and live.stack is not None
    assert engine.execute(q, path="fluxsieve").count == truth
    pinned.release()
    assert live.stack is None       # drained -> freed deterministically


# -- threaded races ----------------------------------------------------------

def test_threaded_lease_publish_race():
    """Readers lease/verify/release while a maintenance thread publishes
    epoch after epoch: every reader always observes a complete image of
    the token set it bound (never torn, never freed under it), and the
    plane drains to zero device bytes afterwards."""
    store = ArrangementStore()
    gens = {0: 0, 1: 0}
    gen_lock = threading.Lock()
    stop = threading.Event()
    errors = []

    def reader(rid):
        try:
            while not stop.is_set():
                with gen_lock:
                    snapshot = dict(gens)
                items = [_item(s, g) for s, g in sorted(snapshot.items())]
                lease = store.lease(items, (1,), block_n=64,
                                    owner=f"reader-{rid}")
                try:
                    host = _stack_host(lease.arrangement)
                    for slot, (s, g) in enumerate(sorted(snapshot.items())):
                        assert host[slot * 8, 0] == s * 100 + g, \
                            (slot, host[slot * 8, 0])
                finally:
                    lease.release()
        except Exception as e:  # noqa: BLE001
            errors.append((rid, e))

    def maintenance():
        try:
            for g in range(1, 15):
                with gen_lock:
                    gens[0] = g
                    gens[1] = g
                store.publish([0, 1])
        except Exception as e:  # noqa: BLE001
            errors.append(("maint", e))

    readers = [threading.Thread(target=reader, args=(i,)) for i in range(6)]
    for t in readers:
        t.start()
    m = threading.Thread(target=maintenance)
    m.start()
    m.join()
    stop.set()
    for t in readers:
        t.join()
    assert not errors, errors
    store.publish()
    assert store.device_bytes == 0
    assert store.live_arrangements() == 0
    assert store.active_leases() == {}


# -- leak detection & accounting ---------------------------------------------

def test_lease_leak_detected_at_finalization():
    store = ArrangementStore()
    lease = store.lease([_item(0, 0)], (0,), block_n=64, owner="sloppy")
    assert store.active_leases() == {"sloppy": 1}
    with pytest.warns(ResourceWarning, match="sloppy"):
        del lease
        gc.collect()
    assert store.leaks == 1
    assert store.active_leases() == {}
    store.publish()
    assert store.device_bytes == 0      # the leaked ref still freed


def test_ephemeral_build_counts_no_shared_traffic():
    store = ArrangementStore()
    lease = store.build_ephemeral([_item(0, 0)], (0, 1), block_n=64,
                                  owner="cold")
    assert store.device_bytes > 0
    assert store.upload_counts() == {} and store.h2d_bytes == 0
    host = _stack_host(lease.arrangement)
    assert host.shape[1] == 2 and host[0, 0] == 0
    lease.release()
    assert store.device_bytes == 0
    assert store.active_leases() == {}


def test_publish_during_build_dooms_installed_arrangement():
    """A maintenance publish that lands while an arrangement is still
    BUILDING must not let the finished build squat a live slot under dead
    tokens: it installs retired, stays readable for its lease, and frees
    the moment the lease drains."""
    store = ArrangementStore()
    gate, release = threading.Event(), threading.Event()

    def load():
        gate.set()
        assert release.wait(5)
        return np.zeros((8, W), np.uint32)

    items = [ArrangementItem(token=(0, 0), num_records=8, load=load)]
    out = {}
    t = threading.Thread(target=lambda: out.setdefault(
        "lease", store.lease(items, (0,), block_n=64, owner="builder")))
    t.start()
    assert gate.wait(5)
    store.publish([0])              # the swap lands mid-build
    release.set()
    t.join(5)
    lease = out["lease"]
    assert lease.arrangement.retired
    assert store.live_arrangements() == 0
    lease.release()
    store.publish([0])              # clear the dead-token pooled columns
    assert store.device_bytes == 0


def test_column_pool_lru_bound():
    """The device column pool is bounded: beyond ``max_pool_columns`` the
    coldest unreferenced columns evict (re-uploading on next use) instead
    of growing device residency monotonically between epochs."""
    store = ArrangementStore(max_live=2, max_pool_columns=4)
    for s in range(8):              # 8 distinct segment columns, one at a time
        store.lease([_item(s, 0)], (0,), block_n=64,
                    owner=f"q{s}").release()
    assert len(store._columns) <= 4 + 1     # bound (+1: newest may be refd)
    store.publish()
    assert store.device_bytes == 0


def test_shared_arrangements_single_epoch_per_swap(tmp_path):
    """Two engines sharing one ArrangementStore over one SegmentStore
    subscribe its publish ONCE: a maintenance swap advances the shared
    epoch by exactly one, and a dead engine's arrangement store is not
    pinned by the segment store's listener list."""
    import weakref
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=24,
                                                  num_records=1500)
    shared = ArrangementStore()
    e1 = QueryEngine(store, mapper=mapper, backend="ref",
                     arrangements=shared)
    e2 = QueryEngine(store, mapper=mapper, backend="ref",
                     arrangements=shared)
    epoch0 = shared.epoch
    store.segments[0].apply_update(meta_updates={})
    assert shared.epoch == epoch0 + 1       # deduped: one epoch, not two
    # a discarded engine's (private) arrangement store must be collectable
    e3 = QueryEngine(store, mapper=mapper, backend="ref")
    ref = weakref.ref(e3.arrangements)
    del e3
    gc.collect()
    assert ref() is None
    store.segments[0].apply_update(meta_updates={})     # prunes dead refs
    assert shared.epoch == epoch0 + 2


def test_max_live_eviction_retires_not_frees_leased():
    store = ArrangementStore(max_live=2)
    leases = [store.lease([_item(0, 0)], (w,), block_n=64, owner=f"q{w}")
              for w in range(4)]
    assert store.live_arrangements() <= 2
    for lease in leases:            # evicted-but-leased stayed readable
        assert lease.arrangement.stack is not None
        lease.release()
    store.publish()
    assert store.device_bytes == 0


# -- epoch prefetch + cost-weighted eviction (standing-query satellites) -----

def test_epoch_prefetch_zero_builds_after_swap(tmp_path):
    """Satellite: an update epoch re-builds the retired arrangement
    families eagerly (on publish, from maintenance context), so the next
    query over the swapped store performs ZERO builds — the post-epoch
    latency spike moves off the query path."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=23,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend="ref")
    q = Query(terms=DENSE_TERMS, mode="count")
    want = engine.execute(q, path="fluxsieve").count
    arr = engine.arrangements
    assert arr.prefetches == 0

    store.segments[0].apply_update(meta_updates={"touched": True})
    assert arr.prefetches >= 1          # rebuilt on publish, eagerly
    builds = arr.builds
    r = engine.execute(q, path="fluxsieve")
    assert r.count == want
    assert arr.builds == builds         # the hot query built nothing


def test_epoch_prefetch_off_when_disabled(tmp_path):
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=23,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend="ref",
                         prefetch=False)
    q = Query(terms=DENSE_TERMS, mode="count")
    engine.execute(q, path="fluxsieve")
    store.segments[0].apply_update(meta_updates={"touched": True})
    assert engine.arrangements.prefetches == 0


def test_eviction_prefers_cheapest_rebuild():
    """Satellite: at max_live pressure the store evicts the family that is
    cheapest to rebuild (fewest device bytes), not the oldest — a large
    hot arrangement survives a parade of small one-off queries."""
    store = ArrangementStore(max_live=2)
    store.lease([_item(0, 0, n=512)], (0,), block_n=64, owner="big").release()
    store.lease([_item(1, 0, n=8)], (0,), block_n=64, owner="small").release()
    # third family forces an eviction: under FIFO the (older) big family
    # would go; cost-weighted eviction drops the small one
    store.lease([_item(2, 0, n=128)], (0,), block_n=64, owner="mid").release()
    assert store.live_arrangements() <= 2

    builds = store.builds
    store.lease([_item(0, 0, n=512)], (0,), block_n=64, owner="big2").release()
    assert store.builds == builds           # big survived: lease hit
    store.lease([_item(1, 0, n=8)], (0,), block_n=64, owner="s2").release()
    assert store.builds == builds + 1       # small was the one evicted
