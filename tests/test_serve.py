import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.model import Model
from repro.serve import kv_cache
from repro.serve.engine import Request, ServeEngine
from repro.serve.serve_step import (build_decode_step, build_encode_step,
                                    build_prefill_step, greedy_sample)


@pytest.fixture(scope="module")
def served():
    model = Model.from_name("yi-34b", reduced=True)
    params = model.init(jax.random.key(0))
    return model, params


def test_prefill_decode_pipeline(served):
    model, params = served
    prefill = build_prefill_step(model, cache_size=32)
    decode = build_decode_step(model, donate=False)
    toks = jnp.asarray(np.random.default_rng(0).integers(3, 400, (2, 8)),
                       dtype=jnp.int32)
    logits, caches = prefill(params, {"tokens": toks})
    assert logits.shape == (2, 1, model.cfg.vocab_size)
    nxt = greedy_sample(logits)
    logits2, caches = decode(params, nxt, caches, jnp.int32(8))
    assert logits2.shape == (2, 1, model.cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_greedy_deterministic(served):
    model, params = served
    eng1 = ServeEngine(model, params, batch_size=2, max_cache=48)
    eng2 = ServeEngine(model, params, batch_size=2, max_cache=48)
    prompt = np.arange(3, 3 + 12, dtype=np.int32)
    for eng in (eng1, eng2):
        eng.submit(Request(0, prompt, max_new_tokens=6))
        eng.submit(Request(1, prompt, max_new_tokens=6))
    r1 = {r.request_id: r.tokens.tolist() for r in eng1.run()}
    r2 = {r.request_id: r.tokens.tolist() for r in eng2.run()}
    assert r1 == r2
    assert r1[0] == r1[1]                        # same prompt -> same output


def test_bucketing_mixed_lengths(served):
    model, params = served
    eng = ServeEngine(model, params, batch_size=2, max_cache=64)
    for i, L in enumerate((8, 8, 16, 16, 8)):
        eng.submit(Request(i, np.arange(3, 3 + L, dtype=np.int32),
                           max_new_tokens=4))
    resp = eng.run()
    assert len(resp) == 5
    assert eng.pending() == 0
    assert len(eng.telemetry) == 5


def test_batch_padding_isolation(served):
    """A padded slot (engine fills short batches) must not change results."""
    model, params = served
    prompt = np.arange(3, 3 + 10, dtype=np.int32)
    eng_full = ServeEngine(model, params, batch_size=2, max_cache=32)
    eng_full.submit(Request(0, prompt, max_new_tokens=4))
    eng_full.submit(Request(1, prompt, max_new_tokens=4))
    out_full = {r.request_id: r.tokens.tolist() for r in eng_full.run()}
    eng_half = ServeEngine(model, params, batch_size=2, max_cache=32)
    eng_half.submit(Request(0, prompt, max_new_tokens=4))
    out_half = {r.request_id: r.tokens.tolist() for r in eng_half.run()}
    assert out_half[0] == out_full[0]


def test_encode_step_encoder_only():
    model = Model.from_name("hubert-xlarge", reduced=True)
    params = model.init(jax.random.key(0))
    encode = build_encode_step(model)
    frames = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 16, model.cfg.frontend_dim)), jnp.bfloat16)
    logits = encode(params, {"frames": frames})
    assert logits.shape == (2, 16, model.cfg.vocab_size)


def test_int8_kv_cache_matches_bf16():
    """§Perf hillclimb C: quantized decode tracks the bf16 cache closely."""
    import dataclasses
    base = Model.from_name("yi-34b", reduced=True)
    q8 = Model(dataclasses.replace(base.cfg, kv_cache_dtype="int8"))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, 400, (2, 12)), dtype=jnp.int32)
    outs = {}
    for model in (base, q8):
        params = model.init(jax.random.key(0))      # same weights
        prefill = build_prefill_step(model, cache_size=16)
        decode = build_decode_step(model, donate=False)
        logits, caches = prefill(params, {"tokens": toks[:, :10]})
        for i in range(2):
            logits, caches = decode(params, toks[:, 10 + i:11 + i], caches,
                                    jnp.int32(10 + i))
        outs[model.cfg.kv_cache_dtype] = np.asarray(logits, np.float32)
    err = np.abs(outs["int8"] - outs["bfloat16"]).max()
    assert err < 0.05, err
    # and the cache footprint halves (+ small scale overhead)
    b_bytes = kv_cache.cache_nbytes(base, 2, 16)
    q_bytes = kv_cache.cache_nbytes(q8, 2, 16)
    assert q_bytes < 0.56 * b_bytes


def test_cache_specs_and_sizes():
    model = Model.from_name("yi-34b", reduced=True)
    specs = kv_cache.cache_specs(model, batch=2, cache_size=64)
    leaves = jax.tree.leaves(specs)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
    nbytes = kv_cache.cache_nbytes(model, 2, 64)
    assert nbytes == sum(int(np.prod(s.shape)) * s.dtype.itemsize
                         for s in leaves)
    caches = kv_cache.init_caches(model, 2, 64)
    for s, c in zip(leaves, jax.tree.leaves(caches)):
        assert s.shape == c.shape and s.dtype == c.dtype
        assert float(jnp.abs(c).max()) == 0.0


def test_telemetry_feeds_ingestion(served):
    model, params = served
    eng = ServeEngine(model, params, batch_size=2, max_cache=32)
    eng.submit(Request(0, np.arange(3, 13, dtype=np.int32), max_new_tokens=3))
    eng.run()
    tb = eng.telemetry_batch()
    assert len(tb) == 1
    assert tb.text_fields == ("content1",)
    from repro.core.matcher import compile_bundle
    from repro.core.patterns import Rule, RuleSet
    from repro.core.stream_processor import StreamProcessor
    rs = RuleSet((Rule(0, "s", "serve request", fields=("content1",)),))
    proc = StreamProcessor(compile_bundle(rs, ("content1",)))
    out = proc.process(tb)
    from repro.core import enrichment
    assert enrichment.any_match(out.columns["rule_bitmap"]).all()
