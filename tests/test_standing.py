"""Standing-query tests: O(delta) incremental view maintenance stays
bit-identical to the pull path across every epoch kind the store publishes
(seal / backfill install / compaction replace / retention retire), folds
only the changed segments, degrades honestly when a fold faults, and heals
on the next pass."""
import os

import numpy as np
import pytest

from repro.core import faults
from repro.core.control_plane import ControlBus
from repro.core.maintenance import (BackfillWorker, Compactor,
                                    RetentionPolicy, RetentionWorker)
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.store import SegmentStore
from repro.core.records import decode_texts
from repro.core.stream_processor import StreamProcessor
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec


@pytest.fixture(autouse=True)
def _fault_isolation():
    """Fresh fault state per test, with the chaos-leg env profile (if any)
    re-armed *before* each test so its fire budget resets: every standing
    test absorbs the same `standing.fold` injection — a failed seal fold
    healed by the next pass with results identical to a clean run."""
    faults.reset()
    if os.environ.get(faults.ENV_VAR):
        faults.load_profile(os.environ[faults.ENV_VAR])
    yield
    faults.reset()
    if os.environ.get(faults.ENV_VAR):
        faults.load_profile(os.environ[faults.ENV_VAR])


def make_world(tmp_path, *, num_records=6000, segment_size=1500, seed=13,
               hold_back=None, shards=1):
    """Planted workload + full maintenance stack.  ``hold_back`` keeps one
    rule out of the initial rollout (the late rule backfill re-enriches)."""
    spec = WorkloadSpec(num_records=num_records, ultra_rate=1e-3,
                        high_rate=1e-2, seed=seed, text_width=256)
    gen = LogGenerator(spec)
    full = RuleSet(tuple(Rule(i, t.term, t.term, fields=(t.fieldname,))
                         for i, t in enumerate(spec.planted)))
    initial = full.without_ids([hold_back]) if hold_back is not None else full
    bus, ostore = ControlBus(), ObjectStore()
    proc = StreamProcessor(compile_bundle(initial, spec.content_fields),
                           bus=bus, store=ostore)
    store = SegmentStore(segment_size=segment_size, root=tmp_path,
                         index_fields=spec.content_fields)
    updater = MatcherUpdater(ostore, bus, spec.content_fields,
                             initial=initial)
    from repro.data.pipeline import IngestPipeline
    IngestPipeline(gen, store, proc).run(batch_size=1000)
    mapper = QueryMapper(initial, version_id=0)
    engine = QueryEngine(store, mapper=mapper, shards=shards)
    return dict(spec=spec, gen=gen, full=full, initial=initial, bus=bus,
                ostore=ostore, proc=proc, store=store, updater=updater,
                mapper=mapper, engine=engine)


def activate_full_ruleset(w):
    h = w["updater"].submit(w["full"], asynchronous=False)
    assert h.published, h.error
    w["proc"].poll_updates()
    w["mapper"].notify(w["full"], version_id=w["proc"].active_version_id)


def ingest_more(w, num_records, seed):
    spec = WorkloadSpec(num_records=num_records,
                        ultra_rate=w["spec"].ultra_rate,
                        high_rate=w["spec"].high_rate, seed=seed,
                        text_width=w["spec"].text_width)
    from repro.data.pipeline import IngestPipeline
    IngestPipeline(LogGenerator(spec), w["store"], w["proc"]).run(
        batch_size=1000)


def assert_matches_pull(w, sq, q):
    """The maintained view must be bit-identical to a cold re-plan: count
    equals the fluxsieve pull path AND the enrichment-free full-scan
    oracle; copy mode returns the same physical records."""
    r = sq.refresh()
    pull = w["engine"].execute(q, path="auto")
    scan = w["engine"].execute(q, path="full_scan")
    assert not r.partial, r.failed_segment_ids
    assert r.count == pull.count == scan.count
    if q.mode == "copy":
        for f, col in pull.records.columns.items():
            assert np.array_equal(r.records.columns[f], col), f
    return r


# ---------------------------------------------------------------------------
# Maintained-vs-pull equivalence
# ---------------------------------------------------------------------------

def test_standing_tracks_seals(tmp_path):
    w = make_world(tmp_path)
    t = w["spec"].planted[1]
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    sq = w["engine"].register_standing(q, name="seals")
    assert sq.refresh().count == w["gen"].true_count(t)

    folds0 = sq.folds
    ingest_more(w, 3000, seed=21)           # two more seal epochs
    assert sq.folds > folds0                # folds rode the epoch feed
    assert_matches_pull(w, sq, q)


def test_standing_refresh_is_o_changed_segments(tmp_path):
    """The maintained view's steady state: refresh after refresh touches
    NO segment; one apply_update epoch folds exactly that one segment."""
    w = make_world(tmp_path)
    t = w["spec"].planted[1]
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    sq = w["engine"].register_standing(q, name="odelta")
    assert sq.segments_folded == len(w["store"].segments)

    folded0, folds0 = sq.segments_folded, sq.folds
    sq.refresh()
    sq.refresh()
    assert (sq.segments_folded, sq.folds) == (folded0, folds0)

    # one segment's enrichment swaps -> exactly one segment refolds
    w["store"].segments[2].apply_update(meta_updates={"touched": True})
    assert sq.segments_folded == folded0 + 1
    r = sq.refresh()
    assert sq.segments_folded == folded0 + 1    # refresh folded nothing
    assert r.count == w["gen"].true_count(t)


def test_standing_copy_mode_records_identical(tmp_path):
    w = make_world(tmp_path)
    t = w["spec"].planted[1]
    q = Query(terms=((t.fieldname, t.term),), mode="copy")
    sq = w["engine"].register_standing(q, name="copy")
    r = assert_matches_pull(w, sq, q)
    texts = decode_texts(r.records.columns[t.fieldname])
    assert all(t.term in x for x in texts)
    ingest_more(w, 1500, seed=22)
    assert_matches_pull(w, sq, q)


def test_standing_drop_epochs_fold_nothing(tmp_path):
    """Cache drops change residency, not results — a fold would re-warm
    what the cold-run semantics need cold."""
    w = make_world(tmp_path)
    t = w["spec"].planted[1]
    sq = w["engine"].register_standing(
        Query(terms=((t.fieldname, t.term),), mode="count"), name="drop")
    sq.refresh()
    folds0, folded0 = sq.folds, sq.segments_folded
    for seg in w["store"].segments:
        seg.drop_caches()
    assert (sq.folds, sq.segments_folded) == (folds0, folded0)
    assert sq.refresh().count == w["gen"].true_count(t)


@pytest.mark.parametrize("seed", [5, 17])
def test_standing_randomized_interleaved_epochs(tmp_path, seed):
    """The tentpole invariant: across a randomized interleaving of every
    epoch source — ingest seals, a late-rule rollout + backfill installs,
    compaction replaces, retention stamps and retires — the maintained
    result stays bit-identical to a cold pull-path re-plan after EVERY
    step, in count and copy mode both."""
    rng = np.random.default_rng(seed)
    w = make_world(tmp_path, num_records=6000, segment_size=700,
                   seed=seed, hold_back=0)
    t = w["spec"].planted[1]
    late = w["spec"].planted[0]
    qc = Query(terms=((t.fieldname, t.term),), mode="count")
    qr = Query(terms=((t.fieldname, t.term),), mode="copy")
    ql = Query(terms=((late.fieldname, late.term),), mode="count")
    e = w["engine"]
    standing = [(e.register_standing(qc, name="rand-count"), qc),
                (e.register_standing(qr, name="rand-copy"), qr),
                (e.register_standing(ql, name="rand-late"), ql)]

    backfill = BackfillWorker(w["store"], w["bus"], w["ostore"])
    compactor = Compactor(w["store"], min_records=900, target_records=2500)
    activated = False
    extra_seed = 100 + seed
    for step in range(10):
        op = rng.integers(0, 5)
        if op == 0:                         # seal epochs
            extra_seed += 1
            ingest_more(w, int(rng.integers(700, 2000)), seed=extra_seed)
        elif op == 1:                       # rollout + backfill installs
            if not activated:
                activate_full_ruleset(w)
                activated = True
            backfill.run_cycle(max_segments=3)
        elif op == 2:                       # compaction replaces
            compactor.run_cycle(max_merges=1)
        elif op == 3:                       # retention stamp + retire
            ts = sorted(s.meta["ts_min"] for s in w["store"].segments)
            if len(ts) > 3:
                horizon = ts[1] + 1         # expires ~1 segment, straddles 1
                RetentionWorker(w["store"],
                                RetentionPolicy(horizon=horizon)).run_cycle()
        else:                               # meta-only enrichment swap
            segs = w["store"].segments
            segs[int(rng.integers(0, len(segs)))].apply_update(
                meta_updates={"step": step})
        for sq, q in standing:
            assert_matches_pull(w, sq, q)
    assert len(w["store"].segments) > 0


def test_standing_sharded_engine(tmp_path):
    """Folds route through the sharded executor with the same equivalence
    (and the weighted shard affinity is the engine default)."""
    w = make_world(tmp_path, shards=3)
    assert w["engine"].executor.affinity == "weighted"
    t = w["spec"].planted[1]
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    sq = w["engine"].register_standing(q, name="sharded")
    ingest_more(w, 3000, seed=31)
    assert_matches_pull(w, sq, q)


# ---------------------------------------------------------------------------
# Honest degradation + healing
# ---------------------------------------------------------------------------

def test_standing_fold_fault_partial_then_heals(tmp_path):
    """An injected ``standing.fold`` error marks exactly the fold's
    segments failed: refresh reports honest partial/coverage, and once the
    fault clears the next pass heals the failed set."""
    w = make_world(tmp_path)
    t = w["spec"].planted[1]
    truth = w["gen"].true_count(t)
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    sq = w["engine"].register_standing(q, name="faulty")
    assert sq.refresh().count == truth

    faults.reset()
    try:
        # first shot kills the seal-epoch fold, second kills the heal
        # attempt inside the next refresh -> the partial is observable
        faults.inject("standing.fold", "error", times=2)
        ingest_more(w, 1500, seed=41)
        r = sq.refresh()
        assert r.partial
        assert r.segments_failed == 1
        assert r.coverage < 1.0
        new_sid = w["store"].segments[-1].segment_id
        assert new_sid in r.failed_segment_ids
        # served segments still answer: the old store's worth of matches
        assert r.count == truth
    finally:
        faults.reset()

    # fault cleared: the refresh heal pass refolds the failed segment
    r2 = sq.refresh()
    assert not r2.partial
    assert r2.count == w["engine"].execute(q, path="fluxsieve").count
    assert r2.count >= truth


def test_standing_close_and_registry(tmp_path):
    w = make_world(tmp_path)
    t = w["spec"].planted[1]
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    sq = w["engine"].register_standing(q, name="dup")
    with pytest.raises(ValueError):
        w["engine"].register_standing(q, name="dup")
    assert w["engine"]._standing.get("dup") is sq

    folds0 = sq.folds
    sq.close()
    ingest_more(w, 1500, seed=51)           # epochs after close: ignored
    assert sq.folds == folds0
    with pytest.raises(RuntimeError):
        sq.refresh()
    assert w["engine"]._standing.get("dup") is None
    # the name frees up for a fresh registration
    sq2 = w["engine"].register_standing(q, name="dup")
    assert sq2.refresh().count == \
        w["engine"].execute(q, path="fluxsieve").count
