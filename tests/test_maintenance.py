"""Maintenance-plane tests: retroactive re-enrichment (backfill), compaction,
rule-aware coverage, the scheduler's heat/budget policy, and the rollout edge
cases (rollback to the initial version, rule removal, mixed-coverage stores).

The invariant under test throughout: a query's result set is byte-identical
whether a segment is served via backfilled bitmap, postings, metadata counts,
or full-scan fallback — before, during, and after maintenance.

``FLUXSIEVE_MAINT_WORKERS=N`` (CI's distributed matrix leg) runs every
end-to-end convergence path below through an N-worker sharded
``MaintenanceWorkerPool`` instead of a single ``BackfillWorker`` — same
assertions, distributed execution.  ``FLUXSIEVE_WORKER_MODEL=process``
(CI's process leg) goes further: a ``ProcessMaintenancePool`` of real
spawn processes over the durable control plane, with the world's bus and
object store file-backed so children share them."""
import os
import threading

import numpy as np
import pytest

from repro.core.control_plane import (CONTROL_DIRNAME, ControlBus,
                                      DurableControlBus, SEGMENT_MAINTENANCE)
from repro.core.maintenance import (BackfillWorker, Compactor,
                                    MaintenancePolicy, MaintenanceScheduler,
                                    MaintenanceWorkerPool,
                                    ProcessMaintenancePool)

MAINT_WORKERS = int(os.environ.get("FLUXSIEVE_MAINT_WORKERS", "1") or "1")
WORKER_MODEL = os.environ.get("FLUXSIEVE_WORKER_MODEL", "thread")


def make_backfill(store, bus, ostore, **kw):
    """A BackfillWorker, or (under the CI matrix's distributed leg) a
    sharded+leased pool with the same run_cycle/run_until_converged/
    worker_ids surface — as threads, or (process leg) real spawn processes
    over the durable control plane.  The process pool needs a durable
    world (spilled store + file-backed bus/objects); in-memory worlds
    (a few unit tests build their own) keep the thread model."""
    if (WORKER_MODEL == "process" and store.root is not None
            and getattr(ostore, "_root", None) is not None
            and isinstance(bus, DurableControlBus)):
        sched = kw.pop("scheduler", None)
        if sched is not None:
            kw.setdefault("policy", sched.policy)
        return ProcessMaintenancePool(
            store.root, store=store, objects_root=ostore._root,
            num_workers=max(MAINT_WORKERS, 2),
            segment_size=store.segment_size,
            index_fields=store.index_fields, **kw)
    if MAINT_WORKERS > 1:
        return MaintenanceWorkerPool(store, bus, ostore,
                                     num_workers=MAINT_WORKERS, **kw)
    return BackfillWorker(store, bus, ostore, **kw)
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.profiler import QueryProfiler
from repro.core.query.store import SegmentStore
from repro.core.records import RecordBatch, decode_texts, encode_texts
from repro.core.stream_processor import StreamProcessor
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import IngestPipeline

ALL_PATHS = ("full_scan", "text_index", "fluxsieve")


def make_world(tmp_path, *, num_records=6000, segment_size=1500, seed=13,
               hold_back=0):
    """Ingest a planted workload with rule ``hold_back`` NOT yet active —
    the late rule the maintenance plane must backfill."""
    spec = WorkloadSpec(num_records=num_records, ultra_rate=1e-3,
                        high_rate=1e-2, seed=seed, text_width=256)
    gen = LogGenerator(spec)
    full = RuleSet(tuple(Rule(i, t.term, t.term, fields=(t.fieldname,))
                         for i, t in enumerate(spec.planted)))
    initial = full.without_ids([hold_back])
    if WORKER_MODEL == "process":
        # durable control plane: worker processes read the same files
        bus = DurableControlBus(tmp_path / CONTROL_DIRNAME)
        ostore = ObjectStore(root=tmp_path / "objects")
    else:
        bus, ostore = ControlBus(), ObjectStore()
    proc = StreamProcessor(compile_bundle(initial, spec.content_fields),
                           bus=bus, store=ostore)
    store = SegmentStore(segment_size=segment_size, root=tmp_path,
                         index_fields=spec.content_fields)
    updater = MatcherUpdater(ostore, bus, spec.content_fields,
                             initial=initial)
    IngestPipeline(gen, store, proc).run(batch_size=1000)
    mapper = QueryMapper(initial, version_id=0)
    profiler = QueryProfiler(hot_count=2, hot_seconds=1e-6)
    engine = QueryEngine(store, mapper=mapper, profiler=profiler)
    return dict(spec=spec, gen=gen, full=full, initial=initial, bus=bus,
                ostore=ostore, proc=proc, store=store, updater=updater,
                mapper=mapper, profiler=profiler, engine=engine,
                late=spec.planted[hold_back])


def activate_late_rule(w):
    """Roll the full ruleset out to the stream plane + mapper (the late rule
    becomes active, historical segments still predate it)."""
    h = w["updater"].submit(w["full"], asynchronous=False)
    assert h.published, h.error
    w["proc"].poll_updates()
    w["mapper"].notify(w["full"], version_id=w["proc"].active_version_id)
    return h


def assert_paths_agree(engine, q, expect=None):
    counts = {p: engine.execute(q, path=p).count for p in ALL_PATHS}
    assert len(set(counts.values())) == 1, counts
    if expect is not None:
        assert counts["fluxsieve"] == expect, counts
    return counts["fluxsieve"]


# ---------------------------------------------------------------------------
# Backfill
# ---------------------------------------------------------------------------

def test_backfill_late_rule_end_to_end(tmp_path):
    w = make_world(tmp_path)
    late = w["late"]
    truth = w["gen"].true_count(late)
    assert truth > 0
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    activate_late_rule(w)

    # pre-backfill: correct via consistency fallback on every segment
    r_pre = w["engine"].execute(q, path="fluxsieve")
    assert r_pre.count == truth
    assert r_pre.segments_fallback == len(w["store"].segments)

    worker = make_backfill(w["store"], w["bus"], w["ostore"],
                           scheduler=MaintenanceScheduler(w["profiler"]))
    rep = worker.run_until_converged()
    assert rep.segments_backfilled == len(w["store"].segments)
    assert rep.pending_after == 0 and rep.acked

    # post-backfill: served from enrichment, zero fallback, same bytes
    r_post = w["engine"].execute(q, path="fluxsieve")
    assert r_post.count == truth
    assert r_post.segments_fallback == 0
    assert_paths_agree(w["engine"], q, expect=truth)

    # copy mode returns the same physical records
    qc = Query(terms=((late.fieldname, late.term),), mode="copy")
    recs = {p: w["engine"].execute(qc, path=p).records for p in ALL_PATHS}
    texts = {p: sorted(decode_texts(r.columns[late.fieldname]))
             for p, r in recs.items()}
    assert texts["fluxsieve"] == texts["full_scan"] == texts["text_index"]

    # ack flow: updater sees the maintenance rollout as complete (one ack
    # per worker/shard under the distributed leg)
    status = w["updater"].await_maintenance(rep.version,
                                            worker.worker_ids, timeout=2)
    assert status.complete


def test_backfill_survives_spill_reload(tmp_path):
    """Backfilled artifacts are durable: a cold store reloaded from disk
    serves the late rule from enrichment with no fallback."""
    w = make_world(tmp_path)
    late = w["late"]
    truth = w["gen"].true_count(late)
    activate_late_rule(w)
    make_backfill(w["store"], w["bus"], w["ostore"]).run_until_converged()

    reloaded = SegmentStore.load(tmp_path)
    engine = QueryEngine(reloaded, mapper=w["mapper"])
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    r = engine.execute(q, path="fluxsieve", cold=True)
    assert r.count == truth and r.segments_fallback == 0


def test_mixed_store_partial_backfill(tmp_path):
    """Budgeted cycle: some segments backfilled, the rest on fallback —
    every path still returns identical counts (the acceptance invariant)."""
    w = make_world(tmp_path)
    late = w["late"]
    truth = w["gen"].true_count(late)
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    activate_late_rule(w)

    sched = MaintenanceScheduler(
        w["profiler"], MaintenancePolicy(max_segments_per_cycle=1))
    worker = BackfillWorker(w["store"], w["bus"], w["ostore"],
                            scheduler=sched)
    rep = worker.run_cycle()
    assert rep.segments_backfilled == 1
    r = w["engine"].execute(q, path="fluxsieve")
    assert 0 < r.segments_fallback < len(w["store"].segments)
    assert_paths_agree(w["engine"], q, expect=truth)


def test_backfill_concurrent_with_ingest_and_queries(tmp_path):
    """Acceptance: ingest + BackfillWorker.run_cycle() + queries interleave
    with no pauses; fluxsieve and full_scan agree at every step."""
    w = make_world(tmp_path, num_records=6000, segment_size=800)
    late = w["late"]
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    activate_late_rule(w)
    worker = make_backfill(
        w["store"], w["bus"], w["ostore"],
        scheduler=MaintenanceScheduler(
            w["profiler"], MaintenancePolicy(max_segments_per_cycle=2)))

    gen2 = LogGenerator(WorkloadSpec(num_records=4000, ultra_rate=1e-3,
                                     high_rate=1e-2, seed=99, text_width=256))
    start = 0
    while start < 4000:
        batch = gen2.batch(start, 500)
        w["store"].append(w["proc"].process(batch))   # ingest continues
        worker.run_cycle()                            # maintenance continues
        # queries stay consistent at every interleaving point
        c_flux = w["engine"].execute(q, path="fluxsieve").count
        c_scan = w["engine"].execute(q, path="full_scan").count
        assert c_flux == c_scan, (start, c_flux, c_scan)
        start += 500
    w["store"].seal()
    worker.run_until_converged()
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.segments_fallback == 0
    assert r.count == w["engine"].execute(q, path="full_scan").count


def test_backfill_thread_safe_against_queries(tmp_path):
    """Atomic swap under a real thread race: one thread backfills while the
    main thread hammers the query; the count never deviates from truth."""
    w = make_world(tmp_path, num_records=4000, segment_size=500)
    late = w["late"]
    truth = w["gen"].true_count(late)
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    activate_late_rule(w)
    worker = make_backfill(w["store"], w["bus"], w["ostore"])
    errors = []

    def drain():
        try:
            worker.run_until_converged()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=drain)
    t.start()
    while t.is_alive():
        assert w["engine"].execute(q, path="fluxsieve").count == truth
    t.join()
    assert not errors, errors
    assert w["engine"].execute(q, path="fluxsieve").segments_fallback == 0


def _read_blob(ostore, version, key="engines/matcher"):
    """Artifact bytes regardless of the object-store backend — the
    process-model world uses a ROOTED store, where payloads live in blob
    files rather than the in-memory dict."""
    if ostore._root is None:
        return ostore._mem[(key, version)][0]
    return ostore._path(key, version).read_bytes()


def _write_blob(ostore, version, blob, key="engines/matcher"):
    if ostore._root is None:
        ostore._mem[(key, version)] = (blob, ostore._mem[(key, version)][1])
    else:
        ostore._path(key, version).write_bytes(blob)


def test_backfill_handles_corrupt_artifact(tmp_path):
    """A tampered maintenance artifact is nacked (with the object ref), the
    worker keeps serving its previous target, and the notification is
    RETRIED — a transient failure must not permanently drop the newest
    version (nor regress the worker to an older one)."""
    w = make_world(tmp_path, num_records=2000, segment_size=1000)
    h = activate_late_rule(w)
    data = _read_blob(w["ostore"], h.ref.version)
    _write_blob(w["ostore"], h.ref.version, data[:-40] + b"x" * 40)
    worker = BackfillWorker(w["store"], w["bus"], w["ostore"])
    rep = worker.run_cycle()
    assert rep.segments_backfilled == 0
    status = w["updater"].await_maintenance(h.version, [worker.worker_id],
                                            timeout=0.5)
    assert worker.worker_id in status.failed

    # the fault heals (e.g. transient object-store corruption): the next
    # cycle re-fetches the same uncommitted notification and converges
    _write_blob(w["ostore"], h.ref.version, data)
    rep2 = worker.run_until_converged()
    assert rep2.segments_backfilled == len(w["store"].segments)
    assert rep2.pending_after == 0 and rep2.acked


def test_compactor_isolates_failing_group(tmp_path):
    """One corrupt spill file fails only its own merge group; other groups
    still compact, and no orphaned merged dir is left for load() to
    double-count."""
    w = make_world(tmp_path, num_records=6000, segment_size=600)
    victim = w["store"].segments[0]
    victim.drop_caches()
    (victim.path / "content1.npy").write_bytes(b"corrupt")
    comp = Compactor(w["store"], min_records=1000, target_records=3000)
    rep = comp.run_cycle()
    assert rep.merges_failed == 1 and rep.errors
    assert rep.merges >= 1                       # healthy group still merged
    reloaded = SegmentStore.load(tmp_path)
    assert sum(s.num_records for s in reloaded.segments) == 6000


def test_backfill_isolates_failing_segment(tmp_path):
    """One corrupt segment must not crash the worker, block the healthy
    segments, or trigger a premature ack — and queries on the corrupt
    segment stay correct via the fallback scan path."""
    w = make_world(tmp_path, num_records=3000, segment_size=1000)
    activate_late_rule(w)
    victim = w["store"].segments[1]
    victim.drop_caches()
    (victim.path / "rule_bitmap.npy").write_bytes(b"corrupt")
    worker = BackfillWorker(w["store"], w["bus"], w["ostore"])
    rep = worker.run_until_converged()
    assert rep.segments_failed >= 1 and rep.errors
    assert rep.segments_backfilled == 2          # healthy segments done
    assert rep.pending_after == 1 and not rep.acked
    late = w["late"]
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == w["engine"].execute(q, path="full_scan").count
    assert r.segments_fallback == 1              # corrupt one scans


def test_budgeted_backfill_not_starved_by_failing_segment(tmp_path):
    """Budget of one segment per cycle + the first-scheduled segment
    permanently failing: the healthy segments must still converge (failed
    segments are deprioritized, not re-picked every cycle)."""
    w = make_world(tmp_path, num_records=3000, segment_size=1000)
    activate_late_rule(w)
    victim = w["store"].segments[0]              # lowest id schedules first
    victim.drop_caches()
    (victim.path / "rule_bitmap.npy").write_bytes(b"corrupt")
    worker = BackfillWorker(
        w["store"], w["bus"], w["ostore"],
        scheduler=MaintenanceScheduler(
            None, MaintenancePolicy(max_segments_per_cycle=1)))
    rep = worker.run_until_converged()
    assert rep.segments_backfilled == 2          # both healthy segments
    assert rep.pending_after == 1 and not rep.acked


def test_rule_count_survives_meta_swap_and_reload(tmp_path):
    """Metadata-count path after a meta-only apply_update + disk reload:
    rule_count normalization must never leak int keys into meta.json."""
    w = make_world(tmp_path, num_records=2000, segment_size=1000)
    t = w["spec"].planted[1]
    truth = w["gen"].true_count(t)
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    assert w["engine"].execute(q, path="fluxsieve").count == truth
    for seg in w["store"].segments:
        seg.rule_count(1)                        # populate the lookup cache
        seg.apply_update(meta_updates={"touched": True})   # persists meta
    reloaded = SegmentStore.load(tmp_path)
    assert sum(s.rule_count(1) for s in reloaded.segments) == truth


def test_version_min_fallback_distrusts_changed_pattern(tmp_path):
    """Legacy segments (no rules_known metadata) use the version-min check;
    a changed pattern must bump the rule's added-at version so stale bits
    are never served."""
    rs1 = RuleSet((Rule(0, "r0", "alpha", fields=("content1",)),))
    rs2 = RuleSet((Rule(0, "r0", "beta", fields=("content1",)),))
    proc = StreamProcessor(compile_bundle(rs1, ("content1",)))
    store = SegmentStore(segment_size=2)         # no version_rules wiring
    b1 = RecordBatch({"timestamp": np.arange(2, dtype=np.int64),
                      "content1": encode_texts(["has alpha", "has beta"], 64)})
    store.append(proc.process(b1))
    proc.swap(compile_bundle(rs2, ("content1",)))
    b2 = RecordBatch({"timestamp": np.arange(2, 4, dtype=np.int64),
                      "content1": encode_texts(["more beta", "none"], 64)})
    store.append(proc.process(b2))
    store.seal()
    assert store.segments[0].meta.get("rules_known") is None
    mapper = QueryMapper(rs1, version_id=0)
    mapper.notify(rs2, version_id=1)
    engine = QueryEngine(store, mapper=mapper)
    r = engine.execute(Query(terms=(("content1", "beta"),), mode="count"),
                       path="fluxsieve")
    assert r.count == 2                          # "has beta" + "more beta"
    assert r.segments_fallback == 1              # pre-change segment scanned


def test_version_min_fallback_removed_then_readded_rule():
    """A rule removed and later re-added is NEW from the coverage
    perspective: segments sealed during the removal window have no bits
    for it and must not look covered."""
    rs = RuleSet((Rule(0, "r0", "alpha", fields=("content1",)),))
    mapper = QueryMapper(rs, version_id=1)
    mapper.notify(RuleSet(()), version_id=2)     # removal window
    mapper.notify(rs, version_id=3)              # re-add, same id + pattern
    plan = mapper.map(Query(terms=(("content1", "alpha"),), mode="count"))
    assert plan.min_version_id == 3


# ---------------------------------------------------------------------------
# Rule-aware coverage: removal, change, rollback
# ---------------------------------------------------------------------------

def test_coverage_after_rule_removal(tmp_path):
    """Removing a rule: the mapper stops planning it (queries fall back to
    scan paths with identical counts), and backfill retires its bits."""
    w = make_world(tmp_path, num_records=3000, segment_size=1000,
                   hold_back=0)
    activate_late_rule(w)
    make_backfill(w["store"], w["bus"], w["ostore"]).run_until_converged()

    victim = w["spec"].planted[1]
    removed = w["full"].without_ids([1])
    h = w["updater"].submit(removed, asynchronous=False)
    assert h.published, h.error
    w["proc"].poll_updates()
    w["mapper"].notify(removed, version_id=w["proc"].active_version_id)

    q = Query(terms=((victim.fieldname, victim.term),), mode="count")
    assert w["mapper"].map(q) is None            # no longer a planned rule
    r = w["engine"].execute(q, path="auto")
    assert r.path != "fluxsieve"
    assert r.count == w["gen"].true_count(victim)

    worker = make_backfill(w["store"], w["bus"], w["ostore"])
    worker.run_until_converged()
    for seg in w["store"].segments:
        assert "1" not in seg.meta["rule_idents"]


def test_coverage_rule_changed_pattern_not_trusted(tmp_path):
    """Reusing a rule id with a new pattern must NOT serve stale bits:
    coverage is by content identity, so pre-change segments fall back until
    backfill re-matches them."""
    rs1 = RuleSet((Rule(0, "r0", "alpha", fields=("content1",)),))
    rs2 = RuleSet((Rule(0, "r0", "beta", fields=("content1",)),))
    bus, ostore = ControlBus(), ObjectStore()
    proc = StreamProcessor(compile_bundle(rs1, ("content1",)),
                           bus=bus, store=ostore)
    store = SegmentStore(segment_size=2, version_rules=proc.version_rules)
    updater = MatcherUpdater(ostore, bus, ("content1",), initial=rs1)
    b1 = RecordBatch({"timestamp": np.arange(2, dtype=np.int64),
                      "content1": encode_texts(["has alpha", "has beta"], 64)})
    store.append(proc.process(b1))

    h = updater.submit(rs2, asynchronous=False)
    assert h.published, h.error
    proc.poll_updates()
    b2 = RecordBatch({"timestamp": np.arange(2, 4, dtype=np.int64),
                      "content1": encode_texts(["more beta", "none"], 64)})
    store.append(proc.process(b2))
    store.seal()

    mapper = QueryMapper(rs1, version_id=0)
    mapper.notify(rs2, version_id=proc.active_version_id)
    engine = QueryEngine(store, mapper=mapper)
    q = Query(terms=(("content1", "beta"),), mode="count")
    r = engine.execute(q, path="fluxsieve")
    assert r.count == 2                          # stale bits NOT trusted
    assert r.segments_fallback == 1              # pre-change segment scanned

    make_backfill(store, bus, ostore).run_until_converged()
    r2 = engine.execute(q, path="fluxsieve")
    assert r2.count == 2 and r2.segments_fallback == 0


def test_rollback_to_initial_version(tmp_path):
    """Rolling back to the initial (artifact-less) version recompiles it,
    redistributes it, and the maintenance plane converges segments back to
    the initial coverage."""
    w = make_world(tmp_path, num_records=2000, segment_size=1000)
    h = activate_late_rule(w)
    worker = make_backfill(w["store"], w["bus"], w["ostore"])
    worker.run_until_converged()
    assert w["updater"].await_maintenance(
        h.version, worker.worker_ids, timeout=2).complete

    rb = w["updater"].rollback()
    assert rb.published, rb.error
    assert w["updater"].current_version == w["initial"].version_hash()
    assert w["proc"].poll_updates() == 1
    assert w["proc"].active_version == w["initial"].version_hash()
    w["mapper"].notify(w["initial"], version_id=w["proc"].active_version_id)

    rep = worker.run_until_converged()
    for seg in w["store"].segments:
        assert "0" not in seg.meta["rule_idents"]   # late rule retired again
    # re-acking a previously acked version: rolling BACK must still produce
    # a fresh convergence ack, or await_maintenance hangs to timeout
    assert rep.acked
    assert w["updater"].await_maintenance(
        rb.version, worker.worker_ids, timeout=2).complete
    # the de-activated rule no longer plans; other rules still serve fast
    other = w["spec"].planted[1]
    q = Query(terms=((other.fieldname, other.term),), mode="count")
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == w["gen"].true_count(other)
    assert r.segments_fallback == 0


# ---------------------------------------------------------------------------
# Compaction
# ---------------------------------------------------------------------------

def test_compaction_preserves_results(tmp_path):
    w = make_world(tmp_path, num_records=6000, segment_size=600)
    late = w["late"]
    activate_late_rule(w)
    BackfillWorker(w["store"], w["bus"], w["ostore"]).run_until_converged()
    n_before = len(w["store"].segments)
    counts_before = {
        t.term: assert_paths_agree(
            w["engine"], Query(terms=((t.fieldname, t.term),), mode="count"))
        for t in w["spec"].planted[:3]}

    comp = Compactor(w["store"], min_records=1000, target_records=3000)
    rep = comp.run_cycle()
    assert rep.merges >= 1 and rep.segments_in > rep.merges
    assert len(w["store"].segments) < n_before
    for t in w["spec"].planted[:3]:
        q = Query(terms=((t.fieldname, t.term),), mode="count")
        assert_paths_agree(w["engine"], q, expect=counts_before[t.term])
    # merged segments keep the backfilled (rule-aware) coverage
    q_late = Query(terms=((late.fieldname, late.term),), mode="count")
    assert w["engine"].execute(q_late, path="fluxsieve").segments_fallback == 0

    # reload from disk: retired inputs are gone, merged segments load clean
    reloaded = SegmentStore.load(tmp_path)
    assert len(reloaded.segments) == len(w["store"].segments)
    assert sum(s.num_records for s in reloaded.segments) == 6000
    engine = QueryEngine(reloaded, mapper=w["mapper"])
    assert engine.execute(q_late, cold=True).count == counts_before[late.term]


def test_compaction_skips_right_sized_segments(tmp_path):
    w = make_world(tmp_path, num_records=4000, segment_size=1000)
    comp = Compactor(w["store"], min_records=500, target_records=2000)
    rep = comp.run_cycle()
    assert rep.merges == 0
    assert len(w["store"].segments) == 4


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

class _FakeSeg:
    def __init__(self, sid, n=100, b=1000):
        self.segment_id, self.num_records, self._b = sid, n, b

    def nbytes(self, names=None):
        return self._b


def test_scheduler_orders_by_heat():
    prof = QueryProfiler()
    q = Query(terms=(("content1", "x"),), mode="count")

    class R:
        latency_s = 2.0
        path = "fluxsieve"
        fallback_ids = (7, 7, 3)
    prof.record(q, R())
    sched = MaintenanceScheduler(prof)
    segs = [_FakeSeg(1), _FakeSeg(3), _FakeSeg(7)]
    assert [s.segment_id for s in sched.order(segs)] == [7, 3, 1]


def test_backfill_clears_segment_heat(tmp_path):
    """Backfill-aware pruning stats: after a backfill install, the freshly
    covered segments' fallback heat is cleared — they stop looking hot to
    the scheduler, whose next ordering reflects segments STILL burning
    fallback time (here: none, so ordering falls back to segment id)."""
    w = make_world(tmp_path)
    late = w["late"]
    activate_late_rule(w)
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    w["engine"].execute(q, path="fluxsieve")    # all-fallback: heats every seg
    heat_pre = w["profiler"].segment_heat()
    assert set(heat_pre) == {s.segment_id for s in w["store"].segments}
    sched = MaintenanceScheduler(w["profiler"])
    worker = BackfillWorker(w["store"], w["bus"], w["ostore"],
                            scheduler=sched)
    # budgeted cycle: only the installed segments cool down, the rest stay
    # hot (and therefore first in line next cycle)
    rep = worker.run_cycle(max_segments=2)
    assert rep.segments_backfilled == 2
    heat_mid = w["profiler"].segment_heat()
    assert len(heat_mid) == len(heat_pre) - 2
    remaining = [s for s in w["store"].segments
                 if s.segment_id in heat_mid]
    assert [s.segment_id for s in sched.order(w["store"].segments)[:len(remaining)]] \
        == sorted(heat_mid, key=lambda sid: (-heat_mid[sid], sid))
    worker.run_until_converged()
    assert w["profiler"].segment_heat() == {}


def test_scheduler_enforces_budget():
    sched = MaintenanceScheduler(None, MaintenancePolicy(
        max_bytes_per_cycle=2500, max_segments_per_cycle=10))
    segs = [_FakeSeg(i, b=1000) for i in range(5)]
    assert len(sched.plan_cycle(segs)) == 2
    # a single oversized segment is still admitted (no starvation)
    big = [_FakeSeg(0, b=10_000)]
    assert len(sched.plan_cycle(big)) == 1
    sched2 = MaintenanceScheduler(None, MaintenancePolicy(
        max_records_per_cycle=250))
    assert len(sched2.plan_cycle(segs)) == 2


# ---------------------------------------------------------------------------
# Review-finding fixes: poll_target commit discipline, compactor failure
# memory, dtype/width-aware compaction grouping
# ---------------------------------------------------------------------------

def test_poll_target_keeps_transiently_failed_older_candidate(tmp_path):
    """When the NEWEST notification is permanently invalid and an older one
    fails transiently, neither offset may be committed: once the older
    artifact heals, the worker must still be able to install it (and the
    newest keeps being retried on top)."""
    w = make_world(tmp_path, num_records=2000, segment_size=1000)
    h1 = activate_late_rule(w)
    extra = w["full"].with_rules(
        [Rule(w["full"].num_rules, "extra", "XZneedleXZ",
              fields=("content1",))])
    h2 = w["updater"].submit(extra, asynchronous=False)
    assert h2.published
    blobs = {}
    for h in (h1, h2):
        data = _read_blob(w["ostore"], h.ref.version)
        blobs[h.ref.version] = data
        _write_blob(w["ostore"], h.ref.version, data[:-40] + b"x" * 40)

    worker = BackfillWorker(w["store"], w["bus"], w["ostore"])
    worker.run_cycle()
    assert worker._target is None                # nothing installable

    # the OLDER artifact heals: it must still be fetchable (not forfeited
    # by a premature commit) and becomes the installed target
    _write_blob(w["ostore"], h1.ref.version, blobs[h1.ref.version])
    rep = worker.run_until_converged()
    assert worker._target is not None
    assert worker._target.version == h1.version
    assert rep.segments_backfilled == len(w["store"].segments)

    # the newest stays uncommitted and wins once it heals too
    _write_blob(w["ostore"], h2.ref.version, blobs[h2.ref.version])
    worker.run_until_converged()
    assert worker._target.version == h2.version


def _append_text_segment(store, texts, width):
    n = len(texts)
    base = store.num_records
    store.append(RecordBatch({
        "timestamp": np.arange(base, base + n, dtype=np.int64),
        "content1": encode_texts(texts, width)}))
    store.seal()


def test_compactor_schema_compare_includes_dtype_and_width(tmp_path):
    """Mixed text_width segments share column NAMES but not widths; a
    name-only compare would group them and np.concatenate would raise every
    cycle.  Grouping must key on {name: (dtype, shape[1:])}."""
    store = SegmentStore(segment_size=1000, root=tmp_path)
    _append_text_segment(store, ["a"] * 3, 32)
    _append_text_segment(store, ["b"] * 3, 32)
    _append_text_segment(store, ["c"] * 3, 64)
    _append_text_segment(store, ["d"] * 3, 64)
    comp = Compactor(store, min_records=10, target_records=100)
    groups = [[s.segment_id for s in g] for g in comp.candidate_groups()]
    assert groups == [[0, 1], [2, 3]]
    rep = comp.run_cycle()
    assert rep.merges == 2 and rep.merges_failed == 0
    assert [s.num_records for s in store.segments] == [6, 6]


def test_compactor_failure_memory(tmp_path):
    """A permanently failing merge group is deprioritized (not fully
    re-read and re-failed every cycle) while fresh groups exist, retried
    when idle, and forgiven once it heals — mirroring the BackfillWorker's
    _failed_ids discipline."""
    store = SegmentStore(segment_size=1000, root=tmp_path)
    for texts in (["a"] * 3, ["b"] * 3):         # group A (ids 0, 1)
        _append_text_segment(store, texts, 32)
    _append_text_segment(store, ["big"] * 50, 32)  # not small: splits runs
    for texts in (["c"] * 3, ["d"] * 3):         # group B (ids 3, 4)
        _append_text_segment(store, texts, 32)
    victim = store.segments[0]
    victim.drop_caches()
    good_bytes = (victim.path / "content1.npy").read_bytes()
    (victim.path / "content1.npy").write_bytes(b"corrupt")

    comp = Compactor(store, min_records=10, target_records=100)
    rep1 = comp.run_cycle()                      # A fails, B merges
    assert rep1.merges == 1 and rep1.merges_failed == 1

    for texts in (["e"] * 3, ["f"] * 3):         # fresh group appears
        _append_text_segment(store, texts, 32)
    rep2 = comp.run_cycle()                      # fresh merged, A NOT re-read
    assert rep2.merges == 1 and rep2.merges_failed == 0
    assert {0, 1} <= {s.segment_id for s in store.segments}

    rep3 = comp.run_cycle()                      # idle: A retried, fails
    assert rep3.merges == 0 and rep3.merges_failed == 1

    (victim.path / "content1.npy").write_bytes(good_bytes)  # heals
    rep4 = comp.run_cycle()
    assert rep4.merges == 1 and rep4.merges_failed == 0
    assert not comp._failed_keys
