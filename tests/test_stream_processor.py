import numpy as np
import pytest

from repro.core import enrichment
from repro.core.matcher import EngineBundle, build_matchers, compile_bundle
from repro.core.stream_processor import (ENGINE_VERSION_COLUMN,
                                         ENRICH_COLUMN, StreamProcessor)


@pytest.fixture
def bundle(small_ruleset):
    return compile_bundle(small_ruleset, fields=("content1", "content2"))


def test_enrich_mode(bundle, small_batch):
    proc = StreamProcessor(bundle)
    out = proc.process(small_batch)
    assert len(out) == len(small_batch)
    bm = out.columns[ENRICH_COLUMN]
    # rule 0 (ERROR @content1): record 0; rule 1 (panic|fatal @*): 2, 4;
    # rule 2 (usr[0-9] @content2): 1, 5 — record 3 matches nothing
    assert enrichment.bitmap_get(bm, 0).tolist() == [1, 0, 0, 0, 0, 0]
    assert enrichment.bitmap_get(bm, 1).tolist() == [0, 0, 1, 0, 1, 0]
    assert enrichment.bitmap_get(bm, 2).tolist() == [0, 1, 0, 0, 0, 1]
    assert (out.columns[ENGINE_VERSION_COLUMN] == 0).all()


def test_filter_mode(bundle, small_batch):
    proc = StreamProcessor(bundle, mode="filter")
    out = proc.process(small_batch)
    assert len(out) == 5                      # record 3 ('quiet'/'calm') drops
    assert out.columns["timestamp"].tolist() == [0, 1, 2, 4, 5]


def test_field_scoping(bundle, small_batch):
    """Rule 0 is content1-only: 'ERROR' in content2 must NOT fire it."""
    batch = small_batch.with_column(
        "content2", small_batch.columns["content1"])
    proc = StreamProcessor(bundle)
    bm = proc.process(batch).columns[ENRICH_COLUMN]
    assert enrichment.bitmap_get(bm, 0).tolist() == [1, 0, 0, 0, 0, 0]


def test_swap_without_retrace(bundle, small_ruleset, small_batch):
    from repro.core.patterns import Rule
    proc = StreamProcessor(bundle)
    proc.process(small_batch)
    rs2 = small_ruleset.with_rules([Rule(3, "quiet", "quiet",
                                         fields=("content1",))])
    proc.swap(compile_bundle(rs2, ("content1", "content2")))
    out = proc.process(small_batch)
    bm = out.columns[ENRICH_COLUMN]
    assert enrichment.bitmap_get(bm, 3).tolist() == [0, 0, 0, 1, 0, 0]
    assert proc.active_version_id == 1
    assert (out.columns[ENGINE_VERSION_COLUMN] == 1).all()
    assert proc.stats.swaps == 1


def test_backends_agree(bundle, small_batch, small_ruleset):
    outs = {}
    for backend in ("dfa_ref", "dfa", "dfa_selective", "shift_or"):
        # shift_or needs literal-only patterns <= 32B: our set qualifies
        proc = StreamProcessor(bundle, backend=backend, block_n=8)
        outs[backend] = np.asarray(
            proc.process(small_batch).columns[ENRICH_COLUMN])
    for backend in ("dfa", "dfa_selective", "shift_or"):
        np.testing.assert_array_equal(outs["dfa_ref"], outs[backend])


def test_stats(bundle, small_batch):
    proc = StreamProcessor(bundle)
    proc.process(small_batch)
    proc.process(small_batch)
    assert proc.stats.records_in == 12
    assert proc.stats.batches == 2
    assert proc.stats.records_matched == 10   # 5 matching records x 2 batches
