import re

import pytest
pytest.importorskip("hypothesis")  # optional dev dep; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.patterns import Rule, RuleSet

literal_st = st.text(alphabet=st.characters(min_codepoint=33,
                                            max_codepoint=126,
                                            exclude_characters="|[].\\"),
                     min_size=1, max_size=12)


def test_rule_literals_alternation():
    r = Rule(0, "alt", "foo|bar|baz")
    assert set(r.literals()) == {"foo", "bar", "baz"}


def test_rule_literals_class():
    r = Rule(0, "cls", "usr[0-3]")
    assert set(r.literals()) == {"usr0", "usr1", "usr2", "usr3"}


def test_rule_literals_dot_and_nested():
    r = Rule(0, "d", "a[bc]d")
    assert set(r.literals()) == {"abd", "acd"}


def test_rule_case_insensitive():
    r = Rule(0, "ci", "Error", case_insensitive=True)
    assert r.matches("AN ERROR HERE")
    assert r.matches("an error here")


def test_rule_validation():
    with pytest.raises(ValueError):
        Rule(0, "empty", "")
    with pytest.raises(ValueError):
        Rule(-1, "neg", "x")
    with pytest.raises(ValueError):
        Rule(0, "emptybranch", "a||b")
    with pytest.raises(ValueError):
        Rule(0, "wide", "[ -~][ -~]")  # 95^2 expansion > cap


@given(lit=literal_st, hay=st.text(max_size=64))
@settings(max_examples=50, deadline=None)
def test_rule_matches_agrees_with_python(lit, hay):
    r = Rule(0, "p", lit)
    assert r.matches(hay) == (lit in hay)


def test_ruleset_duplicate_ids():
    with pytest.raises(ValueError):
        RuleSet((Rule(0, "a", "x"), Rule(0, "b", "y")))


def test_ruleset_diff():
    rs1 = RuleSet((Rule(0, "a", "x"), Rule(1, "b", "y")))
    rs2 = RuleSet((Rule(0, "a", "x2"), Rule(2, "c", "z")))
    d = rs1.diff(rs2)
    assert [r.rule_id for r in d["added"]] == [2]
    assert [r.rule_id for r in d["removed"]] == [1]
    assert [r.rule_id for r in d["changed"]] == [0]


def test_ruleset_diff_noop():
    rs = RuleSet((Rule(0, "a", "x"),))
    d = rs.diff(rs)
    assert not (d["added"] or d["removed"] or d["changed"])


def test_version_hash_stable_and_sensitive():
    rs1 = RuleSet((Rule(0, "a", "x"), Rule(1, "b", "y")))
    rs2 = RuleSet((Rule(1, "b", "y"), Rule(0, "a", "x")))  # order-insensitive
    assert rs1.version_hash() == rs2.version_hash()
    rs3 = rs1.with_rules([Rule(2, "c", "z")])
    assert rs3.version_hash() != rs1.version_hash()


def test_json_round_trip():
    rs = RuleSet((Rule(0, "a", "x|y", fields=("content1",)),
                  Rule(3, "b", "q", case_insensitive=True)))
    rs2 = RuleSet.from_json(rs.to_json())
    assert rs2 == rs


def test_rules_for_field():
    rs = RuleSet((Rule(0, "a", "x", fields=("content1",)),
                  Rule(1, "b", "y", fields=("*",))))
    assert [r.rule_id for r in rs.rules_for_field("content1")] == [0, 1]
    assert [r.rule_id for r in rs.rules_for_field("content2")] == [1]


def test_num_rules_uses_max_id():
    rs = RuleSet((Rule(5, "a", "x"),))
    assert rs.num_rules == 6
