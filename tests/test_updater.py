"""Update-protocol tests: delta -> compile -> upload -> notify -> fetch ->
validate -> swap -> ack (paper §3.4.2 steps 1-6), plus rollback and the
failure paths (corrupt artifact, missing instance)."""
import numpy as np
import pytest

from repro.core.control_plane import ControlBus, MATCHER_UPDATES
from repro.core.matcher import compile_bundle
from repro.core.object_store import IntegrityError, ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.stream_processor import StreamProcessor
from repro.core.updater import ENGINE_KEY, MatcherUpdater


@pytest.fixture
def world(small_ruleset):
    store, bus = ObjectStore(), ControlBus()
    bundle = compile_bundle(small_ruleset, ("content1", "content2"))
    procs = [StreamProcessor(bundle, instance_id=f"proc-{i}", bus=bus,
                             store=store) for i in range(3)]
    upd = MatcherUpdater(store, bus, ("content1", "content2"),
                         initial=small_ruleset)
    return store, bus, procs, upd


def test_full_rollout(world, small_ruleset):
    store, bus, procs, upd = world
    rs2 = small_ruleset.with_rules([Rule(3, "new", "needle")])
    h = upd.submit(rs2)
    assert h.wait(10) and h.published, h.error
    for p in procs:
        assert p.poll_updates() == 1
    status = upd.await_rollout(h.version, [p.instance_id for p in procs],
                               timeout=5)
    assert status.complete
    assert all(p.num_rules == 4 for p in procs)
    assert all(p.active_version == rs2.version_hash() for p in procs)


def test_noop_delta(world, small_ruleset):
    _, _, _, upd = world
    h = upd.submit(small_ruleset)
    assert h.wait(5)
    assert "no-op" in h.error


def test_missing_instance_detected(world, small_ruleset):
    _, _, procs, upd = world
    rs2 = small_ruleset.with_rules([Rule(3, "new", "needle")])
    h = upd.submit(rs2)
    h.wait(10)
    procs[0].poll_updates()                      # only one instance fetches
    status = upd.await_rollout(h.version, ["proc-0", "proc-1", "proc-2"],
                               timeout=0.3)
    assert not status.complete
    assert status.acked == ("proc-0",)
    assert set(status.missing) == {"proc-1", "proc-2"}


def test_corrupt_artifact_nacked(world, small_ruleset):
    store, bus, procs, upd = world
    rs2 = small_ruleset.with_rules([Rule(3, "new", "needle")])
    h = upd.submit(rs2)
    h.wait(10)
    # tamper with the stored artifact AFTER upload
    key = (ENGINE_KEY, h.ref.version)
    data, meta = store._mem[key]
    store._mem[key] = (data[:-40] + b"x" * 40, meta)
    procs[0].poll_updates()
    status = upd.await_rollout(h.version, ["proc-0"], timeout=0.5)
    assert not status.complete
    assert "proc-0" in status.failed
    # processor keeps serving on the old engine
    assert procs[0].num_rules == 3


def test_rollback(world, small_ruleset):
    _, _, procs, upd = world
    rs2 = small_ruleset.with_rules([Rule(3, "new", "needle")])
    rs3 = rs2.with_rules([Rule(4, "newer", "pin")])
    for rs in (rs2, rs3):
        h = upd.submit(rs)
        h.wait(10)
        for p in procs:
            p.poll_updates()
    assert all(p.num_rules == 5 for p in procs)
    rb = upd.rollback()
    assert rb.published, rb.error
    for p in procs:
        p.poll_updates()
    assert all(p.num_rules == 4 for p in procs)
    assert upd.current_version == rs2.version_hash()


def test_object_store_versioning_and_integrity():
    store = ObjectStore()
    r1 = store.put("k", b"v1")
    r2 = store.put("k", b"v2")
    assert (r1.version, r2.version) == (1, 2)
    assert store.get(r1) == b"v1"                # old versions immutable
    data, latest = store.get_latest("k")
    assert data == b"v2" and latest.version == 2
    bad = type(r1)(key="k", version=1, sha256="0" * 64, size=2)
    with pytest.raises(IntegrityError):
        store.get(bad)
    assert store.expire_versions("k", keep_latest=1) == 1
    assert store.list_versions("k") == [2]


def test_object_store_on_disk(tmp_path):
    store = ObjectStore(tmp_path)
    ref = store.put("engines/matcher", b"payload")
    store2 = ObjectStore(tmp_path)               # new process view
    assert store2.get(ref) == b"payload"


def test_control_bus_at_least_once():
    bus = ControlBus()
    bus.publish(MATCHER_UPDATES, {"v": 1})
    bus.publish(MATCHER_UPDATES, {"v": 2})
    msgs = bus.poll(MATCHER_UPDATES, "g1")
    assert [m.value["v"] for m in msgs] == [1, 2]
    # not committed -> redelivered
    assert len(bus.poll(MATCHER_UPDATES, "g1")) == 2
    bus.commit(MATCHER_UPDATES, "g1", msgs[0].offset)
    assert [m.value["v"] for m in bus.poll(MATCHER_UPDATES, "g1")] == [2]
    # independent groups
    assert len(bus.poll(MATCHER_UPDATES, "g2")) == 2
