"""Maintenance plane v2 — crash injection and distribution.

What a durable maintenance plane must survive, each simulated here:

  * a worker killed mid-backfill resumes from its row-watermark checkpoint
    (never re-matches from row 0);
  * a hard kill between a compactor spilling its merged segment and
    retiring the inputs must not double-count on reload (the root manifest
    is the single commit point);
  * two workers racing one segment: the fenced loser's write is REJECTED
    at the write barrier, the winner's install stands;
  * retention age-out, compaction row purge, and spill-dir GC cooperate
    without breaking in-flight readers.

``FLUXSIEVE_MAINT_WORKERS`` (also honored by ``test_maintenance.py``) runs
the end-to-end paths through a sharded ``MaintenanceWorkerPool`` instead
of a single worker — CI exercises the distributed plane on every PR.
"""
import os
import threading

import numpy as np
import pytest

from repro.core.control_plane import ControlBus
from repro.core.maintenance import (BackfillWorker, Compactor,
                                    FencedWriteError, LeaseManager,
                                    MaintenanceWorkerPool, RetentionPolicy,
                                    RetentionWorker, SpillGC, shard_of)
from repro.core.maintenance.backfill import CKPT_NAME
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.arrangement import ArrangementStore
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.store import (RETIRED_MARKER, Manifest, SegmentStore)
from repro.core.records import RecordBatch
from repro.core.stream_processor import StreamProcessor
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import IngestPipeline

MAINT_WORKERS = int(os.environ.get("FLUXSIEVE_MAINT_WORKERS", "1") or "1")


def make_world(tmp_path, *, num_records=6000, segment_size=1500, seed=13,
               hold_back=0, root=True):
    spec = WorkloadSpec(num_records=num_records, ultra_rate=1e-3,
                        high_rate=1e-2, seed=seed, text_width=256)
    gen = LogGenerator(spec)
    full = RuleSet(tuple(Rule(i, t.term, t.term, fields=(t.fieldname,))
                         for i, t in enumerate(spec.planted)))
    initial = full.without_ids([hold_back])
    bus, ostore = ControlBus(), ObjectStore()
    proc = StreamProcessor(compile_bundle(initial, spec.content_fields),
                           bus=bus, store=ostore)
    store = SegmentStore(segment_size=segment_size,
                         root=tmp_path if root else None)
    updater = MatcherUpdater(ostore, bus, spec.content_fields,
                             initial=initial)
    IngestPipeline(gen, store, proc).run(batch_size=1000)
    mapper = QueryMapper(initial, version_id=0)
    engine = QueryEngine(store, mapper=mapper)
    return dict(spec=spec, gen=gen, full=full, initial=initial, bus=bus,
                ostore=ostore, proc=proc, store=store, updater=updater,
                mapper=mapper, engine=engine, late=spec.planted[hold_back])


def activate_late_rule(w):
    h = w["updater"].submit(w["full"], asynchronous=False)
    assert h.published, h.error
    w["proc"].poll_updates()
    w["mapper"].notify(w["full"], version_id=w["proc"].active_version_id)
    return h


def late_query(w):
    late = w["late"]
    return (Query(terms=((late.fieldname, late.term),), mode="count"),
            w["gen"].true_count(late))


# ---------------------------------------------------------------------------
# Incremental checkpointing: watermark resume, not row 0
# ---------------------------------------------------------------------------

def test_watermark_resume_after_worker_kill(tmp_path):
    """Kill a worker mid-backfill (after a partial, checkpointed pass); a
    FRESH worker — no shared memory, the restart case — resumes every
    segment from its row watermark instead of re-matching from row 0."""
    w = make_world(tmp_path)
    activate_late_rule(w)
    q, truth = late_query(w)
    n_seg = len(w["store"].segments)
    seg_rows = w["store"].segments[0].num_records

    worker = BackfillWorker(w["store"], w["bus"], w["ostore"],
                            rows_per_pass=600)
    rep1 = worker.run_cycle()
    # every segment got exactly one 600-row partial pass, none installed
    assert rep1.segments_partial == n_seg
    assert rep1.segments_backfilled == 0
    assert rep1.rows_matched == 600 * n_seg
    for seg in w["store"].segments:
        assert (seg.path / CKPT_NAME).exists()
    # partially backfilled state is invisible: queries still consistent
    assert w["engine"].execute(q, path="fluxsieve").count == truth

    # "kill" the worker: a brand-new instance has no in-memory state and
    # must pick the on-disk checkpoints up
    worker2 = BackfillWorker(w["store"], w["bus"], w["ostore"])
    rep2 = worker2.run_until_converged()
    assert rep2.segments_backfilled == n_seg
    assert rep2.rows_resumed == 600 * n_seg
    # the decisive assertion: only the REMAINING rows were re-matched
    assert rep2.rows_matched == (seg_rows - 600) * n_seg
    assert rep2.pending_after == 0 and rep2.acked

    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == truth and r.segments_fallback == 0
    # checkpoints are consumed by the install
    for seg in w["store"].segments:
        assert not (seg.path / CKPT_NAME).exists()


def test_checkpoint_invalidated_by_moved_target(tmp_path):
    """A checkpoint written for target A must not seed a resume toward
    target B: the key includes version + delta, so the segment restarts
    from row 0 under the new target."""
    w = make_world(tmp_path)
    seg = w["store"].segments[0]
    n = seg.num_records

    worker = BackfillWorker(w["store"], w["bus"], w["ostore"],
                            rows_per_pass=500)
    worker.set_target(w["full"])
    rep = worker.run_cycle(max_segments=1)
    assert rep.segments_partial == 1 and rep.rows_matched == 500

    # target moves: the late rule's PATTERN changes, so the delta (and the
    # checkpoint key) differ — the stale checkpoint must not seed a resume
    moved = RuleSet(tuple(
        Rule(r.rule_id, r.name, r.pattern + "X", fields=r.fields)
        if r.rule_id == 0 else r for r in w["full"].rules))
    worker2 = BackfillWorker(w["store"], w["bus"], w["ostore"])
    worker2.set_target(moved)
    rep2 = BackfillWorkerDrain(worker2, seg)
    assert rep2.rows_resumed == 0
    assert rep2.rows_matched >= n     # full re-match, stale ckpt ignored


def BackfillWorkerDrain(worker, seg):
    """Drain one segment through a worker, returning the merged report."""
    from repro.core.maintenance import BackfillReport, merge_reports
    total = BackfillReport()
    for _ in range(100):
        rep = worker.run_cycle()
        merge_reports(total, rep)
        if rep.pending_after == 0:
            break
    return total


def test_budget_cut_resumes_within_one_worker(tmp_path):
    """A mid-segment budget cut (scheduler policy rows budget) resumes at
    the watermark on the next cycle of the SAME worker."""
    from repro.core.maintenance import MaintenancePolicy, MaintenanceScheduler
    w = make_world(tmp_path)
    activate_late_rule(w)
    q, truth = late_query(w)
    sched = MaintenanceScheduler(
        None, MaintenancePolicy(max_rows_per_segment_pass=700))
    worker = BackfillWorker(w["store"], w["bus"], w["ostore"],
                            scheduler=sched)
    rep = worker.run_until_converged()
    n_rows = sum(s.num_records for s in w["store"].segments)
    assert rep.segments_backfilled == len(w["store"].segments)
    # total matched rows across all passes == store rows, exactly once
    assert rep.rows_matched == n_rows
    assert rep.segments_partial > 0       # the budget actually cut passes
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == truth and r.segments_fallback == 0


# ---------------------------------------------------------------------------
# Crash-safe manifest: the compaction double-count window
# ---------------------------------------------------------------------------

def test_manifest_no_double_count_after_crash_between_spill_and_retire(
        tmp_path):
    """Hard-kill simulation: the compactor spills its merged segment and
    dies BEFORE the swap commits.  Both the merged artifact and the inputs
    are on disk; a manifest-guarded load must count every record once."""
    w = make_world(tmp_path, num_records=4000, segment_size=1000)
    store = w["store"]
    n_before = store.num_records
    group = store.segments[:2]

    # the crash: materialize the merged segment (spilled, UNREGISTERED),
    # then stop — no replace_segments, no tombstones
    names = sorted(group[0].meta["columns"])
    cols = {name: np.concatenate([np.asarray(s.column(name))
                                  for s in group]) for name in names}
    merged = store.make_segment_from_batch(RecordBatch(cols))
    assert merged.path.exists()

    reloaded = SegmentStore.load(tmp_path)
    assert reloaded.num_records == n_before
    assert merged.segment_id not in {s.segment_id for s in reloaded.segments}

    # ...and the other side of the window: the swap commits but the
    # process dies before tombstoning — simulate by deleting the markers
    comp = Compactor(store, min_records=1001, target_records=4000)
    rep = comp.run_cycle()
    assert rep.merges >= 1
    for d in tmp_path.glob(f"segment-*/{RETIRED_MARKER}"):
        d.unlink()      # crash erased the advisory tombstones
    reloaded2 = SegmentStore.load(tmp_path)
    assert reloaded2.num_records == n_before


def test_manifest_upgrades_legacy_store(tmp_path):
    """A pre-manifest spill tree (RETIRED tombstones only) loads via the
    directory scan and is upgraded: the adopted set becomes its first
    manifest, so the next load is manifest-guarded."""
    w = make_world(tmp_path, num_records=3000, segment_size=1000)
    n = w["store"].num_records
    manifest_path = tmp_path / "manifest.json"
    manifest_path.unlink()          # legacy store: no manifest on disk

    reloaded = SegmentStore.load(tmp_path)
    assert reloaded.num_records == n
    assert manifest_path.exists()   # upgraded
    assert Manifest.read(tmp_path)["segments"]
    # id allocator survives the round trip past the highest on-disk id
    assert reloaded._next_id > max(s.segment_id
                                   for s in reloaded.segments)


# ---------------------------------------------------------------------------
# Leases + epoch fencing
# ---------------------------------------------------------------------------

def test_fencing_rejects_stale_lease_holder(tmp_path):
    """Two workers race one segment: A's lease expires mid-write, B
    acquires (higher epoch) and installs; A's late write is rejected at
    the barrier and the segment keeps B's data."""
    w = make_world(tmp_path, num_records=1500, segment_size=1500)
    seg = w["store"].segments[0]
    now = [0.0]
    lm = LeaseManager(ttl=10.0, clock=lambda: now[0],
                      manifest=w["store"].manifest)

    lease_a = lm.acquire(seg.segment_id, "worker-A")
    assert lease_a is not None
    # B cannot intrude while A's lease stands
    assert lm.acquire(seg.segment_id, "worker-B") is None
    assert lm.holder_of(seg.segment_id) == "worker-A"

    now[0] = 11.0                   # A crashes; its lease expires
    lease_b = lm.acquire(seg.segment_id, "worker-B")
    assert lease_b is not None and lease_b.epoch > lease_a.epoch

    seg.apply_update(meta_updates={"winner": "B"}, fence=lm.fence(lease_b))
    meta_before = seg.meta
    with pytest.raises(FencedWriteError):
        seg.apply_update(meta_updates={"winner": "A"},
                         fence=lm.fence(lease_a))
    assert seg.meta is meta_before          # loser mutated NOTHING
    assert seg.meta["winner"] == "B"

    # fencing epochs are durable: a restarted manager cannot re-issue A's
    lm2 = LeaseManager(ttl=10.0, clock=lambda: now[0],
                       manifest=Manifest_reload(w["store"]))
    lease_c = lm2.acquire(seg.segment_id, "worker-C")
    assert lease_c.epoch > lease_b.epoch


def Manifest_reload(store):
    m = Manifest(store.root)
    m.adopt(Manifest.read(store.root))
    return m


def test_two_workers_racing_one_segment_install_once(tmp_path):
    """Overlapping shards (misconfiguration) on one store: leases serialize
    the writers, fencing rejects any zombie, and the store converges with
    every query correct.  At-least-once, never interleaved."""
    w = make_world(tmp_path)
    activate_late_rule(w)
    q, truth = late_query(w)
    lm = LeaseManager(manifest=w["store"].manifest)
    # BOTH workers own every segment (num_shards=1) — worst case overlap
    workers = [BackfillWorker(w["store"], w["bus"], w["ostore"],
                              worker_id=f"racer-{i}", leases=lm)
               for i in range(2)]
    reps, errs = [], []

    def drain(wk):
        try:
            reps.append(wk.run_until_converged())
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=drain, args=(wk,)) for wk in workers]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    assert sum(r.segments_failed for r in reps) == 0
    # every segment converged (>= once — duplicates are idempotent)
    assert sum(r.segments_backfilled for r in reps) >= len(
        w["store"].segments)
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == truth and r.segments_fallback == 0


def test_shard_of_partitions_and_balances():
    shards = {shard_of(sid, 4) for sid in range(1000)}
    assert shards == {0, 1, 2, 3}
    counts = np.bincount([shard_of(sid, 4) for sid in range(1000)])
    assert counts.min() > 150       # roughly balanced under sequential ids
    assert all(shard_of(s, 1) == 0 for s in range(10))


# ---------------------------------------------------------------------------
# Distributed pool: sharded convergence + per-worker acks
# ---------------------------------------------------------------------------

def test_pool_shards_converge_and_ack(tmp_path):
    w = make_world(tmp_path)
    h = activate_late_rule(w)
    q, truth = late_query(w)
    pool = MaintenanceWorkerPool(w["store"], w["bus"], w["ostore"],
                                 num_workers=3)
    rep = pool.run_until_converged()
    assert rep.segments_backfilled == len(w["store"].segments)
    assert rep.pending_after == 0 and rep.acked
    # the work actually partitioned: every non-empty shard converged by
    # its own worker, each acking independently
    status = w["updater"].await_maintenance(h.version, pool.worker_ids,
                                            timeout=2)
    assert status.complete
    assert set(status.acked) == set(pool.worker_ids)
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == truth and r.segments_fallback == 0


def test_pool_survives_one_worker_crash(tmp_path):
    """A worker that dies after a partial pass neither wedges its shard
    nor loses progress: a replacement pool (fresh lease manager — epochs
    come from the manifest) finishes from the checkpoints."""
    w = make_world(tmp_path)
    activate_late_rule(w)
    q, truth = late_query(w)
    pool = MaintenanceWorkerPool(w["store"], w["bus"], w["ostore"],
                                 num_workers=2, rows_per_pass=600)
    rep1 = pool.run_cycle()
    assert rep1.segments_partial == len(w["store"].segments)

    # the whole pool crashes; a replacement converges from checkpoints
    pool2 = MaintenanceWorkerPool(w["store"], w["bus"], w["ostore"],
                                  num_workers=2)
    rep2 = pool2.run_until_converged()
    assert rep2.segments_backfilled == len(w["store"].segments)
    assert rep2.rows_resumed == 600 * len(w["store"].segments)
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == truth and r.segments_fallback == 0


# ---------------------------------------------------------------------------
# Retention + GC
# ---------------------------------------------------------------------------

def test_retention_expires_marks_and_purges(tmp_path):
    """Event-time TTL: whole segments below the horizon retire atomically,
    straddlers are stamped and physically purged by compaction, and every
    query path agrees afterwards."""
    w = make_world(tmp_path, num_records=6000, segment_size=1500)
    store = w["store"]
    ts_all = np.concatenate([np.asarray(s.column("timestamp"))
                             for s in store.segments])
    # mid-data AND mid-segment, so at least one segment straddles it
    horizon = int(np.sort(ts_all)[len(ts_all) // 2 + len(ts_all) // 8])

    ret = RetentionWorker(store, RetentionPolicy(horizon=horizon))
    rep = ret.run_cycle()
    assert rep.segments_expired >= 1
    assert rep.segments_marked >= 1
    assert rep.rows_tombstoned > 0
    # retired segments are out of the manifest immediately
    reloaded = SegmentStore.load(tmp_path)
    assert len(reloaded.segments) == len(store.segments)

    crep = Compactor(store).run_cycle()
    assert crep.rows_purged == rep.rows_tombstoned
    surviving = np.concatenate([np.asarray(s.column("timestamp"))
                                for s in store.segments])
    assert (surviving >= horizon).all()
    assert len(surviving) == int((ts_all >= horizon).sum())

    # a second retention pass is a no-op (idempotent at the same horizon)
    rep2 = RetentionWorker(store,
                           RetentionPolicy(horizon=horizon)).run_cycle()
    assert rep2.segments_expired == 0 and rep2.rows_tombstoned == 0


def test_retention_watermark_horizon(tmp_path):
    """max_age retention is anchored to the newest sealed timestamp (event
    time), so a stalled ingest never silently expires the whole store."""
    w = make_world(tmp_path, num_records=3000, segment_size=1000)
    store = w["store"]
    newest = max(s.meta["ts_max"] for s in store.segments)
    ret = RetentionWorker(store, RetentionPolicy(max_age=10**18))
    assert ret.horizon() == newest - 10**18
    assert ret.run_cycle().segments_expired == 0    # nothing that old


def test_spill_gc_respects_pins_and_grace(tmp_path):
    """GC deletes a RETIRED dir only after (1) the manifest dropped it,
    (2) no leased arrangement pins it, (3) the grace window passed."""
    w = make_world(tmp_path, num_records=3000, segment_size=1000)
    store = w["store"]
    victim = store.segments[0]
    arr = w["engine"].arrangements

    # pin the victim through a live arrangement lease (an in-flight query)
    from repro.core.query.arrangement import ArrangementItem
    item = ArrangementItem(token=victim.meta_token(),
                           num_records=victim.num_records,
                           load=lambda: np.asarray(
                               victim.column("rule_bitmap")))
    lease = arr.lease([item], (0,), owner="pinning-query")
    assert victim.segment_id in arr.pinned_segment_ids()

    assert store.retire_segments([victim])
    assert victim.path.joinpath(RETIRED_MARKER).exists()

    now = [1000.0]
    gc = SpillGC(store, arrangements=arr, grace_s=30.0,
                 clock=lambda: now[0])
    rep = gc.run_cycle()
    assert rep.dirs_deleted == 0 and rep.dirs_kept_pinned == 1
    assert victim.path.exists()

    lease.release()                 # reader drains; pin lifts
    assert victim.segment_id not in arr.pinned_segment_ids()
    # ...but the tombstone is fresh relative to the fake clock? the marker
    # mtime is real wall time, so push the fake clock far past it
    now[0] = victim.path.joinpath(RETIRED_MARKER).stat().st_mtime + 31.0
    rep2 = gc.run_cycle()
    assert rep2.dirs_deleted == 1
    assert not victim.path.exists()
    # the store (and a reload) never miss a beat
    assert SegmentStore.load(tmp_path).num_records == store.num_records


def test_gc_keeps_fresh_tombstones(tmp_path):
    w = make_world(tmp_path, num_records=2000, segment_size=1000)
    store = w["store"]
    victim = store.segments[0]
    assert store.retire_segments([victim])
    gc = SpillGC(store, grace_s=3600.0)     # real clock, huge grace
    rep = gc.run_cycle()
    assert rep.dirs_deleted == 0 and rep.dirs_kept_grace == 1
    assert victim.path.exists()


def test_membership_commits_are_fenced(tmp_path):
    """replace_segments / retire_segments run the caller's fence INSIDE
    the store lock before committing: a compactor or retention writer
    whose leases were superseded mid-operation commits NOTHING."""
    w = make_world(tmp_path, num_records=3000, segment_size=1000)
    store = w["store"]
    n = store.num_records
    segs_before = list(store.segments)

    def tripped():
        raise FencedWriteError("superseded mid-merge")

    group = store.segments[:2]
    cols = {name: np.concatenate([np.asarray(s.column(name))
                                  for s in group])
            for name in sorted(group[0].meta["columns"])}
    merged = store.make_segment_from_batch(RecordBatch(cols))
    with pytest.raises(FencedWriteError):
        store.replace_segments(group, merged, fence=tripped)
    with pytest.raises(FencedWriteError):
        store.retire_segments([store.segments[0]], fence=tripped)
    assert store.segments == segs_before          # nothing committed
    assert SegmentStore.load(tmp_path).num_records == n


def test_epoch_block_reservation_survives_restart(tmp_path):
    """Epoch reservations amortize manifest writes (one per block, not per
    acquire) while a restarted manager still always resumes ABOVE every
    epoch ever issued."""
    w = make_world(tmp_path, num_records=1500, segment_size=1500)
    store = w["store"]
    sid = store.segments[0].segment_id
    lm = LeaseManager(manifest=store.manifest, epoch_block=16)
    epochs = []
    for _ in range(5):      # same holder re-acquires: 5 epochs, ONE write
        lease = lm.acquire(sid, "w")
        epochs.append(lease.epoch)
        lm.release(lease)
    assert epochs == [1, 2, 3, 4, 5]
    assert Manifest.read(tmp_path)["fences"][str(sid)] == 16  # the block

    lm2 = LeaseManager(manifest=Manifest_reload(store), epoch_block=16)
    lease = lm2.acquire(sid, "w2")
    assert lease.epoch > max(epochs)      # resumes above the bound


# ---------------------------------------------------------------------------
# Compactor under leases
# ---------------------------------------------------------------------------

def test_compactor_skips_leased_group(tmp_path):
    w = make_world(tmp_path, num_records=4000, segment_size=1000)
    store = w["store"]
    lm = LeaseManager(manifest=store.manifest)
    held = lm.acquire(store.segments[1].segment_id, "backfill-elsewhere")
    assert held is not None
    comp = Compactor(store, min_records=1001, target_records=4000,
                     leases=lm)
    rep = comp.run_cycle()
    assert rep.merges == 0 and rep.merges_contended >= 1
    lm.release(held)
    rep2 = comp.run_cycle()
    assert rep2.merges >= 1
