"""Fused multi-field dispatch: byte-identical equivalence with the per-field
loop across every backend, single-D2H accounting on the enrich path, and
jit-retrace stability across ragged/tail batch sizes."""
import numpy as np
import pytest

from repro.core import matcher as matcher_mod
from repro.core.automaton import compile_rules, match_oracle
from repro.core.matcher import EngineBundle, FusedMatcher, compile_bundle
from repro.core.patterns import Rule, RuleSet
from repro.core.records import RecordBatch, encode_texts
from repro.core.stream_processor import ENRICH_COLUMN, StreamProcessor
from repro.kernels.dfa_scan import ops as dfa_ops

FIELDS = ("content1", "content2", "content3")
WORDS = ["ERROR", "fatal", "panic", "usr3", "quiet", "calm", "zz",
         "needleA", "needleB", "overlapAB", "xyzzy"]


def random_ruleset(rng, num_rules: int) -> RuleSet:
    """Literal-only rules (<= 32 B, so shift_or qualifies), a mix of
    field-scoped and '*' rules, some shared across fields so single records
    can match in multiple fields."""
    rules = []
    for i in range(num_rules):
        term = rng.choice(WORDS)
        fields = ("*",) if rng.random() < 0.4 else \
            (FIELDS[rng.integers(0, len(FIELDS))],)
        rules.append(Rule(i, f"r{i}", str(term), fields=fields))
    return RuleSet(tuple(rules))


def random_batch(rng, n: int, width: int = 64) -> RecordBatch:
    cols = {"timestamp": np.arange(n, dtype=np.int64)}
    for f in FIELDS:
        texts = [" ".join(rng.choice(WORDS, size=rng.integers(1, 6)))
                 for _ in range(n)]
        cols[f] = encode_texts(texts, width)
    return RecordBatch(cols)


def oracle_bitmap(bundle: EngineBundle, batch: RecordBatch) -> np.ndarray:
    """Ground truth: numpy per-field loop over the compiled automata."""
    bm = np.zeros((len(batch), bundle.words), np.uint32)
    for fieldname in bundle.fields:
        eng = bundle.engines[fieldname]
        cols = batch.text_fields if fieldname == "*" else \
            ((fieldname,) if fieldname in batch.text_fields else ())
        for c in cols:
            bm |= match_oracle(eng, batch.columns[c])
    return bm


@pytest.mark.parametrize("backend",
                         ["dfa", "dfa_ref", "dfa_selective", "shift_or"])
@pytest.mark.parametrize("seed", [0, 1])
def test_backend_equivalence_randomized(backend, seed):
    """Every backend — fused (dfa/dfa_ref) or per-field fallback — produces
    byte-identical bitmaps on randomized rulesets, including ragged tail
    batch sizes and the empty batch."""
    rng = np.random.default_rng(seed)
    ruleset = random_ruleset(rng, num_rules=24)
    bundle = compile_bundle(ruleset, FIELDS)
    proc = StreamProcessor(bundle, backend=backend, block_n=8)
    for n in (0, 1, 5, 37):
        batch = random_batch(rng, n)
        got = np.asarray(proc.process(batch).columns[ENRICH_COLUMN])
        want = oracle_bitmap(bundle, batch)
        np.testing.assert_array_equal(got, want, err_msg=f"{backend} n={n}")


@pytest.mark.parametrize("backend", ["dfa", "dfa_ref"])
def test_fused_matches_per_field_loop(backend):
    """The fused dispatcher's OR-of-fields equals the per-field
    MatchEngine.match loop bit for bit."""
    rng = np.random.default_rng(2)
    ruleset = random_ruleset(rng, num_rules=16)
    bundle = compile_bundle(ruleset, FIELDS)
    batch = random_batch(rng, 21)
    fused = FusedMatcher(bundle, backend=backend, block_n=8)
    bm, mask = fused.match_batch(batch.columns, batch.text_fields,
                                 len(batch)).to_host()
    want = oracle_bitmap(bundle, batch)
    np.testing.assert_array_equal(bm, want)
    np.testing.assert_array_equal(mask, want.any(axis=1))


def test_fused_parallel_backend():
    """The associative-scan backend fuses too (small-automaton bundles)."""
    rs = RuleSet((Rule(0, "a", "ab", fields=("content1",)),
                  Rule(1, "b", "ba", fields=("*",))))
    engines = {f: compile_rules(rs, f, bucket=256)
               for f in ("content1", "content2")}
    bundle = EngineBundle(version=rs.version_hash(), num_rules=rs.num_rules,
                          engines=engines, ruleset_json=rs.to_json())
    batch = RecordBatch({
        "content1": encode_texts(["abba", "zz", "xbax"], 16),
        "content2": encode_texts(["zz", "ab", "zz"], 16),
    })
    fused = FusedMatcher(bundle, backend="parallel", block_n=8)
    bm, _ = fused.match_batch(batch.columns, batch.text_fields,
                              len(batch)).to_host()
    np.testing.assert_array_equal(bm, oracle_bitmap(bundle, batch))


@pytest.mark.parametrize("backend", ["dfa", "dfa_ref"])
def test_shared_star_engine_deduped(backend):
    """A '*' engine matched against every text column is stored ONCE in the
    fused plan (eng_idx maps all slots to one table row) and still yields
    oracle-identical bitmaps."""
    rng = np.random.default_rng(5)
    rs = RuleSet((Rule(0, "e", "ERROR", fields=("*",)),
                  Rule(1, "p", "panic", fields=("*",))))
    bundle = compile_bundle(rs, ("*",))
    batch = random_batch(rng, 19)
    fused = FusedMatcher(bundle, backend=backend, block_n=8)
    bm, _ = fused.match_batch(batch.columns, batch.text_fields,
                              len(batch)).to_host()
    plan = fused._plan(batch.text_fields)
    if backend == "dfa":
        # pallas can't take the slot->row indirection in its index maps:
        # tables are expanded once at plan build, eng_idx is identity
        assert plan.eng_idx == tuple(range(len(FIELDS)))
        assert plan.deltas.shape[0] == len(FIELDS)
    else:
        assert plan.eng_idx == (0,) * len(FIELDS)  # one table, three slots
        assert plan.deltas.shape[0] == 1
    np.testing.assert_array_equal(bm, oracle_bitmap(bundle, batch))


def test_multi_field_matches_merge():
    """A record matching different rules in different fields carries the OR
    of all of them."""
    rs = RuleSet((Rule(0, "e", "ERROR", fields=("content1",)),
                  Rule(1, "u", "usr3", fields=("content2",)),
                  Rule(2, "any", "panic", fields=("*",))))
    bundle = compile_bundle(rs, ("content1", "content2"))
    batch = RecordBatch({
        "content1": encode_texts(["ERROR panic", "calm"], 32),
        "content2": encode_texts(["usr3 here", "panic"], 32),
    })
    proc = StreamProcessor(bundle, backend="dfa_ref")
    bm = np.asarray(proc.process(batch).columns[ENRICH_COLUMN])
    assert bm[0, 0] == 0b111          # rules 0, 1, 2 all set on record 0
    assert bm[1, 0] == 0b100          # panic via content2 '*' on record 1


@pytest.mark.parametrize("backend", ["dfa", "dfa_ref"])
def test_single_d2h_transfer_per_batch(backend):
    """The enrich path performs exactly ONE device-to-host transfer per
    processed batch: the counted MatchResult.to_host hook fires once, and
    jax's transfer guard proves no other (implicit) D2H sneaks in."""
    import jax
    rng = np.random.default_rng(3)
    bundle = compile_bundle(random_ruleset(rng, 8), FIELDS)
    proc = StreamProcessor(bundle, backend=backend, block_n=8)
    proc.process(random_batch(rng, 16))            # warmup/compile
    before = matcher_mod.transfer_count()
    with jax.transfer_guard_device_to_host("disallow"):
        # only the explicit jax.device_get inside to_host is permitted;
        # any np.asarray-style implicit transfer raises here
        for _ in range(4):
            proc.process(random_batch(rng, 16))
    assert matcher_mod.transfer_count() - before == 4


def test_no_retrace_across_batch_sizes():
    """After warming the N shape buckets, varying batch sizes (tail batches
    included) must not trigger new jit traces."""
    rng = np.random.default_rng(4)
    bundle = compile_bundle(random_ruleset(rng, 8), FIELDS)
    proc = StreamProcessor(bundle, backend="dfa_ref", block_n=8)
    for n in (8, 16, 32, 64):                      # warm buckets 8..64
        proc.process(random_batch(rng, n))
    before = dict(dfa_ops.TRACE_COUNTS)
    for n in (3, 7, 12, 33, 64, 20, 5, 48):       # all land in warm buckets
        proc.process(random_batch(rng, n))
    assert dict(dfa_ops.TRACE_COUNTS) == before


def test_bucket_n():
    assert dfa_ops.bucket_n(0, 256) == 256
    assert dfa_ops.bucket_n(1, 256) == 256
    assert dfa_ops.bucket_n(256, 256) == 256
    assert dfa_ops.bucket_n(257, 256) == 512
    assert dfa_ops.bucket_n(4096, 256) == 4096
    assert dfa_ops.bucket_n(4097, 256) == 8192
    assert dfa_ops.bucket_n(100, 8) == 128
    # non-power-of-two block_n still yields block-aligned buckets
    assert dfa_ops.bucket_n(25, 24) % 24 == 0
