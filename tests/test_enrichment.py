import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core import enrichment as E


@given(st.integers(1, 200), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_bool_round_trip(num_rules, seed):
    rng = np.random.default_rng(seed)
    W = E.words_for_rules(num_rules)
    bm = rng.integers(0, 2**32, size=(7, W), dtype=np.uint32)
    # mask out bits beyond num_rules so round trip is exact
    cols = E.to_bool_columns(bm, num_rules)
    bm2 = E.from_bool_columns(cols)
    np.testing.assert_array_equal(E.to_bool_columns(bm2, num_rules), cols)


@given(st.integers(1, 100), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_sparse_round_trip(num_rules, seed):
    rng = np.random.default_rng(seed)
    W = E.words_for_rules(num_rules)
    cols = rng.random((9, num_rules)) < 0.05
    bm = E.from_bool_columns(cols)
    ids = E.to_sparse_ids(bm, max_matches=num_rules)
    bm2 = E.from_sparse_ids(ids, num_rules)
    np.testing.assert_array_equal(bm, bm2)


def test_rule_mask():
    m = E.rule_mask([0, 33], 64)
    assert m[0] == 1 and m[1] == 2
    with pytest.raises(ValueError):
        E.rule_mask([64], 64)


def test_bitmap_get_and_popcount():
    bm = E.from_bool_columns(np.asarray([[1, 0, 1], [0, 0, 0]], bool))
    assert E.bitmap_get(bm, 0).tolist() == [True, False]
    assert E.bitmap_get(bm, 2).tolist() == [True, False]
    assert E.popcount(bm).tolist() == [2, 0]
    assert E.any_match(bm).tolist() == [True, False]


def test_storage_nbytes_ordering():
    """Sparse < bitmap < bools under high selectivity (paper's rationale)."""
    cols = np.zeros((1000, 1000), bool)
    cols[::200, 3] = True
    bm = E.from_bool_columns(cols)
    s = E.storage_nbytes(bm, "sparse", 1000)
    b = E.storage_nbytes(bm, "bitmap", 1000)
    f = E.storage_nbytes(bm, "bools", 1000)
    assert s < b < f
