"""Serving front-end battery (docs/SERVING.md): the framed wire protocol
answers bit-identically to direct ``QueryEngine`` calls, malformed frames
never crash or wedge the server (every rejection lands in telemetry), the
shedding ladder returns the documented statuses (404/429/503/504/500), a
chaos soak under injected handler + shard faults leaves every client with
a well-formed response and the inflight gauge at zero, and the model
plane (``repro.serve.engine``) stays importable beside the front end."""
import json
import os
import random
import socket
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import faults, telemetry
from repro.core.matcher import compile_bundle
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.store import SegmentStore
from repro.core.stream_processor import StreamProcessor
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.serve import frontend as fr
from repro.serve.frontend import (FrontEnd, ProtocolError, ServeClient,
                                  http_get, recv_frame, result_payload,
                                  send_frame)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # optional dev dep; see pyproject
    HAVE_HYPOTHESIS = False


@pytest.fixture(autouse=True)
def _fault_isolation():
    """Fresh fault state per test; the CI chaos-leg env profile (if any)
    is re-armed before each test so its fire budget resets — every
    front-end test must absorb `serve.accept`/`serve.handle` injections
    without wedging a connection or leaking an inflight slot."""
    faults.reset()
    if os.environ.get(faults.ENV_VAR):
        faults.load_profile(os.environ[faults.ENV_VAR])
    yield
    faults.reset()
    if os.environ.get(faults.ENV_VAR):
        faults.load_profile(os.environ[faults.ENV_VAR])


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    """Small enriched store + engine (module-scoped: the battery hits one
    corpus through many front ends)."""
    spec = WorkloadSpec(num_records=3000, ultra_rate=1e-3, high_rate=1e-2,
                        seed=11, text_width=128)
    gen = LogGenerator(spec)
    rules = RuleSet(tuple(Rule(i, t.term, t.term, fields=(t.fieldname,))
                          for i, t in enumerate(spec.planted)))
    proc = StreamProcessor(compile_bundle(rules, spec.content_fields),
                           backend="dfa_ref")
    store = SegmentStore(segment_size=800,
                         root=tmp_path_factory.mktemp("serve-store"),
                         index_fields=spec.content_fields)
    from repro.data.pipeline import IngestPipeline
    IngestPipeline(gen, store, proc).run(batch_size=1000)
    engine = QueryEngine(store, mapper=QueryMapper(rules))

    def ingest_sink(batch):
        store.append(proc.process(batch))
        return len(batch)

    w = {"spec": spec, "engine": engine, "terms":
         [(t.fieldname, t.term) for t in spec.planted],
         "ingest": ingest_sink}
    yield w
    engine.close()


def make_fe(world, **kw):
    kw.setdefault("rate_per_client", 1e9)
    kw.setdefault("ingest", world["ingest"])
    return FrontEnd(world["engine"], **kw).start()


def raw_conn(fe):
    return socket.create_connection(fe.address, timeout=5.0)


def server_alive(fe):
    """The liveness probe every malformed-input test ends with: a fresh
    connection still gets a well-formed pong."""
    with ServeClient(*fe.address) as c:
        return c.request("ping").get("pong") is True


# -- e2e: wire responses are bit-identical to direct engine calls ------------
def test_roundtrip_matches_direct_engine(world):
    with make_fe(world) as fe, ServeClient(*fe.address, client_id="t") as c:
        for f, term in world["terms"][:3]:
            for mode in ("count", "ids", "copy"):
                emode = "count" if mode == "count" else "copy"
                direct = result_payload(
                    world["engine"].execute(
                        Query(terms=((f, term),), mode=emode)), mode)
                resp = c.query([(f, term)], mode=mode)
                assert resp["status"] == 200
                for key in ("count", "ids", "columns", "partial",
                            "coverage"):
                    if key in direct:
                        assert resp[key] == direct[key], (f, term, mode, key)


def test_ping_and_id_echo(world):
    with make_fe(world) as fe, ServeClient(*fe.address) as c:
        r1, r2 = c.request("ping"), c.request("ping")
        assert (r1["pong"], r2["pong"]) == (True, True)
        assert r2["id"] == r1["id"] + 1   # echoed per-request id


def test_standing_register_and_refresh(world):
    f, term = world["terms"][0]
    with make_fe(world) as fe, ServeClient(*fe.address) as c:
        reg = c.request("standing.register", terms=[[f, term]],
                        mode="count", name="wire-view")
        assert (reg["status"], reg["name"]) == (200, "wire-view")
        ref = c.request("standing.refresh", name="wire-view")
        direct = world["engine"].execute(
            Query(terms=((f, term),), mode="count"))
        assert (ref["status"], ref["count"]) == (200, direct.count)
        missing = c.request("standing.refresh", name="nope")
        assert missing["status"] == 400


def test_ingest_route_appends(world):
    with make_fe(world) as fe, ServeClient(*fe.address) as c:
        r = c.request("ingest", records=[
            {"timestamp": 10**9, "content1": "wire ERROR probe"},
            {"timestamp": 10**9 + 1, "content1": "quiet"}])
        assert (r["status"], r["appended"]) == (200, 2)
        bad = c.request("ingest", records="not-a-list")
        assert bad["status"] == 400


# -- protocol fuzz: malformed frames never crash or wedge the server ---------
def _bad_frame_counter():
    return fr._rejection("unknown", "bad_frame")


def test_truncated_length_prefix(world):
    with make_fe(world) as fe:
        with raw_conn(fe) as s:
            s.sendall(b"\x00\x00")           # half a length prefix, then EOF
        assert server_alive(fe)


def test_oversized_length_rejected_and_closed(world):
    with make_fe(world) as fe:
        before = _bad_frame_counter().value
        with raw_conn(fe) as s:
            s.sendall(struct.pack(">I", 0x7FFFFFFF))
            resp = recv_frame(s)             # server answers before closing
            assert resp["status"] == 400
            assert s.recv(1) == b""          # then closes: framing is gone
        assert _bad_frame_counter().value == before + 1
        assert server_alive(fe)


def test_zero_length_frame_rejected(world):
    with make_fe(world) as fe:
        with raw_conn(fe) as s:
            s.sendall(struct.pack(">I", 0))
            assert recv_frame(s)["status"] == 400
        assert server_alive(fe)


def test_invalid_json_is_recoverable(world):
    """An intact frame with a garbage payload gets a 400 and the SAME
    connection keeps working (the framing is still trustworthy)."""
    with make_fe(world) as fe:
        before = _bad_frame_counter().value
        with raw_conn(fe) as s:
            payload = b"{not json!!"
            s.sendall(struct.pack(">I", len(payload)) + payload)
            assert recv_frame(s)["status"] == 400
            send_frame(s, {"route": "ping"})
            assert recv_frame(s)["pong"] is True
        assert _bad_frame_counter().value == before + 1


def test_non_object_json_is_recoverable(world):
    with make_fe(world) as fe:
        with raw_conn(fe) as s:
            body = json.dumps([1, 2, 3]).encode()
            s.sendall(struct.pack(">I", len(body)) + body)
            assert recv_frame(s)["status"] == 400
            send_frame(s, {"route": "ping"})
            assert recv_frame(s)["pong"] is True


def test_mid_request_disconnect(world):
    with make_fe(world) as fe:
        with raw_conn(fe) as s:
            s.sendall(struct.pack(">I", 500) + b"x" * 120)  # then vanish
        assert server_alive(fe)


def test_unknown_route_404_counted(world):
    with make_fe(world) as fe:
        before = fr._rejection("unknown", "bad_route").value
        with ServeClient(*fe.address) as c:
            assert c.request("no.such.route")["status"] == 404
            assert c.request("query2")["status"] == 404
        assert fr._rejection("unknown", "bad_route").value == before + 2
        assert server_alive(fe)


def test_bad_query_terms_400(world):
    with make_fe(world) as fe, ServeClient(*fe.address) as c:
        assert c.request("query", terms=[])["status"] == 400
        assert c.request("query", terms=[["only-one"]])["status"] == 400
        assert c.request("query", terms=[[1, 2]])["status"] == 400
        assert c.request("query", terms=[["content1", "x"]],
                         mode="teleport")["status"] == 400


def test_garbage_flood_never_wedges(world):
    """Deterministic fuzz: random byte blobs on fresh connections — every
    one is rejected or ignored, the listener survives all of them, and
    each parseable-but-bad frame is counted."""
    rng = random.Random(1234)
    with make_fe(world) as fe:
        before = _bad_frame_counter().value
        for i in range(40):
            blob = bytes(rng.randrange(256)
                         for _ in range(rng.randrange(1, 200)))
            with raw_conn(fe) as s:
                s.sendall(blob)
                if rng.random() < 0.5:       # half linger for the reply
                    try:
                        s.settimeout(2.0)
                        s.recv(64)
                    except OSError:
                        pass
        assert server_alive(fe)
        assert _bad_frame_counter().value >= before  # only ever grows


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st.binary(min_size=0, max_size=64))
    def test_hyp_recv_frame_total(blob):
        """recv_frame on arbitrary bytes: parses, raises ProtocolError,
        or reports EOF — never anything else (run against a socketpair,
        no server needed)."""
        a, b = socket.socketpair()
        try:
            a.sendall(blob)
            a.close()
            b.settimeout(2.0)
            try:
                out = recv_frame(b, max_bytes=1 << 16)
                assert out is None or isinstance(out, dict)
            except ProtocolError:
                pass
        finally:
            b.close()


# -- shedding ladder ---------------------------------------------------------
def test_admission_429(world):
    with make_fe(world, rate_per_client=0.001, burst=1.0) as fe:
        before = fr._rejection("query", "admission").value
        with ServeClient(*fe.address, client_id="limited") as c:
            ok = c.query([world["terms"][0]])
            limited = c.query([world["terms"][0]])
        assert ok["status"] == 200
        assert (limited["status"], limited["reason"]) == (429, "admission")
        assert fr._rejection("query", "admission").value == before + 1


def test_queue_full_503(world):
    """max_queue=0 with the only slot stalled: the next deadline-bearing
    request is shed immediately as queue_full, not parked."""
    faults.inject("serve.handle", "stall", delay=0.8, times=1)
    with make_fe(world, max_inflight=1, max_queue=0) as fe:
        t = threading.Thread(
            target=lambda: ServeClient(*fe.address).query(
                [world["terms"][0]]), daemon=True)
        t.start()
        time.sleep(0.2)                      # let it occupy the slot
        before = fr._shed_counter("query", "queue_full").value
        with ServeClient(*fe.address) as c:
            r = c.query([world["terms"][0]], deadline_ms=100)
        assert (r["status"], r["reason"]) == (503, "queue_full")
        assert fr._shed_counter("query", "queue_full").value == before + 1
        t.join(timeout=5)
        assert not t.is_alive()


def test_deadline_504(world):
    """With queue room, a waiter whose deadline expires before a slot
    frees is shed with 504."""
    faults.inject("serve.handle", "stall", delay=0.8, times=1)
    with make_fe(world, max_inflight=1, max_queue=4) as fe:
        t = threading.Thread(
            target=lambda: ServeClient(*fe.address).query(
                [world["terms"][0]]), daemon=True)
        t.start()
        time.sleep(0.2)
        with ServeClient(*fe.address) as c:
            t0 = time.monotonic()
            r = c.query([world["terms"][0]], deadline_ms=100)
            waited = time.monotonic() - t0
        assert (r["status"], r["reason"]) == (504, "deadline")
        assert waited < 0.7                  # shed at the deadline, not after
        t.join(timeout=5)
        assert not t.is_alive()


def test_handler_fault_is_500_and_slot_freed(world):
    faults.inject("serve.handle", "error", times=1)
    with make_fe(world, max_inflight=1) as fe:
        with ServeClient(*fe.address) as c:
            r = c.query([world["terms"][0]])
            assert r["status"] == 500
            assert c.query([world["terms"][0]])["status"] == 200  # slot free
        assert fr._INFLIGHT.value == 0


def test_accept_fault_drops_conn_listener_survives(world):
    faults.inject("serve.accept", "error", times=1)
    with make_fe(world) as fe:
        with raw_conn(fe) as s:              # this one is dropped at accept
            s.settimeout(2.0)
            try:
                send_frame(s, {"route": "ping"})
                assert recv_frame(s) is None  # EOF: closed without service
            except OSError:
                pass                          # reset also acceptable
        assert server_alive(fe)               # listener took no damage


# -- chaos soak --------------------------------------------------------------
def test_chaos_soak_all_clients_answered(world):
    """8 concurrent clients under injected handler + shard faults: every
    request gets a well-formed framed response (200 with honest partial
    coverage, or a clean 500), no client hangs past its deadline, and the
    inflight gauge drains to exactly zero."""
    faults.inject("serve.handle", "error", prob=0.2, seed=21)
    faults.inject("query.shard", "error", prob=0.2, seed=22)
    with make_fe(world, max_inflight=4, max_queue=16) as fe:
        outs = [[] for _ in range(8)]

        def client(i, out):
            with ServeClient(*fe.address, client_id=f"chaos-{i}") as c:
                for j in range(15):
                    terms = [world["terms"][j % len(world["terms"])]]
                    out.append(c.query(terms, mode="count",
                                       deadline_ms=5000))

        threads = [threading.Thread(target=client, args=(i, outs[i]),
                                    daemon=True) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)               # no hang past deadline
            assert not t.is_alive()
        flat = [r for o in outs for r in o]
        assert len(flat) == 8 * 15           # every request answered
        statuses = {r["status"] for r in flat}
        assert statuses <= {200, 500, 504}, statuses
        assert any(r["status"] == 500 for r in flat)  # faults really fired
        for r in flat:                       # well-formed: echoed id, and
            assert "id" in r                 # 200s carry honest coverage
            if r["status"] == 200:
                assert "partial" in r and "coverage" in r
        assert fr._INFLIGHT.value == 0
        assert fr._QUEUED.value == 0
    deadline = time.monotonic() + 5          # conn threads unwind on close
    while fr._CONNS.value != 0 and time.monotonic() < deadline:
        time.sleep(0.05)
    assert fr._CONNS.value == 0


# -- HTTP plane --------------------------------------------------------------
def test_metrics_and_healthz(world):
    with make_fe(world) as fe:
        with ServeClient(*fe.address) as c:
            c.query([world["terms"][0]])
        status, body = http_get(*fe.address, "/metrics")
        assert status == 200
        text = body.decode()
        for series in ("fluxsieve_serve_requests_total",
                       "fluxsieve_serve_inflight",
                       "fluxsieve_serve_latency_seconds"):
            assert series in text, series
        status, body = http_get(*fe.address, "/healthz")
        health = json.loads(body)
        assert (status, health["status"]) == (200, "ok")
        assert health["inflight"] == 0
        status, _ = http_get(*fe.address, "/nope")
        assert status == 404
        assert server_alive(fe)              # HTTP and frames coexist


# -- the serve/ package hosts two planes -------------------------------------
def test_frontend_import_skips_model_plane():
    """Importing the query front end must not drag in the model plane
    (ServeEngine + the model zoo) — the PEP-562 split in
    repro/serve/__init__.py.  (jax itself still loads via the core
    matcher kernels; the split isolates the PLANES, not the framework.)"""
    code = ("import sys; import repro.serve.frontend; "
            "from repro.serve import FrontEnd, ServeClient; "
            "bad = [m for m in sys.modules if m.startswith("
            "('repro.serve.engine', 'repro.serve.serve_step', "
            "'repro.serve.kv_cache', 'repro.models'))]; "
            "assert not bad, bad; print('clean')")
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr
    assert "clean" in out.stdout


def test_both_planes_listed_side_by_side():
    import repro.serve as pkg
    names = dir(pkg)
    assert {"ServeEngine", "Request", "init_caches"} <= set(names)
    assert {"FrontEnd", "ServeClient", "TokenBucket"} <= set(names)
    from repro.serve import FrontEnd as FE   # lazy resolution works
    assert FE is FrontEnd
    with pytest.raises(AttributeError):
        pkg.not_a_plane
