import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.model import Model
from repro.train import checkpoint as ckpt
from repro.train.compression import compressed_psum, zeros_like_err
from repro.train.fault_tolerance import (RestartManager, StragglerMonitor,
                                         largest_mesh_shape)
from repro.train.optimizer import (OptimizerConfig, adamw_init, adamw_update,
                                   global_norm, lr_at)
from repro.train.train_step import TrainStepConfig, build_train_step, init_state


@pytest.fixture(scope="module")
def tiny():
    model = Model.from_name("phi3-mini-3.8b", reduced=True)
    ts = TrainStepConfig(optimizer=OptimizerConfig(
        lr=1e-3, warmup_steps=2, total_steps=50))
    state = init_state(model, jax.random.key(0), ts)
    return model, ts, state


def _batch(B=4, S=32, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(3, 500, (B, S)).astype(np.int32)
    return {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}


def test_lr_schedule():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0, abs=1e-6)
    assert float(lr_at(cfg, 100)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr_at(cfg, 55)) == pytest.approx(0.55, abs=0.01)


def test_adamw_moves_params():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    st = adamw_init(params)
    grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=10)
    p2, st2, m = adamw_update(cfg, grads, st, params)
    assert float(jnp.abs(p2["w"] - params["w"]).max()) > 0
    assert int(st2["count"]) == 1
    assert float(m["grad_norm"]) == pytest.approx(
        float(global_norm(grads)), rel=1e-5)


def test_loss_decreases(tiny):
    model, ts, state0 = tiny
    step = build_train_step(model, ts, donate=False)
    state = state0
    batch = _batch()
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_microbatch_equivalence():
    """Grad accumulation over 4 microbatches == single big batch (f32
    activations so the comparison is not dominated by bf16 noise)."""
    import dataclasses
    cfg = Model.from_name("phi3-mini-3.8b", reduced=True).cfg
    model = Model(dataclasses.replace(cfg, dtype="float32"))
    batch = _batch(B=8, S=16)
    outs = {}
    for n in (1, 4):
        ts = TrainStepConfig(microbatches=n, optimizer=OptimizerConfig(
            lr=1e-2, warmup_steps=0, total_steps=10))
        state = init_state(model, jax.random.key(0), ts)
        step = build_train_step(model, ts, donate=False)
        new_state, m = step(state, batch)
        outs[n] = (new_state["params"], float(m["grad_norm"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)
    # Adam normalizes by sqrt(v), amplifying float-associativity noise where
    # v ~ 0 — require near-exact agreement for 99.99% of elements and bound
    # the stragglers by one optimizer step (lr).
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        a, b = np.asarray(a), np.asarray(b)
        close = np.isclose(a, b, rtol=1e-3, atol=1e-5)
        assert close.mean() > 0.9999, close.mean()
        np.testing.assert_allclose(a, b, atol=2.5e-2)  # <= one lr step


def test_remat_equivalence():
    model = Model.from_name("phi3-mini-3.8b", reduced=True)
    batch = _batch(B=2, S=16)
    outs = {}
    for remat in (True, False):
        ts = TrainStepConfig(remat=remat, optimizer=OptimizerConfig(
            lr=1e-2, warmup_steps=0, total_steps=10))
        state = init_state(model, jax.random.key(0), ts)
        step = build_train_step(model, ts, donate=False)
        _, metrics = step(state, batch)
        outs[remat] = float(metrics["loss"])
    assert outs[True] == pytest.approx(outs[False], rel=1e-5)


def test_compression_error_feedback():
    """int8 psum with error feedback: quantization residual is carried, so
    the running sum converges to the true sum (bias-free)."""
    mesh = jax.make_mesh((1,), ("pod",), devices=jax.devices()[:1])
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                          jnp.float32) * 1e-3}
    err = zeros_like_err(g)
    from functools import partial
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()),
             out_specs=(P(), P()), axis_names={"pod"}, check_vma=False)
    def run(gg, ee):
        return compressed_psum(gg, "pod", ee)

    total = jnp.zeros_like(g["w"])
    acc_true = jnp.zeros_like(g["w"])
    for i in range(20):
        out, err = run(g, err)
        total = total + out["w"]
        acc_true = acc_true + g["w"]
    # cumulative compressed sum tracks the true sum within quantization noise
    denom = float(jnp.abs(acc_true).max())
    rel = float(jnp.abs(total - acc_true).max()) / denom
    assert rel < 0.01, rel


def test_checkpoint_async_and_prune(tmp_path, tiny):
    model, ts, state = tiny
    saver = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3):
        saver.save(s, state, {"s": s})
    saver.wait()
    assert ckpt.list_steps(tmp_path) == [2, 3]
    restored, meta = ckpt.restore_checkpoint(tmp_path, 3, state)
    assert meta["s"] == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_manager_survives_failures(tmp_path, tiny):
    model, ts, state0 = tiny
    step_fn_inner = build_train_step(model, ts, donate=False)
    batch = _batch()
    fail_at = {7}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)                # fail once, then succeed
            raise RuntimeError("injected node failure")
        state, _ = step_fn_inner(state, batch)
        return state

    mgr = RestartManager(tmp_path, save_every=2, max_restarts=2)
    final, report = mgr.run(state0, step_fn, num_steps=10)
    assert report.final_step == 10
    assert report.restarts == 1
    assert int(final["step"]) == 10
    assert len(report.failures) == 1


def test_restart_manager_gives_up(tmp_path, tiny):
    model, ts, state0 = tiny

    def always_fail(state, step):
        raise RuntimeError("dead node")

    mgr = RestartManager(tmp_path, save_every=2, max_restarts=1)
    with pytest.raises(RuntimeError):
        mgr.run(state0, always_fail, num_steps=5)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=1.5, min_samples=2)
    for _ in range(4):
        for h in ("h0", "h1", "h2", "h3"):
            mon.report(h, 1.0)
        mon.report("slow", 3.0)
    assert mon.stragglers() == ["slow"]


def test_elastic_mesh_shapes():
    assert largest_mesh_shape(256, model_parallel=16) == (16, 16)
    assert largest_mesh_shape(192, model_parallel=16) == (12, 16)
    assert largest_mesh_shape(512, model_parallel=16, pods=2) == (2, 16, 16)
    assert largest_mesh_shape(480, model_parallel=16, pods=2) == (2, 15, 16)
    with pytest.raises(ValueError):
        largest_mesh_shape(8, model_parallel=16)


def test_elastic_restore_across_meshes(tmp_path, tiny):
    """Checkpoint saved unsharded restores under different shardings."""
    model, ts, state = tiny
    ckpt.save_checkpoint(tmp_path, 1, state)
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh((1, 1), ("data", "model"))
    from repro.train.train_step import state_shardings
    sh = state_shardings(model, ts, mesh)
    restored, _ = ckpt.restore_checkpoint(tmp_path, 1, state, sh)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}
