import os

import numpy as np
import pytest

from repro.core.patterns import Rule, RuleSet
from repro.core.records import RecordBatch, encode_texts


def pytest_sessionfinish(session, exitstatus):
    """CI chaos leg: FLUXSIEVE_TELEMETRY_DUMP=<dir> makes the suite leave
    its full telemetry dump (metrics.prom / snapshot.json / trace.json)
    behind as a build artifact — the record of every injected fault and
    every recovery action the run actually exercised."""
    out = os.environ.get("FLUXSIEVE_TELEMETRY_DUMP")
    if out:
        from repro.core import telemetry
        telemetry.write_dump(out)


@pytest.fixture
def small_ruleset() -> RuleSet:
    return RuleSet((
        Rule(0, "err", "ERROR", fields=("content1",)),
        Rule(1, "panic", "panic|fatal", fields=("*",)),
        Rule(2, "user", "usr[0-9]", fields=("content2",)),
    ))


@pytest.fixture
def small_batch() -> RecordBatch:
    return RecordBatch({
        "timestamp": np.arange(6, dtype=np.int64),
        "status": np.zeros(6, np.int32),
        "content1": encode_texts([
            "an ERROR occurred", "all good here", "panic in module a",
            "quiet", "fatal usr3 problem", "usr5 normal"], 64),
        "content2": encode_texts([
            "x", "usr2 activity", "y", "calm trace", "z", "usr7 login"], 64),
    })
