import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.automaton import (CLASS_BUCKETS, CompiledEngine, compile_rules,
                                  match_oracle, words_for_rules)
from repro.core.patterns import Rule, RuleSet
from repro.core.records import encode_texts

word_st = st.text(alphabet="abcXYZ019 _", min_size=1, max_size=10)


def _engine(patterns, **kw):
    rs = RuleSet(tuple(Rule(i, f"r{i}", p) for i, p in enumerate(patterns)))
    return rs, compile_rules(rs, **kw)


def test_basic_match():
    rs, eng = _engine(["ERROR", "fatal|panic", "usr[0-9]"])
    data = encode_texts(["xx ERROR", "a panic", "usr7!", "none"], 32)
    bm = match_oracle(eng, data)
    assert bm[:, 0].tolist() == [1, 2, 4, 0]


def test_overlapping_patterns():
    rs, eng = _engine(["abc", "bcd", "c"])
    bm = match_oracle(eng, encode_texts(["xabcdx"], 16))
    assert bm[0, 0] == 0b111  # all three fire on one pass


def test_word_bucket_stability():
    # growing the rule set within a bucket keeps shapes identical
    _, e1 = _engine(["a"])
    _, e2 = _engine(["a", "b", "c"])
    assert e1.emit.shape[1] == e2.emit.shape[1] == words_for_rules(3)
    assert e1.delta.shape[0] == e2.delta.shape[0]       # state bucket
    assert e1.delta.shape[1] in CLASS_BUCKETS


def test_case_insensitive_routing():
    rs, eng = _engine(["error"])
    rs_ci = RuleSet((Rule(0, "e", "error", case_insensitive=True),))
    eng_ci = compile_rules(rs_ci)
    data = encode_texts(["big ERROR here"], 32)
    assert match_oracle(eng, data)[0, 0] == 0
    assert match_oracle(eng_ci, data)[0, 0] == 1


def test_serialize_round_trip():
    _, eng = _engine(["foo", "bar|baz"])
    eng2 = CompiledEngine.deserialize(eng.serialize())
    np.testing.assert_array_equal(eng.delta, eng2.delta)
    np.testing.assert_array_equal(eng.emit, eng2.emit)
    assert eng2.checksum() == eng.checksum()


def test_corrupt_artifact_rejected():
    _, eng = _engine(["foo"])
    blob = bytearray(eng.serialize())
    # flip bytes; either the npz container or the sha256 check must trip
    for i in range(60, len(blob), 97):
        blob[i] ^= 0xFF
    with pytest.raises(ValueError):
        CompiledEngine.deserialize(bytes(blob))


@given(pats=st.lists(word_st, min_size=1, max_size=8, unique=True),
       texts=st.lists(st.text(alphabet="abcXYZ019 _", max_size=40),
                      min_size=1, max_size=8))
@settings(max_examples=40, deadline=None)
def test_oracle_matches_python_substring(pats, texts):
    rs = RuleSet(tuple(Rule(i, f"r{i}", p) for i, p in enumerate(pats)))
    eng = compile_rules(rs)
    data = encode_texts(texts, 64)
    bm = match_oracle(eng, data)
    for ti, text in enumerate(texts):
        raw = data[ti].tobytes().rstrip(b"\x00").decode()
        for ri, p in enumerate(pats):
            expect = p in raw
            got = bool((bm[ti, ri // 32] >> np.uint32(ri % 32)) & 1)
            assert got == expect, (p, text)


def test_field_scoped_compile():
    rs = RuleSet((Rule(0, "a", "xx", fields=("content1",)),
                  Rule(1, "b", "yy", fields=("content2",))))
    e1 = compile_rules(rs, "content1")
    data = encode_texts(["xx yy"], 16)
    bm = match_oracle(e1, data)
    assert bm[0, 0] == 1  # only rule 0 lives in the content1 engine
