import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding
from repro.launch.hlo import parse_collectives, shape_bytes
from repro.launch.mesh import make_smoke_mesh
from repro.launch.roofline import RooflineTerms, model_flops
from repro.configs import base as cfgbase
from repro.models.model import Model
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainStepConfig, build_train_step, init_state


def test_logical_to_spec_drops_missing_axes():
    mesh = make_smoke_mesh((1, 1), ("data", "model"))
    spec = sharding.logical_to_spec(("batch", "seq", "heads_act"), mesh)
    assert spec == P(("data",), None, "model")   # 'pod' dropped


def test_rules_replace():
    rules = sharding.DEFAULT_RULES.replace(batch=("data",))
    assert rules.get("batch") == ("data",)
    assert sharding.DEFAULT_RULES.get("batch") == ("pod", "data")


def test_tree_specs_on_params():
    mesh = make_smoke_mesh((1, 1), ("data", "model"))
    model = Model.from_name("yi-34b", reduced=True)
    specs = model.param_shardings(mesh)
    flat = jax.tree.leaves(specs)
    assert all(hasattr(s, "spec") for s in flat)


def test_train_step_on_mesh_matches_single_device():
    """The sharded train step (1x1 mesh) reproduces unsharded numerics."""
    model = Model.from_name("phi3-mini-3.8b", reduced=True)
    ts = TrainStepConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0,
                                                   total_steps=10))
    rng = np.random.default_rng(0)
    t = rng.integers(3, 400, (4, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}
    state = init_state(model, jax.random.key(0), ts)
    _, m_plain = build_train_step(model, ts, donate=False)(state, batch)

    mesh = make_smoke_mesh((1, 1), ("data", "model"))
    state_m = init_state(model, jax.random.key(0), ts, mesh)
    step_m = build_train_step(model, ts, mesh, donate=False)
    _, m_mesh = step_m(state_m, batch)
    assert float(m_plain["loss"]) == pytest.approx(float(m_mesh["loss"]),
                                                   rel=1e-4)


def test_moe_on_mesh_matches_local():
    model = Model.from_name("deepseek-moe-16b", reduced=True)
    ts = TrainStepConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0,
                                                   total_steps=10))
    rng = np.random.default_rng(0)
    t = rng.integers(3, 400, (4, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}
    state = init_state(model, jax.random.key(0), ts)
    _, m_plain = build_train_step(model, ts, donate=False)(state, batch)
    mesh = make_smoke_mesh((1, 1), ("data", "model"))
    state_m = init_state(model, jax.random.key(0), ts, mesh)
    _, m_mesh = build_train_step(model, ts, mesh, donate=False)(state_m, batch)
    assert float(m_plain["loss"]) == pytest.approx(float(m_mesh["loss"]),
                                                   rel=1e-3)


def test_shape_bytes():
    assert shape_bytes("bf16[256,4096]{1,0}") == 256 * 4096 * 2
    assert shape_bytes("f32[8]") == 32
    assert shape_bytes("(f32[4], s8[2,2])") == 16 + 4
    assert shape_bytes("pred[]") == 1


def test_parse_collectives_real_module():
    mesh = make_smoke_mesh((1,), ("data",))

    @jax.jit
    def f(x):
        return sharding.shard_map(lambda v: jax.lax.psum(v, "data"),
                                  mesh=mesh, in_specs=P("data"),
                                  out_specs=P(), check_vma=False)(x)

    txt = f.lower(jnp.ones((8, 128))).compile().as_text()
    stats = parse_collectives(txt)
    # single-device psum may optimize away; the parser must at least not crash
    assert stats.total_bytes >= 0


def test_parse_collectives_handcrafted():
    txt = """
  %p = bf16[1024,512]{1,0} parameter(0)
  %ag = bf16[16384,512]{1,0} all-gather(%p), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[256]{0} all-reduce(%conv.5), to_apply=%add
  %conv.5 = f32[256]{0} convert(%p)
  %cp = bf16[8,8]{1,0} collective-permute(%ag2), source_target_pairs={{0,1}}
  %ag2 = bf16[8,8]{1,0} bitcast(%p)
"""
    stats = parse_collectives(txt)
    assert stats.count_by_kind["all-gather"] == 1
    # all-gather counts the RESULT (per-device received volume), not the
    # 1/N operand shard — see hlo.py
    assert stats.bytes_by_kind["all-gather"] == 16384 * 512 * 2
    assert stats.bytes_by_kind["all-reduce"] == 256 * 4
    assert stats.bytes_by_kind["collective-permute"] == 8 * 8 * 2
    assert stats.total_count == 3


def test_roofline_terms_math():
    t = RooflineTerms(arch="a", shape="s", mesh="single", chips=256,
                      device_flops=197e12, device_bytes=819e9,
                      device_collective_bytes=100e9,
                      model_flops_global=197e12 * 256 * 0.5)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(2.0)
    assert t.dominant == "collective"
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.25)


def test_model_flops_kinds():
    cfg = cfgbase.get_config("yi-34b")
    tr = model_flops(cfg, cfgbase.SHAPES["train_4k"])
    pf = model_flops(cfg, cfgbase.SHAPES["prefill_32k"])
    dc = model_flops(cfg, cfgbase.SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 4096 * 256)
    assert pf == pytest.approx(2 * n * 32768 * 32)
    assert dc == pytest.approx(2 * n * 128)


def test_moe_active_params_below_total():
    cfg = cfgbase.get_config("deepseek-moe-16b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
