"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle vs the
host numpy reference, swept over shapes/dtypes per the task spec."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.automaton import compile_rules, match_oracle
from repro.core.patterns import Rule, RuleSet
from repro.core.records import encode_texts
from repro.kernels.bitmap_filter.ops import (bitmap_count, bitmap_match,
                                             bitmap_query_stacked,
                                             bitmap_query_words,
                                             bitmap_select)
from repro.kernels.bitmap_filter.ref import bitmap_filter_ref
from repro.kernels.dfa_scan.ops import dfa_scan
from repro.kernels.shift_or.ops import compile_shift_or, shift_or_match

RULES = RuleSet((
    Rule(0, "err", "ERROR"),
    Rule(1, "alt", "fatal|panic"),
    Rule(2, "cls", "usr[0-9]"),
    Rule(3, "short", "a"),
    Rule(4, "long", "averyveryverylongpattern"),
))
ENGINE = compile_rules(RULES)


def _random_texts(rng, n, width):
    words = ["ERROR", "fatal", "panic", "usr3", "usr9x", "quiet", "a", "zz",
             "averyveryverylongpattern", "averyveryverylongpatter"]
    return encode_texts(
        [" ".join(rng.choice(words, size=rng.integers(1, 8))) for _ in range(n)],
        width)


@pytest.mark.parametrize("n", [1, 3, 8, 37, 256])
@pytest.mark.parametrize("width", [16, 64, 512])
def test_dfa_scan_shapes(n, width):
    rng = np.random.default_rng(n * 1000 + width)
    data = _random_texts(rng, n, width)
    want = match_oracle(ENGINE, data)
    args = (jnp.asarray(data), jnp.asarray(ENGINE.delta),
            jnp.asarray(ENGINE.emit), jnp.asarray(ENGINE.byte_classes))
    got_ref = np.asarray(dfa_scan(*args, backend="ref"))
    got_pl = np.asarray(dfa_scan(*args, backend="pallas", block_n=8))
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_pl, want)


@pytest.mark.parametrize("match_rate", ["none", "some", "all"])
def test_dfa_scan_selective(match_rate):
    """Two-pass confirm path agrees with the oracle at every selectivity."""
    from repro.kernels.dfa_scan.ops import dfa_scan_selective
    rng = np.random.default_rng(7)
    if match_rate == "none":
        texts = ["calm quiet"] * 33
    elif match_rate == "all":
        texts = ["ERROR fatal"] * 33
    else:
        texts = [rng.choice(["an ERROR", "ok", "usr3", "x"]) for _ in range(33)]
    data = encode_texts(texts, 32)
    want = match_oracle(ENGINE, data)
    got = dfa_scan_selective(data, ENGINE.delta, ENGINE.emit,
                             ENGINE.byte_classes)
    np.testing.assert_array_equal(got, want)


def test_dfa_scan_parallel_backend():
    small = RuleSet((Rule(0, "a", "ab"), Rule(1, "b", "ba")))
    eng = compile_rules(small, bucket=256)
    rng = np.random.default_rng(0)
    data = _random_texts(rng, 16, 32)
    want = match_oracle(eng, data)
    got = np.asarray(dfa_scan(jnp.asarray(data), jnp.asarray(eng.delta),
                              jnp.asarray(eng.emit),
                              jnp.asarray(eng.byte_classes),
                              backend="parallel"))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [1, 5, 64])
@pytest.mark.parametrize("width", [32, 128])
def test_shift_or_vs_oracle(n, width):
    rules = RuleSet(tuple(Rule(i, f"r{i}", p) for i, p in enumerate(
        ["ERROR", "fatal|panic", "usr[0-3]", "a"])))
    eng = compile_rules(rules)
    tables = compile_shift_or(rules)
    rng = np.random.default_rng(n + width)
    data = _random_texts(rng, n, width)
    want = match_oracle(eng, data)
    got_ref = np.asarray(shift_or_match(jnp.asarray(data), tables))[:, :want.shape[1]]
    got_pl = np.asarray(shift_or_match(jnp.asarray(data), tables,
                                       backend="pallas", block_n=8))[:, :want.shape[1]]
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_pl, want)


def test_shift_or_rejects_long_literals():
    rules = RuleSet((Rule(0, "too", "x" * 33),))
    with pytest.raises(ValueError):
        compile_shift_or(rules)


@pytest.mark.parametrize("n", [1, 7, 1024, 2500])
@pytest.mark.parametrize("w", [1, 4, 32])
def test_bitmap_filter_shapes(n, w):
    rng = np.random.default_rng(n + w)
    bm = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    bm[rng.random(n) < 0.7] = 0                      # sparse, like real data
    query = np.zeros(w, np.uint32)
    query[0] = 0b1010
    want = np.asarray(bitmap_filter_ref(jnp.asarray(bm), jnp.asarray(query)))
    got = np.asarray(bitmap_match(jnp.asarray(bm), jnp.asarray(query),
                                  backend="pallas", block_n=256))
    np.testing.assert_array_equal(got, want)
    cnt = bitmap_count(jnp.asarray(bm), jnp.asarray(query), backend="pallas")
    assert int(cnt) == int(want.sum())


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_bitmap_query_stacked_multi_segment(backend, p):
    """The multi-segment conjunctive entries (full-width masks AND the
    word-sliced fast path) agree with the numpy AND-of-any semantics across
    ragged segment sizes, and padded rows/slots never contribute."""
    rng = np.random.default_rng(p * 10 + (backend == "pallas"))
    lens = [int(rng.integers(1, 40)) for _ in range(4)]
    N, W = sum(lens), 3
    bm = rng.integers(0, 2**32, size=(N, W), dtype=np.uint32)
    bm[rng.random(N) < 0.5] = 0
    rids = rng.choice(W * 32, size=p, replace=False)
    masks = np.zeros((p, W), np.uint32)
    for i, r in enumerate(rids):
        masks[i, r // 32] = np.uint32(1) << np.uint32(r % 32)
    row_seg = np.repeat(np.arange(4, dtype=np.int32), lens)
    want = (((bm[:, None, :] & masks[None]) != 0).any(-1)).all(-1)
    want_counts = [int(want[row_seg == s].sum()) for s in range(4)]

    m, c = bitmap_query_stacked(jnp.asarray(bm), jnp.asarray(masks),
                                jnp.asarray(row_seg), num_segments=4,
                                backend=backend, block_n=8)
    np.testing.assert_array_equal(np.asarray(m)[:N], want)
    assert not np.asarray(m)[N:].any()          # padded rows never match
    assert np.asarray(c)[:4].tolist() == want_counts
    assert not np.asarray(c)[4:].any()          # padded slots stay zero

    words = jnp.asarray((rids // 32).astype(np.int32))
    cols = jnp.asarray(np.ascontiguousarray(bm[:, np.asarray(rids) // 32]))
    bits = jnp.asarray(masks[np.arange(p), np.asarray(rids) // 32])
    m2, c2 = bitmap_query_words(cols, bits, jnp.asarray(row_seg),
                                num_segments=4, backend=backend, block_n=8)
    np.testing.assert_array_equal(np.asarray(m2)[:N], want)
    assert np.asarray(c2)[:4].tolist() == want_counts
    m3, c3 = bitmap_query_words(cols, bits, jnp.asarray(row_seg),
                                num_segments=4, backend=backend, block_n=8,
                                with_counts=False)
    np.testing.assert_array_equal(np.asarray(m3)[:N], want)
    assert c3 is None


def test_bitmap_select_compaction():
    bm = np.zeros((10, 1), np.uint32)
    bm[[2, 5, 9], 0] = 1
    idx, count = bitmap_select(jnp.asarray(bm), jnp.asarray([1], np.uint32),
                               max_out=5)
    assert int(count) == 3
    assert sorted(np.asarray(idx[:3]).tolist()) == [2, 5, 9]
    assert np.asarray(idx[3:]).tolist() == [-1, -1]


def test_kernels_agree_on_1000_rules():
    """The paper's operating point: 1000 patterns, single pass."""
    rules = tuple(Rule(i, f"r{i}", f"QQpat{i:04d}") for i in range(998))
    rules += (Rule(998, "real", "ERROR"), Rule(999, "alt", "fatal|panic"))
    rs = RuleSet(rules)
    eng = compile_rules(rs)
    data = encode_texts(["an ERROR", "fatal stuff", "QQpat0500!", "calm"], 64)
    want = match_oracle(eng, data)
    got = np.asarray(dfa_scan(jnp.asarray(data), jnp.asarray(eng.delta),
                              jnp.asarray(eng.emit),
                              jnp.asarray(eng.byte_classes),
                              backend="pallas", block_n=8))
    np.testing.assert_array_equal(got, want)
    assert want[0, 998 // 32] >> np.uint32(998 % 32) & 1
    assert want[2, 500 // 32] >> np.uint32(500 % 32) & 1
