import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.records import encode_texts
from repro.data import tokenizer


def test_round_trip():
    data = encode_texts(["hello", "log line 42"], 32)
    toks = tokenizer.encode_bytes(data)
    assert toks.shape == (2, 33)                 # +BOS
    assert (toks[:, 0] == tokenizer.BOS).all()
    out = tokenizer.decode_tokens(toks)
    assert out == ["hello", "log line 42"]


def test_pack_sequences_shapes_and_labels():
    data = encode_texts(["abcdefgh" * 4] * 10, 64)
    rows = tokenizer.encode_bytes(data)
    tokens, labels = tokenizer.pack_sequences(rows, seq_len=16, batch=4)
    assert tokens.shape == labels.shape == (4, 16)
    # labels are the next-token shift of tokens within the packed stream
    flat_t = tokens.reshape(-1)
    flat_l = labels.reshape(-1)
    np.testing.assert_array_equal(flat_l[:15], flat_t[1:16])


@given(st.integers(1, 8), st.integers(4, 64))
@settings(max_examples=20, deadline=None)
def test_pack_sequences_always_fills(batch, seq_len):
    data = encode_texts(["xy"], 8)               # tiny corpus tiles
    rows = tokenizer.encode_bytes(data)
    tokens, labels = tokenizer.pack_sequences(rows, seq_len, batch)
    assert tokens.shape == (batch, seq_len)
    assert (tokens != tokenizer.PAD).all()
