"""Planner/executor split tests: randomized lane equivalence (the stacked
device executors, the sharded + shared-arrangement lanes, and the batched
DFA scan lane vs the pre-refactor numpy path vs the scan baselines),
physical path-class accounting, the shared arrangement plane's epoch
invalidation by maintenance swaps / cold runs, mid-query meta-swap
re-planning, and the one-D2H-per-query discipline under jax's transfer
guard."""
import numpy as np
import pytest

from repro.core.matcher import compile_bundle
from repro.core.patterns import Rule, RuleSet
from repro.core.query import executor as executor_mod
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.planner import (BITMAP, FALLBACK, META_COUNT, POSTINGS,
                                      PRUNED, PhysicalPlan, SegmentTask)
from repro.core.query.store import SegmentStore
from repro.core.stream_processor import StreamProcessor
from repro.data.generator import LogGenerator, WorkloadSpec

# two deliberately DENSE rules (single letters hit most vocab words): their
# posting lists are suppressed by the density cut, so queries over them land
# in the bitmap-scan class — the stacked-dispatch path under test
DENSE_TERMS = (("content1", "a"), ("content1", "e"))


def build_ragged_world(tmp_path, *, seed=0, num_records=4000, late=False):
    """Planted workload ingested into RAGGED segments (sizes drawn per
    seal), with planted selective rules + two dense rules.  ``late=True``
    holds one planted rule out of the ingest-time ruleset but registers it
    with the mapper afterwards — every segment then predates it, so queries
    on it exercise the consistency-fallback class on every segment."""
    spec = WorkloadSpec(num_records=num_records, ultra_rate=1e-3,
                        high_rate=1e-2, seed=seed, text_width=256)
    gen = LogGenerator(spec)
    rules = [Rule(i, t.term, t.term, fields=(t.fieldname,))
             for i, t in enumerate(spec.planted)]
    base = len(rules)
    for j, (f, term) in enumerate(DENSE_TERMS):
        rules.append(Rule(base + j, f"dense{j}", term, fields=(f,)))
    full = RuleSet(tuple(rules))
    late_rule = rules[0]
    ingest_rs = full.without_ids([late_rule.rule_id]) if late else full
    proc = StreamProcessor(compile_bundle(ingest_rs, spec.content_fields))
    store = SegmentStore(segment_size=10**9, root=tmp_path,
                         index_fields=spec.content_fields,
                         version_rules=proc.version_rules)
    rng = np.random.default_rng(seed + 99)
    start = 0
    while start < num_records:
        n = int(rng.integers(300, 900))
        n = min(n, num_records - start)
        store.append(proc.process(gen.batch(start, n)))
        store.seal()
        start += n
    mapper = QueryMapper(ingest_rs, version_id=0)
    if late:
        mapper.notify(full, version_id=1)
    return spec, gen, store, mapper


def make_engines(store, mapper):
    return {
        "numpy": QueryEngine(store, mapper=mapper, backend="numpy"),
        "ref": QueryEngine(store, mapper=mapper, backend="ref"),
        "pallas": QueryEngine(store, mapper=mapper, backend="pallas",
                              block_n=256),
        "ref+dfa": QueryEngine(store, mapper=mapper, backend="ref",
                               scan_backend="dfa_ref", block_n=64),
        # sharded query workers over the shared arrangement plane
        "ref+shards": QueryEngine(store, mapper=mapper, backend="ref",
                                  shards=3),
        # forced device-side count reduction (the accelerator path, on CPU)
        "ref+devcounts": QueryEngine(store, mapper=mapper, backend="ref",
                                     device_counts=True),
    }


def queries(spec):
    ultra = next(t for t in spec.planted
                 if t.fieldname == "content1" and t.rate < 1e-2)
    high1 = next(t for t in spec.planted
                 if t.fieldname == "content1" and t.rate >= 1e-2)
    high2 = next(t for t in spec.planted
                 if t.fieldname == "content2" and t.rate >= 1e-2)
    return {
        "q2_ultra_copy": Query(terms=((ultra.fieldname, ultra.term),),
                               mode="copy"),
        "q3_high_count": Query(terms=((high1.fieldname, high1.term),),
                               mode="count"),
        "q3_dense_count": Query(terms=DENSE_TERMS, mode="count"),
        "q4_mixed_copy": Query(terms=((high1.fieldname, high1.term),
                                      (high2.fieldname, high2.term)),
                               mode="copy"),
        "q4_dense_copy": Query(terms=(DENSE_TERMS[0],
                                      ("content2", high2.term)),
                               mode="copy"),
    }


def result_fingerprint(r):
    ts = (tuple(np.sort(r.records.columns["timestamp"]).tolist())
          if r.records is not None and r.records.columns else ())
    return (r.count, r.segments_scanned, r.segments_pruned,
            r.segments_fallback, r.bytes_read, tuple(sorted(r.fallback_ids)),
            ts)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_randomized_lane_equivalence(tmp_path, seed):
    """All executor lanes (numpy oracle, stacked jnp, stacked pallas, dfa
    full scans) agree on count, materialized records, bytes_read, and
    pruned/fallback accounting across Q1-Q4 shapes, ragged segments, and
    cold/hot runs — and match the untouched scan baselines."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=seed)
    engines = make_engines(store, mapper)
    baseline = engines["numpy"]
    for qname, q in queries(spec).items():
        for cold in (True, False):
            if not cold:
                # lanes share one store: pre-warm the host cache so every
                # hot lane sees identical residency (the first hot reader
                # would otherwise pay — and retain — the disk read alone)
                baseline.execute(q, path="fluxsieve")
            want = None
            for lane, engine in engines.items():
                r = engine.execute(q, path="fluxsieve", cold=cold)
                got = result_fingerprint(r)
                if want is None:
                    want = got
                else:
                    assert got == want, (qname, lane, cold, got, want)
            # anchored to the untouched substring-scan baseline
            r_scan = baseline.execute(q, path="full_scan")
            assert want[0] == r_scan.count, (qname, want[0], r_scan.count)
    # planted truth for the single-term queries
    ultra = next(t for t in spec.planted
                 if t.fieldname == "content1" and t.rate < 1e-2)
    r = engines["ref"].execute(
        Query(terms=((ultra.fieldname, ultra.term),), mode="count"),
        path="fluxsieve")
    assert r.count == gen.true_count(ultra)


@pytest.mark.parametrize("seed", [3, 4])
def test_randomized_equivalence_under_fallback(tmp_path, seed):
    """Every segment predates the queried rule: the whole store serves via
    consistency fallback, and the dfa-backed scan lane must agree with the
    numpy substring lane byte-for-byte."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=seed,
                                                  late=True)
    engines = make_engines(store, mapper)
    t = spec.planted[0]
    for mode in ("count", "copy"):
        q = Query(terms=((t.fieldname, t.term),), mode=mode)
        fps = {lane: result_fingerprint(e.execute(q, path="fluxsieve"))
               for lane, e in engines.items()}
        assert len(set(fps.values())) == 1, fps
        r = engines["ref"].execute(q, path="fluxsieve")
        assert r.segments_fallback == len(store.segments)
        assert r.count == gen.true_count(t)
        assert r.path_classes == {FALLBACK: len(store.segments)}


def test_plan_classes(tmp_path):
    """The planner's per-segment classification covers all enriched path
    classes and is reflected in QueryResult.path_classes."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=5)
    engine = QueryEngine(store, mapper=mapper, backend="ref")
    ultra = next(t for t in spec.planted
                 if t.fieldname == "content1" and t.rate < 1e-2)
    # single selective rule, count mode: pruned or metadata-count everywhere
    q = Query(terms=((ultra.fieldname, ultra.term),), mode="count")
    plan = engine.plan(q, path="fluxsieve")
    counts = plan.class_counts()
    assert set(counts) <= {PRUNED, META_COUNT}
    assert sum(counts.values()) == len(store.segments)
    r = engine.execute(q, path="fluxsieve")
    assert r.path_classes == counts
    # selective copy: postings class on unpruned segments
    plan_copy = engine.plan(Query(terms=((ultra.fieldname, ultra.term),),
                                  mode="copy"), path="fluxsieve")
    assert set(plan_copy.class_counts()) <= {PRUNED, POSTINGS}
    # dense conjunction: bitmap-scan class everywhere
    plan_dense = engine.plan(Query(terms=DENSE_TERMS, mode="count"),
                             path="fluxsieve")
    assert plan_dense.class_counts() == {BITMAP: len(store.segments)}


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_single_d2h_per_query(tmp_path, backend):
    """The batched bitmap-scan class performs exactly ONE device-to-host
    transfer per query: the counted executor hook fires once per execute,
    and jax's transfer guard proves no implicit D2H sneaks in."""
    import jax
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=6,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend=backend, block_n=256)
    q_count = Query(terms=DENSE_TERMS, mode="count")
    q_copy = Query(terms=DENSE_TERMS, mode="copy")
    truth = engine.execute(q_count, path="full_scan").count
    engine.execute(q_count, path="fluxsieve")       # warmup/compile
    engine.execute(q_copy, path="fluxsieve")
    before = executor_mod.transfer_count()
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(2):
            r = engine.execute(q_count, path="fluxsieve")
            rc = engine.execute(q_copy, path="fluxsieve")
    assert executor_mod.transfer_count() - before == 4
    assert r.count == truth and rc.count == truth
    assert r.path_classes == {BITMAP: len(store.segments)}


def test_arrangement_hot_skip_and_epoch_invalidation(tmp_path):
    """Hot queries lease the shared device arrangement (no disk bytes, no
    re-upload — uploads stay at one per word column per epoch); a
    maintenance meta swap publishes a new epoch and only the swapped
    segment's columns re-upload; cold runs re-read and re-account
    everything."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=7,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend="ref")
    arr = engine.arrangements
    q = Query(terms=DENSE_TERMS, mode="count")
    r_cold = engine.execute(q, path="fluxsieve", cold=True)
    assert r_cold.bytes_read > 0
    assert arr.upload_counts() == {}        # ephemeral: nothing pooled
    r_warm = engine.execute(q, path="fluxsieve")    # builds the arrangement
    builds0 = arr.builds
    r_hot = engine.execute(q, path="fluxsieve")     # pure lease hit
    assert r_hot.bytes_read == 0
    assert arr.builds == builds0 and arr.lease_hits >= 1
    assert r_hot.count == r_cold.count == r_warm.count
    assert arr.live_arrangements() == 1
    uploads0 = arr.upload_counts()
    assert uploads0 and all(v == 1 for v in uploads0.values())
    # maintenance swap on ONE segment: epoch publishes, the old arrangement
    # retires, and the rebuild re-uploads ONLY the swapped segment's columns
    # (unchanged tokens serve from the shared column pool)
    epoch0 = arr.epoch
    swapped = store.segments[0]
    swapped.apply_update(meta_updates={})
    assert arr.epoch == epoch0 + 1
    r_swap = engine.execute(q, path="fluxsieve")
    assert r_swap.count == r_cold.count
    uploads1 = arr.upload_counts()
    assert all(v == 1 for v in uploads1.values())
    fresh = set(uploads1) - set(uploads0)
    assert fresh and {tok[0] for tok, _ in fresh} == {swapped.segment_id}
    # cold run: epoch publication drops device residency; disk bytes
    # re-accounted, and the shared plane holds nothing afterwards
    r_cold2 = engine.execute(q, path="fluxsieve", cold=True)
    assert r_cold2.bytes_read == r_cold.bytes_read
    assert arr.live_arrangements() == 0
    assert arr.device_bytes == 0
    assert arr.active_leases() == {}


def test_mid_query_meta_swap_replans(tmp_path):
    """A plan whose snapshots were ALL invalidated by maintenance swaps
    between planning and execution is re-planned per segment — results stay
    correct and nothing degrades to fallback (the re-plan sees equivalent
    metadata)."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=8,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend="ref")
    q = Query(terms=DENSE_TERMS, mode="copy")
    truth = engine.execute(q, path="full_scan").count
    plan = engine.plan(q, path="fluxsieve")
    for seg in store.segments:                      # swap EVERY snapshot
        seg.apply_update(meta_updates={})
    res = engine._run(plan, cache=True)
    assert res.count == truth
    assert res.segments_fallback == 0
    assert res.path_classes == {BITMAP: len(store.segments)}


def test_fallback_full_scan_returns_directly_after_swap(tmp_path):
    """Satellite fix: a consistency-fallback full scan never reads
    enrichment state, so its result is returned directly even when the
    segment meta swaps mid-query — one fallback per segment, no re-scan."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=9,
                                                  num_records=2000,
                                                  late=True)
    engine = QueryEngine(store, mapper=mapper, backend="ref")
    t = spec.planted[0]
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    plan = engine.plan(q, path="fluxsieve")
    assert plan.class_counts() == {FALLBACK: len(store.segments)}
    for seg in store.segments:
        seg.apply_update(meta_updates={})           # swap under the plan
    res = engine._run(plan, cache=True)
    assert res.count == engine.execute(q, path="full_scan").count
    assert res.segments_fallback == len(store.segments)
    assert res.segments_scanned == len(store.segments)


def test_profiler_path_class_stats(tmp_path):
    from repro.core.query.profiler import QueryProfiler
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=10,
                                                  num_records=2000)
    prof = QueryProfiler()
    engine = QueryEngine(store, mapper=mapper, profiler=prof, backend="ref")
    engine.execute(Query(terms=DENSE_TERMS, mode="count"), path="fluxsieve")
    ultra = next(t for t in spec.planted
                 if t.fieldname == "content1" and t.rate < 1e-2)
    engine.execute(Query(terms=((ultra.fieldname, ultra.term),),
                         mode="count"), path="fluxsieve")
    stats = prof.path_class_stats()
    assert stats[BITMAP]["segments"] == len(store.segments)
    assert stats[BITMAP]["queries"] == 1
    assert set(stats) <= {BITMAP, PRUNED, META_COUNT, POSTINGS}
    assert all(st["seconds"] >= 0 for st in stats.values())


def test_sharded_mid_query_swap_replans(tmp_path):
    """Sharded execution under maintenance churn: every snapshot in the
    plan is invalidated between planning and execution — each shard
    re-plans ITS swapped segments independently, the merge step reassembles
    plan order, and nothing degrades to fallback."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=12,
                                                  num_records=2500)
    engine = QueryEngine(store, mapper=mapper, backend="ref", shards=3)
    q = Query(terms=DENSE_TERMS, mode="copy")
    truth = engine.execute(q, path="full_scan").count
    plan = engine.plan(q, path="fluxsieve")
    for seg in store.segments:                      # swap EVERY snapshot
        seg.apply_update(meta_updates={})
    res = engine._run(plan, cache=True)
    assert res.count == truth
    assert res.segments_fallback == 0
    assert res.path_classes == {BITMAP: len(store.segments)}
    assert engine.arrangements.active_leases() == {}


def test_fallback_batched_single_fused_dispatch(tmp_path):
    """Satellite: with a fused-capable scan backend, ALL consistency-
    fallback segments of a query run as ONE throwaway-DFA dispatch (one
    matcher D2H per query, not one per segment) and stay byte-identical
    with the per-segment numpy substring lane."""
    from repro.core import matcher as matcher_mod
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=13,
                                                  num_records=2000,
                                                  late=True)
    assert len(store.segments) > 1
    eng_np = QueryEngine(store, mapper=mapper, backend="numpy")
    eng_dfa = QueryEngine(store, mapper=mapper, backend="ref",
                          scan_backend="dfa_ref", block_n=64)
    t = spec.planted[0]
    for mode in ("count", "copy"):
        q = Query(terms=((t.fieldname, t.term),), mode=mode)
        want = result_fingerprint(eng_np.execute(q, path="fluxsieve"))
        before = matcher_mod.transfer_count()
        r = eng_dfa.execute(q, path="fluxsieve")
        assert matcher_mod.transfer_count() - before == 1
        assert result_fingerprint(r) == want
        assert r.segments_fallback == len(store.segments)


def test_workers_threaded_equivalence(tmp_path):
    """Intra-query parallelism (workers > 1) returns identical results on
    the host-path classes, with the stacked class unaffected."""
    spec, gen, store, mapper = build_ragged_world(tmp_path, seed=11,
                                                  num_records=2500,
                                                  late=True)
    e1 = QueryEngine(store, mapper=mapper, backend="ref")
    e4 = QueryEngine(store, mapper=mapper, backend="ref", workers=4)
    t = spec.planted[0]
    for q in (Query(terms=((t.fieldname, t.term),), mode="copy"),
              Query(terms=DENSE_TERMS, mode="count")):
        assert result_fingerprint(e1.execute(q, path="fluxsieve")) == \
            result_fingerprint(e4.execute(q, path="fluxsieve"))


def test_shard_affinity_weighted_balances_skewed_sizes():
    """Satellite: record-count-weighted shard assignment keeps per-shard
    load even under skewed segment sizes, where the legacy modulo scheme
    piles the big segments onto one shard."""
    class _Seg:
        def __init__(self, sid, n):
            self.segment_id, self.num_records = sid, n

    # even ids huge, odd ids tiny: modulo(2) puts ALL the weight on shard 0
    sizes = [10_000 if sid % 2 == 0 else 10 for sid in range(8)]
    plan = PhysicalPlan(query=None, path="fluxsieve")
    plan.tasks = [SegmentTask(seg=_Seg(sid, n), meta={}, path_class=BITMAP)
                  for sid, n in enumerate(sizes)]

    def loads(groups):
        return sorted(sum(sizes[i] for i in g) for g in groups)

    modulo = plan.shard_tasks(2, affinity="modulo")
    weighted = plan.shard_tasks(2)
    assert loads(modulo) == [40, 40_000]
    assert loads(weighted) == [20_020, 20_020]
    # deterministic (hot-arrangement keys depend on it), plan order kept
    assert weighted == plan.shard_tasks(2, affinity="weighted")
    assert all(g == sorted(g) for g in weighted)
    with pytest.raises(ValueError):
        plan.shard_tasks(2, affinity="random")
