import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.records import decode_texts
from repro.data.generator import LogGenerator, WorkloadSpec


def test_schema():
    spec = WorkloadSpec(num_records=100, num_content_fields=3)
    gen = LogGenerator(spec)
    b = gen.batch(0, 50)
    assert set(b.columns) == {"timestamp", "status", "event_type",
                              "content1", "content2", "content3"}
    assert b.columns["timestamp"].dtype == np.int64
    assert b.columns["content1"].shape == (50, spec.text_width)


def test_determinism_same_batching():
    """batch(start, n) is pure in (spec, start, n)."""
    spec = WorkloadSpec(num_records=1000, seed=5)
    a, b = LogGenerator(spec), LogGenerator(spec)
    for f in spec.content_fields:
        np.testing.assert_array_equal(a.batch(50, 100).columns[f],
                                      b.batch(50, 100).columns[f])


def test_ground_truth_boundary_independent():
    """Plant decisions are record-indexed: any batching yields the same
    ground-truth match set (filler words may differ; matches may not)."""
    spec = WorkloadSpec(num_records=1000, ultra_rate=5e-2, seed=5)
    gen = LogGenerator(spec)
    t = spec.planted[0]
    whole = gen.batch(0, 200)
    parts = [gen.batch(0, 100), gen.batch(100, 100)]
    def hits(batch):
        return [t.term in x for x in decode_texts(batch.columns[t.fieldname])]
    assert hits(whole) == hits(parts[0]) + hits(parts[1])
    assert hits(whole) == gen.plant_mask(t, 0, 200).tolist()


def test_planted_ground_truth_exact():
    spec = WorkloadSpec(num_records=5000, ultra_rate=2e-3, high_rate=1e-2,
                        seed=9)
    gen = LogGenerator(spec)
    batch = gen.batch(0, 5000)
    for t in spec.planted:
        texts = decode_texts(batch.columns[t.fieldname])
        actual = sum(t.term in x for x in texts)
        assert actual == gen.true_count(t), t.term
        assert actual > 0


def test_absent_terms_absent():
    spec = WorkloadSpec(num_records=2000, seed=3)
    gen = LogGenerator(spec)
    batch = gen.batch(0, 2000)
    for f in spec.content_fields:
        for text in decode_texts(batch.columns[f]):
            for absent in spec.absent_terms:
                assert absent not in text


@given(st.integers(0, 1000), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_plant_mask_pure(start, n):
    spec = WorkloadSpec(num_records=100_000, seed=1)
    gen = LogGenerator(spec)
    t = spec.planted[0]
    m1 = gen.plant_mask(t, start, n)
    m2 = gen.plant_mask(t, start, n)
    np.testing.assert_array_equal(m1, m2)
    # window consistency with a shifted batch
    m3 = gen.plant_mask(t, 0, start + n)[start:]
    np.testing.assert_array_equal(m1, m3)
