"""Process-model tests: the durable control plane (file-backed bus +
leases) and the spawn-process maintenance/query pools built on it.

What the thread-model suites cannot exercise lives here:

  * at-least-once across a REAL restart — a consumer crashed inside the
    consume/commit window (``bus.commit`` fault) must see the same
    messages redeliver from a fresh bus instance over the same files;
  * epoch fencing against a SIGKILLed holder — a worker process killed
    mid-lease, then "restarted" with its stale token, must be rejected by
    the successor epoch another process granted while it was dead;
  * a kill-point sweep over the process pool — workers SIGKILL themselves
    at injected crash sites (checkpoint write, offset commit, delivery),
    the pool respawns them under the same identity, and convergence plus
    exact query counts must survive every site.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.core import faults
from repro.core.control_plane import (CONTROL_DIRNAME, DurableControlBus,
                                      SEGMENT_MAINTENANCE)
from repro.core.maintenance import (BackfillWorker, DurableLeaseManager,
                                    FencedWriteError, Lease,
                                    MaintenancePolicy, MaintenanceScheduler,
                                    ProcessMaintenancePool)
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.process_shards import ProcessQueryPool
from repro.core.query.store import SegmentStore
from repro.core.stream_processor import StreamProcessor
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import IngestPipeline


@pytest.fixture(autouse=True)
def _clean_faults():
    """Fresh fault state per test, and — because several tests here arm
    `FLUXSIEVE_FAULTS` in the environment for spawn children — restore
    the original env value (the CI chaos leg's standing profile, if any)
    and re-arm it afterwards, so these tests never disarm chaos for the
    rest of the session."""
    original = os.environ.get(faults.ENV_VAR)
    yield
    faults.reset()
    if original is None:
        os.environ.pop(faults.ENV_VAR, None)
    else:
        os.environ[faults.ENV_VAR] = original
        faults.load_profile(original)


def durable_world(tmp_path, *, num_records=3000, segment_size=500, seed=13,
                  hold_back=0):
    """A fully durable world: spilled store, file-backed bus + object
    store — everything a worker PROCESS needs to reopen it."""
    spec = WorkloadSpec(num_records=num_records, ultra_rate=1e-3,
                        high_rate=1e-2, seed=seed, text_width=256)
    gen = LogGenerator(spec)
    full = RuleSet(tuple(Rule(i, t.term, t.term, fields=(t.fieldname,))
                         for i, t in enumerate(spec.planted)))
    initial = full.without_ids([hold_back])
    bus = DurableControlBus(tmp_path / CONTROL_DIRNAME)
    ostore = ObjectStore(root=tmp_path / "objects")
    proc = StreamProcessor(compile_bundle(initial, spec.content_fields),
                           bus=bus, store=ostore)
    store = SegmentStore(segment_size=segment_size, root=tmp_path,
                         index_fields=spec.content_fields)
    updater = MatcherUpdater(ostore, bus, spec.content_fields,
                             initial=initial)
    IngestPipeline(gen, store, proc).run(batch_size=1000)
    mapper = QueryMapper(initial, version_id=0)
    engine = QueryEngine(store, mapper=mapper)
    return dict(spec=spec, gen=gen, full=full, initial=initial, bus=bus,
                ostore=ostore, proc=proc, store=store, updater=updater,
                mapper=mapper, engine=engine, late=spec.planted[hold_back])


def activate_late_rule(w):
    h = w["updater"].submit(w["full"], asynchronous=False)
    assert h.published, h.error
    w["proc"].poll_updates()
    w["mapper"].notify(w["full"], version_id=w["proc"].active_version_id)
    return h


def make_pool(w, **kw):
    store = w["store"]
    kw.setdefault("num_workers", 2)
    return ProcessMaintenancePool(
        store.root, store=store, objects_root=w["ostore"]._root,
        segment_size=store.segment_size, index_fields=store.index_fields,
        **kw)


# ---------------------------------------------------------------------------
# Durable bus
# ---------------------------------------------------------------------------

def test_durable_bus_roundtrip_across_instances(tmp_path):
    """Publish through one instance, poll/commit through a FRESH one over
    the same files — the restart case the in-memory bus cannot model."""
    a = DurableControlBus(tmp_path)
    for i in range(5):
        assert a.publish("t", {"i": i}) == i
    b = DurableControlBus(tmp_path)          # "restarted" consumer
    msgs = b.poll("t", "g")
    assert [m.value["i"] for m in msgs] == [0, 1, 2, 3, 4]
    assert [m.offset for m in msgs] == [0, 1, 2, 3, 4]
    b.commit("t", "g", msgs[2].offset)
    # a third instance (second restart) resumes past the committed prefix
    c = DurableControlBus(tmp_path)
    assert [m.value["i"] for m in c.poll("t", "g")] == [3, 4]
    assert c.end_offset("t") == 5
    assert len(c.messages("t", 0)) == 5
    # commit never rewinds, even from a stale instance
    a.commit("t", "g", 0)
    assert [m.value["i"] for m in c.poll("t", "g")] == [3, 4]


def test_durable_bus_commit_crash_window_redelivers(tmp_path):
    """A consumer crashed AFTER processing but BEFORE the offset hit disk
    (the ``bus.commit`` fault window) re-reads the whole uncommitted
    window on restart — at-least-once, exactly like the thread bus."""
    bus = DurableControlBus(tmp_path)
    for i in range(3):
        bus.publish("t", {"i": i})
    msgs = bus.poll("t", "g")
    assert len(msgs) == 3                    # "processed" all three
    faults.inject("bus.commit", "crash", times=1)
    with pytest.raises(faults.InjectedCrash):
        bus.commit("t", "g", msgs[-1].offset)
    faults.reset()
    # restart: fresh instance, same files — nothing was committed
    again = DurableControlBus(tmp_path)
    redelivered = again.poll("t", "g")
    assert [m.value["i"] for m in redelivered] == [0, 1, 2]
    again.commit("t", "g", redelivered[-1].offset)
    assert again.poll("t", "g") == []
    assert DurableControlBus(tmp_path).poll("t", "g") == []


def test_durable_bus_consumer_groups_independent(tmp_path):
    """Two groups drain the same topic at their own pace, offsets durable
    per (topic, group) file, surviving reopen."""
    bus = DurableControlBus(tmp_path)
    for i in range(4):
        bus.publish("t", {"i": i})
    g1 = bus.poll("t", "workers/a")
    bus.commit("t", "workers/a", g1[1].offset)       # a consumed 0..1
    assert [m.value["i"] for m in bus.poll("t", "workers/b")] == [0, 1, 2, 3]
    reopened = DurableControlBus(tmp_path)
    assert [m.value["i"] for m in reopened.poll("t", "workers/a")] == [2, 3]
    assert [m.value["i"] for m in reopened.poll("t", "workers/b")] == \
        [0, 1, 2, 3]
    # the sanitized offset files are per (topic, group)
    names = sorted(p.name for p in (tmp_path / "offsets").glob("*.json"))
    assert names == ["t--workers__a.json"]


def test_durable_bus_torn_tail_ignored_and_repaired(tmp_path):
    """A writer SIGKILLed mid-append leaves a newline-less torn tail:
    readers must stop before it (it was never acknowledged), and the next
    publish must truncate it rather than corrupt the log."""
    bus = DurableControlBus(tmp_path)
    bus.publish("t", {"i": 0})
    log = tmp_path / "topics" / "t.log"
    with open(log, "a") as f:
        f.write('{"offset": 1, "value": {"i": 99}, "timesta')   # torn
    fresh = DurableControlBus(tmp_path)
    assert [m.value["i"] for m in fresh.poll("t", "g")] == [0]
    assert fresh.publish("t", {"i": 1}) == 1     # truncates, then appends
    assert [m.value["i"] for m in fresh.poll("t", "g")] == [0, 1]
    # every line in the repaired log parses
    lines = log.read_text().splitlines()
    assert [json.loads(ln)["value"]["i"] for ln in lines] == [0, 1]


# ---------------------------------------------------------------------------
# Durable leases + fencing
# ---------------------------------------------------------------------------

def test_durable_lease_contention_expiry_release(tmp_path):
    clock = {"t": 100.0}
    mgr = DurableLeaseManager(tmp_path, ttl=10.0, clock=lambda: clock["t"])
    l1 = mgr.acquire(3, "a")
    assert l1.epoch == 1
    assert mgr.acquire(3, "b") is None           # contended while unexpired
    assert mgr.holder_of(3) == "a"
    assert mgr.renew(l1)
    clock["t"] += 20.0                           # past ttl: expiry frees it
    l2 = mgr.acquire(3, "b")
    assert l2.epoch == 2
    with pytest.raises(FencedWriteError):
        mgr.check(l1)                            # superseded epoch fenced
    mgr.check(l2)                                # current epoch passes
    assert not mgr.renew(l1)
    mgr.release(l2)
    assert mgr.holder_of(3) is None
    # epochs never rewind across release + reopen
    l3 = DurableLeaseManager(tmp_path, ttl=10.0,
                             clock=lambda: clock["t"]).acquire(3, "c")
    assert l3.epoch == 3


def test_fencing_rejects_sigkilled_then_restarted_holder(tmp_path):
    """The Chubby/ZooKeeper story with a REAL dead process: a holder in
    another OS process is SIGKILLed mid-lease; after expiry a successor
    (this process) acquires a higher epoch; the zombie's restart presents
    its stale token and must get ``FencedWriteError`` from the durable
    epoch registry — not silently clobber the successor's install."""
    child = subprocess.Popen(
        [sys.executable, "-c",
         "import sys, time\n"
         "from repro.core.maintenance.lease import DurableLeaseManager\n"
         f"m = DurableLeaseManager({str(tmp_path)!r}, ttl=0.3)\n"
         "lease = m.acquire(7, 'zombie')\n"
         "print(lease.epoch, flush=True)\n"
         "time.sleep(120)\n"],
        stdout=subprocess.PIPE, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    try:
        zombie_epoch = int(child.stdout.readline())
    finally:
        child.kill()                             # SIGKILL: no cleanup ran
        child.wait(timeout=10)
    assert zombie_epoch == 1
    mgr = DurableLeaseManager(tmp_path, ttl=30.0)
    assert mgr.holder_of(7) in ("zombie", None)  # lease may not have expired
    deadline = time.time() + 5.0
    successor = None
    while successor is None and time.time() < deadline:
        successor = mgr.acquire(7, "successor")  # granted once ttl passes
        if successor is None:
            time.sleep(0.05)
    assert successor is not None and successor.epoch == zombie_epoch + 1
    stale = Lease(segment_id=7, holder="zombie", epoch=zombie_epoch,
                  expires_at=time.time() + 60.0)
    with pytest.raises(FencedWriteError):
        mgr.check(stale)                         # the restarted zombie
    mgr.check(successor)                         # successor still writes


# ---------------------------------------------------------------------------
# Process maintenance pool
# ---------------------------------------------------------------------------

def test_process_pool_backfill_end_to_end(tmp_path):
    w = durable_world(tmp_path)
    late = w["late"]
    truth = w["gen"].true_count(late)
    assert truth > 0
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    activate_late_rule(w)
    r_pre = w["engine"].execute(q, path="fluxsieve")
    assert r_pre.count == truth
    assert r_pre.segments_fallback == len(w["store"].segments)

    pool = make_pool(w)
    try:
        assert pool.worker_ids == ("maint-0", "maint-1")
        rep = pool.run_until_converged()
        assert rep.pending_after == 0 and rep.acked
        assert rep.segments_backfilled == len(w["store"].segments)
        assert rep.rows_matched > 0
        # the updater sees both workers' acks on the durable topic
        status = w["updater"].await_maintenance(rep.version,
                                                pool.worker_ids, timeout=5)
        assert status.complete
    finally:
        pool.close()
    # the PARENT's store object observed the children's installs
    r_post = w["engine"].execute(q, path="fluxsieve")
    assert r_post.count == truth
    assert r_post.segments_fallback == 0
    assert w["engine"].execute(q, path="full_scan").count == truth


@pytest.mark.parametrize("profile", [
    # crash while writing a row-watermark checkpoint mid-segment
    "maintenance.checkpoint:crash@after=1,times=1",
    # crash inside the consume/commit window (work done, offset not moved)
    "bus.commit:crash@times=1,topic=segment-maintenance",
    # crash on delivery itself (before any work)
    "bus.deliver:crash@times=1,topic=segment-maintenance",
])
def test_process_pool_survives_sigkill_at_injected_sites(tmp_path, profile):
    """Kill-point sweep with REAL processes: each worker loads the fault
    profile from the environment at spawn, SIGKILLs itself at the injected
    site, and the pool must respawn it under the same identity and still
    converge to exact counts over a consistent manifest."""
    w = durable_world(tmp_path, num_records=2000, segment_size=400)
    late = w["late"]
    truth = w["gen"].true_count(late)
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    activate_late_rule(w)

    os.environ["FLUXSIEVE_FAULTS"] = profile
    try:
        # rows_per_pass forces mid-segment checkpoints (site #1's trigger)
        pool = make_pool(w, rows_per_pass=150, recv_timeout=60.0)
    finally:
        # respawned replacements must start CLEAN — the crash profile
        # applies to the first generation only
        del os.environ["FLUXSIEVE_FAULTS"]
    try:
        rep = pool.run_until_converged()
        assert rep.pending_after == 0
        deaths = telemetry_deaths()
        assert deaths >= 1, "no worker actually died at the kill point"
    finally:
        pool.close()

    # manifest is loadable and consistent after the carnage
    reopened = SegmentStore.load(tmp_path,
                                 segment_size=w["store"].segment_size,
                                 index_fields=w["store"].index_fields)
    assert sorted(s.segment_id for s in reopened.segments) == \
        sorted(s.segment_id for s in w["store"].segments)
    # counts are exact on both the live store and the reopened one
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == truth and r.segments_fallback == 0
    engine2 = QueryEngine(reopened, mapper=w["mapper"])
    r2 = engine2.execute(q, path="fluxsieve")
    assert r2.count == truth and r2.segments_fallback == 0


def telemetry_deaths() -> int:
    from repro.core import telemetry
    snap = telemetry.metrics.snapshot()
    series = snap["counters"].get(
        "fluxsieve_maintenance_worker_deaths_total", [])
    return sum(s["value"] for s in series)


def test_process_pool_worker_killed_mid_cycle_respawns(tmp_path):
    """Straight SIGKILL from outside (no faults): the pool marks the
    worker dead for the cycle, respawns it under the same worker id, and
    convergence completes with exact results."""
    w = durable_world(tmp_path, num_records=2000, segment_size=400)
    late = w["late"]
    truth = w["gen"].true_count(late)
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    activate_late_rule(w)
    pool = make_pool(w, recv_timeout=60.0)
    try:
        victim = pool._workers[0]
        os.kill(victim["proc"].pid, signal.SIGKILL)
        victim["proc"].join(timeout=10)
        rep = pool.run_until_converged()
        assert rep.pending_after == 0
        assert pool.worker_ids == ("maint-0", "maint-1")   # same identity
        alive = [w_["proc"].is_alive() for w_ in pool._workers]
        assert all(alive), alive
    finally:
        pool.close()
    r = w["engine"].execute(q, path="fluxsieve")
    assert r.count == truth and r.segments_fallback == 0


# ---------------------------------------------------------------------------
# Process query shards
# ---------------------------------------------------------------------------

def test_process_query_pool_counts_ids_and_isolation(tmp_path):
    w = durable_world(tmp_path, num_records=3000, segment_size=500)
    activate_late_rule(w)
    # backfill in-process first so every segment serves enriched
    BackfillWorker(w["store"], w["bus"], w["ostore"]).run_until_converged()
    term = w["late"]
    truth = w["gen"].true_count(term)

    pool = ProcessQueryPool(tmp_path, w["full"], shards=2,
                            index_fields=w["store"].index_fields,
                            segment_size=w["store"].segment_size)
    try:
        r = pool.execute(((term.fieldname, term.term),), mode="count")
        assert not r.partial and r.shards_served == 2
        assert r.count == truth
        assert r.segments_total == len(w["store"].segments)
        # ids mode: per-segment row ids union to the same cardinality
        ri = pool.execute(((term.fieldname, term.term),), mode="ids")
        assert not ri.partial
        assert ri.count == truth
        assert sum(len(v) for v in ri.ids.values()) == truth
        # each shard saw a disjoint, non-empty slice of the store and paid
        # at most ONE upload per word column (private arrangement planes)
        stats = [s for s in pool.stats() if s is not None]
        assert len(stats) == 2
        assert sum(s["segments"] for s in stats) == len(w["store"].segments)
        for s in stats:
            ups = s["uploads_per_column"].values()
            assert max(ups, default=0) <= 1, s["uploads_per_column"]
    finally:
        pool.close()


def test_process_query_pool_shard_death_degrades_partial(tmp_path):
    """A shard that dies MID-QUERY (self-SIGKILL at the ``query.shard``
    fault site) yields a partial result — never an exception — and the
    pool respawns it so the next query is whole again.  A shard killed
    BETWEEN queries is respawned before broadcast: fully transparent."""
    w = durable_world(tmp_path, num_records=2000, segment_size=500)
    activate_late_rule(w)
    BackfillWorker(w["store"], w["bus"], w["ostore"]).run_until_converged()
    term = w["late"]
    truth = w["gen"].true_count(term)
    os.environ["FLUXSIEVE_FAULTS"] = "query.shard:crash@times=1,shard=0"
    try:
        pool = ProcessQueryPool(tmp_path, w["full"], shards=2,
                                index_fields=w["store"].index_fields,
                                segment_size=w["store"].segment_size)
    finally:
        del os.environ["FLUXSIEVE_FAULTS"]     # respawns start clean
    try:
        r = pool.execute(((term.fieldname, term.term),), mode="count")
        assert r.partial and r.shards_failed == 1 and r.shards_served == 1
        assert r.count <= truth                # subset, never inflated
        # next query: the shard is respawned, results whole again
        r2 = pool.execute(((term.fieldname, term.term),), mode="count")
        assert not r2.partial
        assert r2.count == truth
        # between-queries SIGKILL from outside: respawned before broadcast
        os.kill(pool._workers[1]["proc"].pid, signal.SIGKILL)
        pool._workers[1]["proc"].join(timeout=10)
        r3 = pool.execute(((term.fieldname, term.term),), mode="count")
        assert not r3.partial and r3.count == truth
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# Matcher-cache warm (per-process compile discipline)
# ---------------------------------------------------------------------------

def test_warm_matchers_compiles_once_per_target_version(tmp_path):
    w = durable_world(tmp_path, num_records=2000, segment_size=500)
    activate_late_rule(w)
    worker = BackfillWorker(w["store"], w["bus"], w["ostore"])
    worker.poll_target()
    compiled = worker.warm_matchers()
    assert compiled > 0                      # cold cache: engines compiled
    assert worker.warm_matchers() == 0       # same version: nothing to do
    rep = worker.run_until_converged()       # warmed cache serves the run
    assert rep.pending_after == 0
    late = w["late"]
    r = w["engine"].execute(
        Query(terms=((late.fieldname, late.term),), mode="count"),
        path="fluxsieve")
    assert r.count == w["gen"].true_count(late)
    assert r.segments_fallback == 0
