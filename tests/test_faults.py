"""Fault-injection plane unit tests: spec firing semantics, profile
parsing, the zero-cost-when-off discipline, and the circuit breaker's
state machine (core/faults.py)."""
import os
import time

import pytest

from repro.core import faults, telemetry
from repro.core.faults import (CircuitBreaker, FaultSpec, InjectedCrash,
                               InjectedFault)


@pytest.fixture(autouse=True)
def _fault_isolation():
    """Each test starts disarmed; afterwards restore any env profile (the
    CI chaos leg arms FLUXSIEVE_FAULTS for the whole suite)."""
    faults.reset()
    yield
    faults.reset()
    if os.environ.get(faults.ENV_VAR):
        faults.load_profile(os.environ[faults.ENV_VAR])


# -- registry / spec semantics ------------------------------------------------
def test_unknown_site_and_kind_rejected():
    with pytest.raises(ValueError):
        faults.inject("nonsense.site")
    with pytest.raises(ValueError):
        faults.inject("match.dispatch", "meltdown")


def test_disarmed_fire_is_noop():
    assert not faults.armed()
    faults.fire("match.dispatch")            # nothing armed: returns
    assert faults.act("bus.deliver") is None
    faults.inject("match.dispatch", "error")
    assert faults.armed()
    faults.reset()
    assert not faults.armed()
    faults.fire("match.dispatch")            # disarmed again


def test_every_after_times_schedule():
    spec = faults.inject("ingest.append", "error", after=2, every=3, times=2)
    fired_at = []
    for call in range(1, 13):
        try:
            faults.fire("ingest.append")
        except InjectedFault:
            fired_at.append(call)
    # skip 2 calls, then every 3rd matching call, capped at 2 fires
    assert fired_at == [5, 8]
    assert spec.fired == 2 and spec.calls == 12


def test_default_spec_fires_every_call():
    faults.inject("ingest.append", "error")
    for _ in range(3):
        with pytest.raises(InjectedFault):
            faults.fire("ingest.append")


def test_prob_is_seed_deterministic():
    def sequence():
        spec = faults.inject("store.spill", "error", prob=0.5, seed=42)
        out = []
        for _ in range(64):
            try:
                faults.fire("store.spill")
                out.append(0)
            except InjectedFault:
                out.append(1)
        faults.reset()
        return out, spec.fired

    a, fired_a = sequence()
    b, fired_b = sequence()
    assert a == b
    assert 0 < fired_a == fired_b < 64


def test_where_filter_string_compared():
    faults.inject("bus.deliver", "drop", topic="segment-maintenance")
    assert faults.act("bus.deliver", topic="matcher-updates") is None
    assert faults.act("bus.deliver", topic="segment-maintenance") == "drop"
    # int context values compare through str()
    faults.inject("query.shard", "error", shard=1)
    faults.fire("query.shard", shard=0)      # no match, no raise
    with pytest.raises(InjectedFault):
        faults.fire("query.shard", shard=1)


def test_crash_escapes_broad_exception_handlers():
    faults.inject("store.manifest_commit", "crash")
    with pytest.raises(InjectedCrash):
        try:
            faults.fire("store.manifest_commit")
        except Exception:  # noqa: BLE001 — the point: this must NOT catch
            pytest.fail("InjectedCrash was swallowed by `except Exception`")
    assert not issubclass(InjectedCrash, Exception)


def test_stall_sleeps_delay():
    faults.inject("query.shard", "stall", delay=0.05)
    t0 = time.perf_counter()
    faults.fire("query.shard")               # returns (no raise)
    assert time.perf_counter() - t0 >= 0.04


def test_act_returns_bus_actions():
    for kind in ("drop", "dup", "reorder"):
        faults.inject("bus.deliver", kind, times=1)
    seen = {faults.act("bus.deliver") for _ in range(3)}
    assert seen == {"drop", "dup", "reorder"}
    assert faults.act("bus.deliver") is None          # all specs exhausted
    # bus kinds never raise out of fire()
    faults.inject("bus.deliver", "drop")
    faults.fire("bus.deliver")


def test_injection_bumps_counter_and_event():
    c = telemetry.counter("fluxsieve_faults_injected_total",
                          labels={"site": "match.d2h"})
    before = c.value
    faults.inject("match.d2h", "error", times=1)
    with pytest.raises(InjectedFault):
        faults.fire("match.d2h", version=3)
    assert c.value == before + 1
    evs = telemetry.events.events(kind="fault_injected")
    assert any(e["site"] == "match.d2h" and e["fault"] == "error"
               for e in evs)


def test_load_profile_grammar():
    specs = faults.load_profile(
        "match.dispatch:error@every=97;"
        "bus.deliver:dup@times=1,topic=segment-maintenance;"
        "query.shard:stall@delay=0.25")
    assert [s.site for s in specs] == ["match.dispatch", "bus.deliver",
                                      "query.shard"]
    assert specs[0].kind == "error" and specs[0].every == 97
    assert specs[1].kind == "dup" and specs[1].times == 1
    assert specs[1].where == {"topic": "segment-maintenance"}
    assert specs[2].delay == 0.25
    assert faults.armed() and len(faults.specs()) == 3


def test_load_profile_default_kind_and_blank_parts():
    (spec,) = faults.load_profile(";ingest.wal_append;")
    assert spec.site == "ingest.wal_append" and spec.kind == "error"


# -- circuit breaker ----------------------------------------------------------
def test_breaker_trips_on_consecutive_failures_only():
    br = CircuitBreaker(site="t.consec", failure_threshold=3)
    for _ in range(5):                        # interleaved successes reset
        br.record_failure()
        br.record_failure()
        br.record_success()
    assert br.state == br.CLOSED and br.trips == 0
    for _ in range(3):
        assert br.allow_primary()
        br.record_failure()
    assert br.state == br.OPEN and br.trips == 1


def test_breaker_probe_cycle_and_recovery():
    br = CircuitBreaker(site="t.probe", failure_threshold=1, probe_interval=3)
    gauge = telemetry.gauge("fluxsieve_breaker_state",
                            labels={"site": "t.probe"})
    br.record_failure()
    assert br.state == br.OPEN and gauge.value == 1
    # every 3rd open call is the probe
    assert not br.allow_primary()
    assert not br.allow_primary()
    assert br.allow_primary()                 # probe
    assert br.state == br.HALF_OPEN and gauge.value == 2
    assert not br.allow_primary()             # one probe in flight at a time
    br.record_failure()                       # probe failed: back to OPEN
    assert br.state == br.OPEN and br.trips == 1
    assert not br.allow_primary()
    assert not br.allow_primary()
    assert br.allow_primary()                 # next probe
    br.record_success()                       # probe succeeded: close
    assert br.state == br.CLOSED and gauge.value == 0
    assert br.allow_primary()


def test_breaker_emits_lifecycle_events():
    br = CircuitBreaker(site="t.events", failure_threshold=1,
                        probe_interval=1)
    br.record_failure(error="boom")
    assert br.allow_primary()                 # immediate probe
    br.record_success()
    kinds = {e["kind"] for e in telemetry.events.events()
             if e.get("site") == "t.events"}
    assert {"breaker_trip", "breaker_probe", "breaker_close"} <= kinds


def test_spec_counters_exposed_for_assertions():
    spec = faults.inject("maintenance.checkpoint", "error", every=2)
    for _ in range(4):
        try:
            faults.fire("maintenance.checkpoint")
        except InjectedFault:
            pass
    assert isinstance(spec, FaultSpec)
    assert spec.calls == 4 and spec.fired == 2
