"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + finite values; decode
consistency for decoder archs (prefill+decode == full forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import transformer as T
from repro.models.frontends import audio_frame_embeds, vision_patch_embeds
from repro.models.model import Model

ARCHS = cfgbase.list_configs()


def _train_batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    if cfg.frontend == "audio_stub":
        return {"frames": jnp.asarray(audio_frame_embeds(B, S, cfg.frontend_dim)),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                      dtype=jnp.int32)}
    batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)),
                                   dtype=jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   dtype=jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["vision_embeds"] = jnp.asarray(
            vision_patch_embeds(B, cfg.frontend_tokens, cfg.d_model))
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    model = Model.from_name(arch, reduced=True)
    cfg = model.cfg
    params = model.init(jax.random.key(0))
    ctx = T.Context(mesh=None, remat=False)
    loss, metrics = model.loss(params, _train_batch(cfg), ctx)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["ce_loss"]) > 0
    # grads exist and are finite for a sample leaf
    g = jax.grad(lambda p: model.loss(p, _train_batch(cfg), ctx)[0])(params)
    leaf = jax.tree.leaves(g)[0]
    assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if cfgbase.get_config(a).supports_decode])
def test_decode_matches_full_forward(arch):
    model = Model.from_name(arch, reduced=True)
    cfg = model.cfg
    ctx = T.Context(mesh=None, remat=False)
    params = model.init(jax.random.key(0))
    B, S, extra = 2, 16, 3
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S + extra)),
                       dtype=jnp.int32)
    batch_full = {"tokens": toks}
    batch_prefill = {"tokens": toks[:, :S]}
    if cfg.frontend == "vision_stub":
        v = jnp.asarray(vision_patch_embeds(B, cfg.frontend_tokens, cfg.d_model))
        batch_full["vision_embeds"] = v
        batch_prefill["vision_embeds"] = v
    logits_full, _ = model.prefill(params, batch_full, ctx)
    _, caches = model.prefill(params, batch_prefill, ctx,
                              cache_size=S + extra + cfg.frontend_tokens)
    base = S + (cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0)
    lg = None
    for i in range(extra):
        lg, caches = model.decode(params, toks[:, S + i:S + i + 1], caches,
                                  jnp.int32(base + i), ctx)
    err = float(jnp.abs(lg[:, 0] - logits_full[:, 0]).max())
    tol = 0.05 if cfg.num_experts else 2e-2   # MoE: capacity differs at B=2
    assert err <= tol, (arch, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_supported_shapes(arch):
    cfg = cfgbase.get_config(arch)
    for shape in cfgbase.SHAPES:
        if not cfg.shape_supported(shape):
            assert cfg.skip_reason(shape)
            continue
        specs = cfgbase.input_specs(cfg, shape)
        assert specs, (arch, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_materialized(arch):
    model = Model.from_name(arch, reduced=True)
    params = model.init(jax.random.key(0))
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    declared = model.cfg.param_count()
    # declared is an analytic estimate; must be within 15% of materialized
    assert abs(actual - declared) / actual < 0.15, (arch, actual, declared)


def test_long_500k_only_for_subquadratic():
    allowed = {a for a in ARCHS if cfgbase.get_config(a).subquadratic}
    assert allowed == {"rwkv6-7b", "zamba2-1.2b"}
    hub = cfgbase.get_config("hubert-xlarge")
    assert not hub.shape_supported("decode_32k")
