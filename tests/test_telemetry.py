"""Unified telemetry plane — registry, spans, events, exporters.

The contract under test:

  * the registry is thread-safe (12 concurrent writers lose no update) and
    ``reset()`` zeroes in place so cached handles stay valid;
  * log2 histograms report percentiles within one octave of numpy's answer
    WITHOUT retaining samples, with exact min/max;
  * the span tracer is a bounded ring buffer (memory never grows) whose
    Chrome-trace export is valid trace-event JSON with parent/child linkage;
  * one end-to-end ingest -> query -> backfill run lands series from all
    FIVE planes (ingest, match, query, arrangement, maintenance) in one
    ``telemetry.snapshot()`` — the paper's unified-plane claim, applied to
    our own observability;
  * the orphan sweeper collects crash-leaked spill dirs (and ONLY those);
  * a missing spill dir at load surfaces as a counter + structured event,
    not just a warning.
"""
import json
import math
import os
import threading
import time

import numpy as np
import pytest

from repro.core import telemetry
from repro.core.control_plane import ControlBus
from repro.core.maintenance import BackfillWorker, SpillGC
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.store import RETIRED_MARKER, SegmentStore
from repro.core.stream_processor import StreamProcessor
from repro.core.telemetry.metrics import Histogram, MetricsRegistry
from repro.core.telemetry.trace import Tracer
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import IngestPipeline


# ---------------------------------------------------------------------------
# Registry: thread safety, in-place reset, kind collision, enable gate
# ---------------------------------------------------------------------------

def test_registry_thread_safety_12_writers():
    """12 writer threads × 2000 increments each: no lost update on the
    counter, the gauge aggregate, or the histogram count — and get-or-create
    races resolve to ONE metric object per (name, labels)."""
    reg = MetricsRegistry()
    threads, per_thread = 12, 2000
    start = threading.Barrier(threads)
    errors = []

    def writer(i):
        try:
            start.wait()
            c = reg.counter("t_ops_total")
            g = reg.gauge("t_level")
            h = reg.histogram("t_lat_seconds")
            lc = reg.counter("t_labeled_total", labels={"worker": str(i % 3)})
            for k in range(per_thread):
                c.inc()
                g.inc(2)
                g.dec()
                h.observe(1e-4 * (k + 1))
                lc.inc()
        except Exception as e:  # noqa: BLE001 — surfaced in the main thread
            errors.append(e)

    ts = [threading.Thread(target=writer, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    assert reg.counter("t_ops_total").value == threads * per_thread
    assert reg.gauge("t_level").value == threads * per_thread
    assert reg.histogram("t_lat_seconds").count == threads * per_thread
    by_label = reg.snapshot()["counters"]["t_labeled_total"]
    assert sorted(s["labels"]["worker"] for s in by_label) == ["0", "1", "2"]
    assert sum(s["value"] for s in by_label) == threads * per_thread


def test_reset_zeroes_in_place_and_handles_stay_valid():
    reg = MetricsRegistry()
    c = reg.counter("r_total")
    h = reg.histogram("r_seconds")
    c.inc(5)
    h.observe(0.25)
    reg.reset()
    assert c.value == 0 and h.count == 0
    # the CACHED handle keeps working — same object the registry serves
    c.inc(3)
    assert reg.counter("r_total") is c
    assert reg.snapshot()["counters"]["r_total"][0]["value"] == 3


def test_kind_collision_rejected():
    reg = MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")


def test_set_enabled_gates_all_mutation():
    reg = MetricsRegistry()
    c, g, h = reg.counter("e_total"), reg.gauge("e_g"), reg.histogram("e_s")
    assert telemetry.enabled()
    telemetry.set_enabled(False)
    try:
        c.inc()
        g.set(7)
        h.observe(0.1)
        with telemetry.span("gated"):
            pass
        telemetry.emit("gated_event", plane="test")
    finally:
        telemetry.set_enabled(True)
    assert c.value == 0 and g.value == 0 and h.count == 0
    assert not any(e["kind"] == "gated_event" for e in telemetry.events.events())


# ---------------------------------------------------------------------------
# Histogram: percentile accuracy vs numpy, without sample retention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
def test_histogram_percentiles_within_one_octave_of_numpy(dist):
    """Log2 buckets guarantee any quantile is within ONE octave (factor of
    2) of the exact sample quantile — the design's accuracy bound."""
    rng = np.random.default_rng(42)
    if dist == "lognormal":
        samples = rng.lognormal(mean=-7.0, sigma=1.5, size=5000)
    elif dist == "uniform":
        samples = rng.uniform(1e-5, 1e-2, size=5000)
    else:
        # asymmetric split so no tested quantile falls in the empty gap
        # between modes (there numpy interpolates into no-data territory
        # and no histogram can follow)
        samples = np.concatenate([rng.normal(2e-4, 2e-5, 3000),
                                  rng.normal(5e-2, 5e-3, 2000)]).clip(1e-6)
    h = Histogram("acc_seconds", {})
    for v in samples:
        h.observe(float(v))
    for q in (0.50, 0.90, 0.99):
        est = h.quantile(q)
        true = float(np.quantile(samples, q))
        assert abs(math.log2(est / true)) <= 1.0, \
            f"{dist} p{int(q * 100)}: est {est:.3g} vs true {true:.3g}"
    assert h.quantile(0.0) == float(samples.min())   # clamped to exact min
    assert h.quantile(1.0) == float(samples.max())   # and exact max
    assert h.count == len(samples)
    assert h.sum == pytest.approx(samples.sum(), rel=1e-9)


def test_histogram_bucket_edges():
    h = Histogram("edge_seconds", {})
    # exact powers of two land in the bucket they OPEN: [2^e, 2^(e+1))
    i = h.bucket_index(2.0 ** -10)
    lo, hi = h.bucket_bounds(i)
    assert lo == 2.0 ** -10 and hi == 2.0 ** -9
    # out-of-span values clamp to the edge buckets, never raise
    assert h.bucket_index(0.0) == 0
    assert h.bucket_index(1e-30) == 0
    assert h.bucket_index(1e9) == len(h._counts) - 1


# ---------------------------------------------------------------------------
# Tracer: ring-buffer bound, Chrome-trace validity, parent linkage
# ---------------------------------------------------------------------------

def test_span_ring_buffer_is_bounded():
    tr = Tracer(capacity=32)
    for i in range(100):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 32
    assert tr.dropped == 68
    # newest spans won (the tail of the timeline is what survives)
    assert [ev["name"] for ev in tr.spans()][-1] == "s99"
    doc = tr.export_chrome_trace()
    assert doc["otherData"]["spans_dropped"] == 68


def test_chrome_trace_export_is_valid_trace_event_json():
    tr = Tracer()
    with tr.span("outer", cat="test", phase="setup"):
        time.sleep(0.001)
        with tr.span("inner", cat="test"):
            time.sleep(0.001)
    doc = json.loads(json.dumps(tr.export_chrome_trace()))  # JSON round-trip
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X"                      # complete events
        assert isinstance(ev["ts"], float) and ev["ts"] >= 0.0
        assert isinstance(ev["dur"], float) and ev["dur"] > 0.0
        assert ev["pid"] == os.getpid()
        assert isinstance(ev["tid"], int)
        assert ev["cat"] == "test"
    inner, outer = evs  # inner exits first — ring order is completion order
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert inner["args"]["parent"] == outer["args"]["id"]
    assert "parent" not in outer["args"]            # root span
    assert outer["args"]["phase"] == "setup"        # span args survive export
    # temporal containment: the child ran inside the parent
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0


def test_prometheus_text_renders_and_histograms_are_cumulative():
    reg = MetricsRegistry()
    reg.counter("p_total", help='say "hi"\nok').inc(4)
    reg.counter("p_labeled_total", labels={"path": "a"}).inc(1)
    reg.counter("p_labeled_total", labels={"path": "b"}).inc(2)
    h = reg.histogram("p_seconds", help="latency")
    for v in (1e-4, 2e-4, 1e-3, 1e-2):
        h.observe(v)
    text = telemetry.prometheus_text(reg)
    assert '# HELP p_total say \\"hi\\"\\nok' in text
    assert "# TYPE p_total counter" in text
    assert "p_total 4" in text
    assert 'p_labeled_total{path="a"} 1' in text
    assert 'p_labeled_total{path="b"} 2' in text
    assert "# TYPE p_seconds histogram" in text
    assert 'p_seconds_bucket{le="+Inf"} 4' in text
    assert "p_seconds_count 4" in text
    # cumulative bucket counts are monotone nondecreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("p_seconds_bucket")]
    assert cums == sorted(cums) and cums[-1] == 4


# ---------------------------------------------------------------------------
# End to end: one snapshot carries series from all five planes
# ---------------------------------------------------------------------------

def make_world(tmp_path, *, num_records=4000, segment_size=1000, hold_back=0):
    spec = WorkloadSpec(num_records=num_records, ultra_rate=1e-3,
                        high_rate=1e-2, seed=13, text_width=256)
    gen = LogGenerator(spec)
    rules = [Rule(i, t.term, t.term, fields=(t.fieldname,))
             for i, t in enumerate(spec.planted)]
    # one DENSE rule (matches most records): too dense for seal-time
    # postings, so querying it exercises the bitmap-scan class and the
    # shared arrangement plane
    rules.append(Rule(len(rules), "dense_a", "a", fields=("content1",)))
    full = RuleSet(tuple(rules))
    initial = full.without_ids([hold_back])
    bus, ostore = ControlBus(), ObjectStore()
    proc = StreamProcessor(compile_bundle(initial, spec.content_fields),
                           bus=bus, store=ostore)
    store = SegmentStore(segment_size=segment_size, root=tmp_path)
    updater = MatcherUpdater(ostore, bus, spec.content_fields,
                             initial=initial)
    IngestPipeline(gen, store, proc).run(batch_size=1000)
    mapper = QueryMapper(initial, version_id=0)
    engine = QueryEngine(store, mapper=mapper)
    return dict(spec=spec, gen=gen, full=full, bus=bus, ostore=ostore,
                proc=proc, store=store, updater=updater, mapper=mapper,
                engine=engine, late=spec.planted[hold_back])


FIVE_PLANE_SERIES = {
    "ingest": "fluxsieve_ingest_records_total",
    "match": "fluxsieve_match_dispatch_total",
    "query": "fluxsieve_query_total",
    "arrangement": "fluxsieve_arrangement_uploads_total",
    "maintenance": "fluxsieve_maintenance_segments_backfilled_total",
}


def test_end_to_end_snapshot_covers_all_five_planes(tmp_path):
    """Ingest -> query -> late-rule backfill, then ONE snapshot: every
    plane reported, the trace timeline has spans from ingest, match, query
    AND maintenance, and the event log saw epoch publishes, manifest
    commits, and lease acquisitions."""
    telemetry.reset()
    w = make_world(tmp_path)
    # query (fluxsieve path -> arrangement uploads)
    late = w["late"]
    q = Query(terms=((late.fieldname, late.term),), mode="count")
    h = w["updater"].submit(w["full"], asynchronous=False)
    assert h.published, h.error
    w["proc"].poll_updates()
    w["mapper"].notify(w["full"], version_id=w["proc"].active_version_id)
    worker = BackfillWorker(w["store"], w["bus"], w["ostore"])
    rep = worker.run_until_converged()
    assert rep.segments_backfilled > 0
    res = w["engine"].execute(q, path="fluxsieve")
    assert res.count == w["gen"].true_count(late)
    # the dense rule has no seal-time postings -> bitmap-scan class ->
    # shared-arrangement uploads + the stacked device dispatch
    q_dense = Query(terms=(("content1", "a"),), mode="copy")
    r_dense = w["engine"].execute(q_dense, path="fluxsieve")
    assert r_dense.count == w["engine"].execute(q_dense,
                                               path="full_scan").count
    assert "bitmap" in r_dense.path_classes, r_dense.path_classes

    snap = telemetry.snapshot()
    counters = snap["counters"]
    for plane, name in FIVE_PLANE_SERIES.items():
        assert name in counters, f"{plane} plane missing from snapshot"
        assert sum(s["value"] for s in counters[name]) > 0, \
            f"{plane} plane series {name} is zero"
    # ingest stage latencies landed as histograms
    stages = {s["labels"]["stage"]
              for s in snap["histograms"]["fluxsieve_ingest_stage_seconds"]
              if s["count"]}
    assert {"generate", "dispatch", "store"} <= stages
    # the trace timeline saw multiple planes
    cats = {ev["cat"] for ev in telemetry.export_chrome_trace()["traceEvents"]}
    assert {"ingest", "match", "query", "maintenance"} <= cats
    # structured events from the storage + maintenance planes
    kinds = {e["kind"] for e in snap["events"]}
    assert {"epoch_publish", "manifest_commit"} <= kinds
    # the exporters accept the real registry end to end
    text = telemetry.prometheus_text()
    assert "# TYPE fluxsieve_query_latency_seconds histogram" in text
    json.dumps(snap, default=str)   # snapshot is JSON-able


# ---------------------------------------------------------------------------
# Satellite: orphan-dir sweep (crash between spill and manifest commit)
# ---------------------------------------------------------------------------

def _age(path, seconds):
    old = time.time() - seconds
    os.utime(path, (old, old))


def test_spillgc_sweeps_orphan_dirs(tmp_path):
    """A ``segment-*`` dir absent from the root manifest and never
    tombstoned (crash between spill and manifest registration) is swept
    once past the generous horizon; live and young dirs survive."""
    w = make_world(tmp_path)
    n_live = len(w["store"].segments)
    assert n_live >= 2
    # fabricate two orphans: one old (collectable), one fresh (in-flight)
    old_orphan = tmp_path / "segment-7001"
    old_orphan.mkdir()
    (old_orphan / "content.npy").write_bytes(b"x" * 512)
    _age(old_orphan, 7200)
    fresh_orphan = tmp_path / "segment-7002"
    fresh_orphan.mkdir()
    (fresh_orphan / "content.npy").write_bytes(b"y" * 512)

    orphans = telemetry.metrics.REGISTRY.counter(
        "fluxsieve_maintenance_gc_orphans_deleted_total")
    before = orphans.value
    rep = SpillGC(w["store"], orphan_grace_s=3600.0).run_cycle()
    assert rep.orphans_deleted == 1
    assert rep.dirs_deleted == 0
    assert rep.bytes_deleted == 512
    assert rep.dirs_kept_grace == 1         # the fresh orphan waits
    assert not old_orphan.exists()
    assert fresh_orphan.exists()
    assert len(w["store"].segments) == n_live   # live segments untouched
    assert orphans.value == before + 1
    ev = [e for e in telemetry.events.events(kind="gc_sweep")
          if e.get("orphans_deleted")]
    assert ev and ev[-1]["orphans_deleted"] == 1

    # reload sanity: the sweep removed nothing the manifest knows about
    reopened = SegmentStore.load(tmp_path)
    assert reopened.num_records == w["store"].num_records


def test_spillgc_never_sweeps_pre_manifest_stores(tmp_path):
    """Without an on-disk root manifest the unregistered dirs ARE the
    data — the orphan sweep must refuse to run."""
    root = tmp_path / "pre_manifest"
    root.mkdir()
    d = root / "segment-0"
    d.mkdir()
    (d / "content.npy").write_bytes(b"z" * 64)
    _age(d, 7200)
    store = SegmentStore(root=root)     # fresh store: manifest never written
    assert not store.manifest.path.exists()
    rep = SpillGC(store, orphan_grace_s=0.0).run_cycle()
    assert rep.orphans_deleted == 0
    assert d.exists()


def test_spillgc_still_collects_tombstoned_dirs(tmp_path):
    """The RETIRED path is unchanged by the orphan sweep: a drained
    tombstoned dir collects under its own (short) grace window."""
    w = make_world(tmp_path)
    seg = w["store"].segments[0]
    assert w["store"].retire_segments([seg])
    marker = seg.path / RETIRED_MARKER
    assert marker.exists()
    _age(marker, 120)
    rep = SpillGC(w["store"], grace_s=60.0).run_cycle()
    assert rep.dirs_deleted == 1
    assert rep.orphans_deleted == 0
    assert not seg.path.exists()


# ---------------------------------------------------------------------------
# Satellite: missing spill dir at load -> counter + structured event
# ---------------------------------------------------------------------------

def test_missing_spill_dir_records_event_and_counter(tmp_path):
    import shutil
    w = make_world(tmp_path)
    victim = w["store"].segments[0]
    shutil.rmtree(victim.path)
    missing = telemetry.metrics.REGISTRY.counter(
        "fluxsieve_store_segments_missing_total")
    before = missing.value
    with pytest.warns(RuntimeWarning, match="missing"):
        reopened = SegmentStore.load(tmp_path)
    assert len(reopened.segments) == len(w["store"].segments) - 1
    assert missing.value == before + 1
    evs = telemetry.events.events(kind="segment_missing")
    assert evs and evs[-1]["dir"] == victim.path.name
