import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; suite must collect without it
from hypothesis import given, settings, strategies as st

from repro.core.records import RecordBatch, decode_texts, encode_texts


def test_encode_decode_round_trip():
    texts = ["hello world", "", "x" * 600, "unicode ✓ stripped"]
    data = encode_texts(texts, 64)
    assert data.shape == (4, 64)
    out = decode_texts(data)
    assert out[0] == "hello world"
    assert out[1] == ""
    assert out[2] == "x" * 64          # truncated to width


@given(st.lists(st.text(alphabet=st.characters(min_codepoint=32,
                                               max_codepoint=126),
                        max_size=40), min_size=1, max_size=8))
@settings(max_examples=40, deadline=None)
def test_encode_decode_property(texts):
    out = decode_texts(encode_texts(texts, 64))
    for t, o in zip(texts, out):
        assert o == t[:64].rstrip("\x00")


def test_batch_invariants(small_batch):
    assert len(small_batch) == 6
    assert small_batch.text_fields == ("content1", "content2")
    assert "timestamp" in small_batch.scalar_fields
    with pytest.raises(ValueError):
        RecordBatch({"a": np.zeros(3), "b": np.zeros(4)})


def test_batch_select_slice_concat(small_batch):
    sel = small_batch.select(np.asarray([0, 2]))
    assert len(sel) == 2
    sl = small_batch.slice(1, 4)
    assert len(sl) == 3
    cat = RecordBatch.concat([sel, sl])
    assert len(cat) == 5
    assert cat.columns["timestamp"].tolist() == [0, 2, 1, 2, 3]


def test_with_column(small_batch):
    b2 = small_batch.with_column("extra", np.ones(6, np.int32))
    assert "extra" in b2.columns
    assert "extra" not in small_batch.columns
