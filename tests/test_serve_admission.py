"""Admission-control property tests (docs/SERVING.md): the token bucket
never admits above ``burst + rate * T`` over ANY window ``T`` for ANY
arrival pattern, per-client buckets are independent, and full-bucket
eviction at high cardinality never changes an admission decision.

Every test drives an injected deterministic clock — no sleeps, no wall
time.  The deterministic battery always runs; hypothesis variants ride
along when the optional dev dependency is installed."""
import random

import pytest

from repro.serve.frontend import AdmissionController, TokenBucket

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # optional dev dep; see pyproject
    HAVE_HYPOTHESIS = False


class FakeClock:
    """Injected monotonic clock: tests advance it explicitly."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        assert dt >= 0.0
        self.t += dt


EPS = 1e-9


def drive(bucket, clock, pattern):
    """Replay (dt, attempts) steps; assert the window invariant after
    EVERY attempt, not just at the end (a mid-run overshoot that later
    averages out is still a violation)."""
    t0, admitted = clock.t, 0
    for dt, attempts in pattern:
        clock.advance(dt)
        for _ in range(attempts):
            if bucket.try_acquire():
                admitted += 1
            budget = bucket.burst + bucket.rate * (clock.t - t0)
            assert admitted <= budget + EPS, (
                f"admitted {admitted} > budget {budget} at t={clock.t}")
    return admitted


# -- token bucket: exact arithmetic ------------------------------------------
def test_burst_then_refill_exact():
    clock = FakeClock()
    b = TokenBucket(rate=2.0, burst=3.0, clock=clock)
    assert [b.try_acquire() for _ in range(4)] == [True, True, True, False]
    clock.advance(0.5)           # +1 token
    assert b.try_acquire()
    assert not b.try_acquire()
    clock.advance(100.0)         # refill caps at burst, not rate*dt
    assert sum(b.try_acquire() for _ in range(10)) == 3


def test_fractional_rate_accumulates():
    clock = FakeClock()
    b = TokenBucket(rate=0.5, burst=1.0, clock=clock)
    assert b.try_acquire()
    clock.advance(1.0)           # half a token: still denied
    assert not b.try_acquire()
    clock.advance(1.0)           # the other half
    assert b.try_acquire()


def test_full_is_exactly_fresh_equivalence():
    clock = FakeClock()
    b = TokenBucket(rate=1.0, burst=2.0, clock=clock)
    assert b.full()              # starts full
    b.try_acquire()
    assert not b.full()
    clock.advance(1.0)           # refill-at-now would restore burst
    assert b.full()
    # a full bucket admits exactly what a fresh one would
    fresh = TokenBucket(rate=1.0, burst=2.0, clock=clock)
    got = [b.try_acquire() for _ in range(4)]
    want = [fresh.try_acquire() for _ in range(4)]
    assert got == want == [True, True, False, False]


# -- token bucket: the window invariant over adversarial patterns ------------
@pytest.mark.parametrize("seed", range(20))
def test_never_exceeds_budget_random_patterns(seed):
    rng = random.Random(seed)
    clock = FakeClock(rng.uniform(0, 1000))
    rate = rng.choice([0.1, 0.5, 1.0, 5.0, 100.0])
    burst = rng.choice([1.0, 2.0, rate, 10.0])
    b = TokenBucket(rate=rate, burst=burst, clock=clock)
    pattern = [(rng.choice([0.0, 1e-6, 0.01, 0.2, 3.0]),
                rng.randint(0, 20)) for _ in range(200)]
    drive(b, clock, pattern)


def test_burst_pattern_admits_full_budget():
    """The invariant is tight: a greedy client gets EXACTLY its budget."""
    clock = FakeClock()
    b = TokenBucket(rate=4.0, burst=2.0, clock=clock)
    # first hammer at t=0.25 drains the (capped) burst of 2; each of the
    # 39 later steps refills exactly 0.25s * 4/s = 1 token
    admitted = drive(b, clock, [(0.25, 50) for _ in range(40)])
    assert admitted == 2 + 39


# -- controller: per-client independence and bounded state -------------------
def test_per_client_buckets_independent():
    clock = FakeClock()
    ac = AdmissionController(rate_per_client=1.0, burst=3.0, clock=clock)
    while ac.admit("flooder"):   # drain one client completely
        pass
    assert sum(ac.admit("calm") for _ in range(10)) == 3  # untouched burst


def test_controller_window_invariant_many_clients():
    rng = random.Random(7)
    clock = FakeClock()
    ac = AdmissionController(rate_per_client=2.0, burst=2.0, clock=clock)
    t0, admitted = clock.t, {}
    for _ in range(2000):
        clock.advance(rng.choice([0.0, 0.001, 0.05, 0.7]))
        cid = f"c{rng.randint(0, 9)}"
        if ac.admit(cid):
            admitted[cid] = admitted.get(cid, 0) + 1
        budget = ac.burst + ac.rate * (clock.t - t0)
        for cid, n in admitted.items():
            assert n <= budget + EPS, f"{cid}: {n} > {budget}"


def test_full_bucket_eviction_bounds_table():
    clock = FakeClock()
    ac = AdmissionController(rate_per_client=10.0, burst=1.0, clock=clock,
                             max_clients=64)
    for i in range(10_000):
        clock.advance(0.2)       # every bucket refills to full between ids
        assert ac.admit(f"user-{i}")
    assert ac.num_clients <= 64 + 1  # table stays bounded, not 10k


def test_eviction_preserves_admission_decisions():
    """Evicting a FULL bucket is invisible: the re-created bucket admits
    exactly what the evicted one would have."""
    clock = FakeClock()
    ac = AdmissionController(rate_per_client=1.0, burst=2.0, clock=clock,
                             max_clients=4)
    assert ac.admit("a")         # a: 1 token left
    clock.advance(10.0)          # a refills to full -> evictable
    for i in range(8):           # force evictions past max_clients
        ac.admit(f"filler-{i}")
    # whether or not "a" was evicted, it must admit a full burst now
    assert [ac.admit("a") for _ in range(3)] == [True, True, False]


def test_nonfull_buckets_survive_eviction():
    clock = FakeClock()
    ac = AdmissionController(rate_per_client=0.001, burst=1.0, clock=clock,
                             max_clients=2)
    assert ac.admit("draining")  # nearly-empty bucket: NOT evictable
    ac.admit("x")
    ac.admit("y")                # triggers eviction pass at the cap
    assert not ac.admit("draining")  # its drained state was preserved


# -- hypothesis variants (optional dev dep) ----------------------------------
if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(
        st.floats(min_value=0.0, max_value=5.0, allow_nan=False),
        st.integers(min_value=0, max_value=10)), max_size=100),
        st.floats(min_value=0.01, max_value=100.0, allow_nan=False),
        st.floats(min_value=1.0, max_value=50.0, allow_nan=False))
    def test_hyp_bucket_never_exceeds_budget(pattern, rate, burst):
        clock = FakeClock()
        drive(TokenBucket(rate=rate, burst=burst, clock=clock),
              clock, pattern)

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.tuples(
        st.floats(min_value=0.0, max_value=2.0, allow_nan=False),
        st.sampled_from(["a", "b", "c"])), max_size=200))
    def test_hyp_controller_per_client_budget(steps):
        clock = FakeClock()
        ac = AdmissionController(rate_per_client=3.0, burst=2.0,
                                 clock=clock)
        t0, admitted = clock.t, {}
        for dt, cid in steps:
            clock.advance(dt)
            if ac.admit(cid):
                admitted[cid] = admitted.get(cid, 0) + 1
            budget = ac.burst + ac.rate * (clock.t - t0)
            assert all(n <= budget + EPS for n in admitted.values())
