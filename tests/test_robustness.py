"""Chaos suite: crash-safe ingest (WAL replay, exactly-once), graceful
match-path degradation (breaker -> oracle lane, quarantine), query-plane
degradation (shard deadlines/faults -> partial results), plan-time
retention visibility, and control-plane fault handling.

Every scenario drives REAL plane code through the deterministic fault
registry (core/faults.py); the kill-point sweep aborts the process state
mid-loop with ``InjectedCrash`` (a BaseException no recovery handler may
swallow), then "restarts" by reloading the store from disk."""
import os

import numpy as np
import pytest

from repro.core import faults, telemetry
from repro.core.control_plane import ControlBus
from repro.core.faults import CircuitBreaker, InjectedCrash
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.store import RETENTION_CUTOFF, SegmentStore
from repro.core.stream_processor import (ENRICH_COLUMN, StreamProcessor)
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import (QUARANTINE_DIRNAME, IngestPipeline,
                                 IngestWAL)

SPEC = WorkloadSpec(num_records=2400, seed=13, text_width=256,
                    ultra_rate=2e-3, high_rate=1e-2)
BATCH = 400          # 6 batches per run
SEG = 800            # 3 segments per run


@pytest.fixture(autouse=True)
def _fault_isolation():
    faults.reset()
    yield
    faults.reset()
    if os.environ.get(faults.ENV_VAR):
        faults.load_profile(os.environ[faults.ENV_VAR])


@pytest.fixture(scope="module")
def bundle():
    rs = RuleSet(tuple(Rule(i, t.term, t.term, fields=(t.fieldname,))
                       for i, t in enumerate(SPEC.planted)))
    return rs, compile_bundle(rs, SPEC.content_fields)


def make_pipeline(root, bundle, *, wal=True):
    _, eb = bundle
    store = SegmentStore(segment_size=SEG, root=root,
                         index_fields=SPEC.content_fields)
    pipe = IngestPipeline(LogGenerator(SPEC), store, StreamProcessor(eb),
                          wal=wal)
    return pipe, store


def sealed_timestamps(store):
    parts = [np.asarray(s.column("timestamp")) for s in store.segments]
    if not parts:
        return np.array([], np.int64)
    return np.sort(np.concatenate(parts))


EXPECTED_TS = np.arange(SPEC.num_records, dtype=np.int64) * 1000


# -- crash-safe ingest --------------------------------------------------------
# (site, after): the Nth traversal of each site the simulated kill hits.
# Together these cover every stage of the double-buffered loop: journal
# write, dispatch, D2H finalize, store append, seal spill, manifest commit.
KILL_POINTS = [
    ("ingest.wal_append", 0), ("ingest.wal_append", 2),
    ("match.dispatch", 2),
    ("match.d2h", 2),
    ("ingest.append", 0), ("ingest.append", 2),
    ("store.spill", 1),
    ("store.manifest_commit", 0), ("store.manifest_commit", 1),
]


@pytest.mark.parametrize("site,after", KILL_POINTS)
def test_kill_point_sweep_exactly_once(tmp_path, bundle, site, after):
    root = tmp_path / "segments"
    pipe, store = make_pipeline(root, bundle)
    faults.inject(site, "crash", after=after)
    with pytest.raises(InjectedCrash):
        pipe.run(batch_size=BATCH)
    faults.reset()

    # restart: every in-memory object is abandoned, disk is the only truth
    store2 = SegmentStore.load(root)
    pipe2 = IngestPipeline(LogGenerator(SPEC), store2,
                           StreamProcessor(bundle[1]), wal=True)
    resume = pipe2.recover()
    assert store2.sealed_rows <= resume <= SPEC.num_records
    pipe2.run(batch_size=BATCH, start=resume)

    # exactly-once: timestamps are absolute-row-indexed, so the sealed set
    # must be precisely {row * 1000} — no loss, no duplication
    assert np.array_equal(sealed_timestamps(store2), EXPECTED_TS)
    assert store2.sealed_rows == SPEC.num_records
    assert IngestWAL(root).entries() == []    # journal fully reclaimed


def test_recovery_emits_replay_event(tmp_path, bundle):
    root = tmp_path / "segments"
    pipe, _ = make_pipeline(root, bundle)
    faults.inject("store.manifest_commit", "crash", after=1)
    with pytest.raises(InjectedCrash):
        pipe.run(batch_size=BATCH)
    faults.reset()
    store2 = SegmentStore.load(root)
    pipe2 = IngestPipeline(LogGenerator(SPEC), store2,
                           StreamProcessor(bundle[1]), wal=True)
    n_before = len(telemetry.events.events(kind="wal_replay"))
    resume = pipe2.recover()
    evs = telemetry.events.events(kind="wal_replay")
    assert len(evs) == n_before + 1
    assert evs[-1]["records"] > 0 and evs[-1]["resume"] == resume


def test_ingest_cli_restart_reopens_committed_store(tmp_path):
    """The launcher must REOPEN a populated root on restart, not build a
    fresh store over it — a fresh SegmentStore starts an empty manifest
    whose first commit disowns every already-committed segment (the WAL
    then replays only its own window: rows sealed before the journal's
    oldest entry are silently lost)."""
    from repro.launch.ingest import main as ingest_main
    root = tmp_path / "store"
    argv = ["--records", "2400", "--rules", "8", "--segment-size", "800",
            "--batch-size", "400", "--store", str(root), "--wal"]
    # kill at the 3rd manifest commit: by then seals 1-2 are durable and
    # the journal has been truncated behind their watermark, so recovery
    # MUST adopt the committed segments — the WAL alone can't rebuild them
    faults.inject("store.manifest_commit", "crash", after=2)
    with pytest.raises(InjectedCrash):
        ingest_main(argv)
    faults.reset()
    ingest_main(argv)
    store = SegmentStore.load(root)
    assert np.array_equal(sealed_timestamps(store),
                          np.arange(2400, dtype=np.int64) * 1000)
    assert store.sealed_rows == 2400
    assert IngestWAL(root).entries() == []


def test_wal_requires_rooted_store_and_enrich_mode(bundle):
    _, eb = bundle
    with pytest.raises(ValueError, match="rooted"):
        IngestPipeline(LogGenerator(SPEC), SegmentStore(segment_size=SEG),
                       StreamProcessor(eb), wal=True)


def test_wal_rejects_filter_mode(tmp_path, bundle):
    _, eb = bundle
    store = SegmentStore(segment_size=SEG, root=tmp_path / "s")
    with pytest.raises(ValueError, match="enrich"):
        IngestPipeline(LogGenerator(SPEC), store,
                       StreamProcessor(eb, mode="filter"), wal=True)


# -- graceful match-path degradation ------------------------------------------
def test_breaker_routes_to_oracle_lane_and_recovers(bundle):
    _, eb = bundle
    gen = LogGenerator(SPEC)
    batches = [gen.batch(i * 200, 200) for i in range(8)]
    clean = StreamProcessor(eb)
    expected = [clean.process(b).columns[ENRICH_COLUMN] for b in batches]

    breaker = CircuitBreaker(site="t.equiv", failure_threshold=2,
                             probe_interval=2)
    proc = StreamProcessor(eb, retry_limit=0, retry_backoff_s=0.0,
                           breaker=breaker)
    faults.inject("match.dispatch", "error", times=4)
    states = []
    for b, exp in zip(batches, expected):
        out = proc.process(b)
        # degraded lane output is bit-identical to the healthy run
        assert np.array_equal(out.columns[ENRICH_COLUMN], exp)
        states.append(breaker.state)
    # fail, trip, fallback, failed probe, fallback, failed probe (last
    # injected error), fallback, successful probe -> closed
    assert states == ["closed", "open", "open", "open",
                      "open", "open", "open", "closed"]
    assert breaker.trips == 1
    assert proc.stats.batches == 8


def test_both_lanes_down_quarantines_and_stream_flows(tmp_path, bundle):
    root = tmp_path / "segments"
    pipe, store = make_pipeline(root, bundle)
    faults.inject("match.dispatch", "error")            # primary always down
    faults.inject("match.fallback", "error", times=2)   # oracle down briefly
    pipe.run(batch_size=BATCH)
    faults.reset()

    # first two batches failed BOTH lanes -> dead-lettered, rest flowed
    assert pipe.quarantined == 2 * BATCH
    assert store.num_records == SPEC.num_records - 2 * BATCH
    assert np.array_equal(sealed_timestamps(store), EXPECTED_TS[2 * BATCH:])
    # the durability watermark covers the quarantined gap...
    assert store.sealed_rows == SPEC.num_records
    qdir = root / QUARANTINE_DIRNAME
    assert len(list(qdir.glob("batch-*.npy"))) == 2
    assert any(e["records"] == BATCH
               for e in telemetry.events.events(kind="quarantine"))

    # ...so a restart neither replays nor regenerates the dead letters
    store2 = SegmentStore.load(root)
    pipe2 = IngestPipeline(LogGenerator(SPEC), store2,
                           StreamProcessor(bundle[1]), wal=True)
    assert pipe2.recover() == SPEC.num_records


# -- query-plane degradation --------------------------------------------------
@pytest.fixture(scope="module")
def world(bundle, tmp_path_factory):
    rs, eb = bundle
    root = tmp_path_factory.mktemp("world")
    store = SegmentStore(segment_size=SEG, root=root,
                         index_fields=SPEC.content_fields)
    gen = LogGenerator(SPEC)
    IngestPipeline(gen, store, StreamProcessor(eb)).run(batch_size=BATCH)
    return gen, store, QueryMapper(rs)


def _high_query():
    t = next(p for p in SPEC.planted if p.rate == SPEC.high_rate)
    return t, Query(terms=((t.fieldname, t.term),), mode="count")


def test_shard_fault_yields_partial_result(world):
    gen, store, mapper = world
    qe = QueryEngine(store, mapper=mapper, shards=2, shard_deadline_s=10.0)
    try:
        t, q = _high_query()
        full = qe.execute(q)
        assert not full.partial and full.coverage == 1.0
        assert full.count == gen.true_count(t)

        faults.inject("query.shard", "error", shard=0)
        res = qe.execute(q)
        assert res.partial
        assert 0 < res.segments_failed < res.segments_total
        assert 0.0 < res.coverage < 1.0
        assert res.count <= full.count
        assert len(res.failed_segment_ids) == res.segments_failed
        assert any(e["failed"] == res.segments_failed for e in
                   telemetry.events.events(kind="query_partial"))

        faults.reset()                   # fault clears -> full answers again
        again = qe.execute(q)
        assert not again.partial and again.count == full.count
    finally:
        qe.close()


def test_shard_deadline_yields_partial_result(world):
    gen, store, mapper = world
    qe = QueryEngine(store, mapper=mapper, shards=2, shard_deadline_s=0.2)
    try:
        t, q = _high_query()
        faults.inject("query.shard", "stall", delay=1.0, shard=1)
        res = qe.execute(q)
        assert res.partial and res.segments_failed >= 1
        assert res.coverage < 1.0
    finally:
        faults.reset()
        qe.close()


def test_shard_crash_is_not_absorbed_into_partial(world):
    _, store, mapper = world
    qe = QueryEngine(store, mapper=mapper, shards=2)
    try:
        _, q = _high_query()
        faults.inject("query.shard", "crash", shard=0)
        with pytest.raises(InjectedCrash):
            qe.execute(q)
    finally:
        faults.reset()
        qe.close()


# -- retention visibility at plan time ----------------------------------------
def test_retention_cutoff_visible_before_compaction(tmp_path, bundle):
    rs, eb = bundle
    store = SegmentStore(segment_size=SEG, root=tmp_path / "segments",
                         index_fields=SPEC.content_fields)
    IngestPipeline(LogGenerator(SPEC), store,
                   StreamProcessor(eb)).run(batch_size=BATCH)
    qe = QueryEngine(store, mapper=QueryMapper(rs))
    t, q = _high_query()

    pre = qe.execute(Query(terms=q.terms, mode="copy"))
    cutoff = 1200 * 1000                 # mid segment 1: seg0 fully expired
    expect = int((pre.records.columns["timestamp"] >= cutoff).sum())
    assert 0 < expect < pre.count

    # the retention plane stamps segments long before compaction runs
    segs = list(store.segments)
    segs[0].apply_update(meta_updates={RETENTION_CUTOFF: cutoff})
    segs[1].apply_update(meta_updates={RETENTION_CUTOFF: cutoff})

    # expired rows are invisible on EVERY logical path, counts and copies
    for path in ("fluxsieve", "text_index", "full_scan"):
        assert qe.execute(q, path=path).count == expect, path
    post = qe.execute(Query(terms=q.terms, mode="copy"))
    assert post.count == expect
    assert (post.records.columns["timestamp"] >= cutoff).all()

    # fully-expired segment classifies PRUNED (zero I/O); the straddler
    # refuses the metadata count shortcut (it would count expired rows)
    plan = qe.plan(q)
    classes = {task.seg.segment_id: task.path_class for task in plan.tasks}
    assert classes[segs[0].segment_id] == "pruned"
    assert classes[segs[1].segment_id] != "meta_count"
    assert next(task.cutoff for task in plan.tasks
                if task.seg.segment_id == segs[1].segment_id) == cutoff


# -- control-plane robustness -------------------------------------------------
def test_updater_nacks_uncompilable_rule_individually(bundle):
    rs, eb = bundle
    bus, ostore = ControlBus(), ObjectStore()
    upd = MatcherUpdater(ostore, bus, SPEC.content_fields, initial=rs)
    proc = StreamProcessor(eb, bus=bus, store=ostore)

    # passes construction (4096 literals of 63 bytes) but its trie upper
    # bound blows past the largest DFA state bucket at compile time
    monster = Rule(5, "monster", "X" * 60 + "[a-p][a-p][a-p]",
                   fields=("content1",))
    good = Rule(4, "goodlit", "BRANDNEWLITERAL", fields=("content1",))
    handle = upd.submit(rs.with_rules([monster, good]), asynchronous=False)

    assert handle.published, handle.error
    assert set(handle.rejected) == {"monster"}
    assert "state estimate" in handle.rejected["monster"]
    names = {r.name for r in upd.current_ruleset.rules}
    assert "goodlit" in names and "monster" not in names
    assert any(e["rule"] == "monster"
               for e in telemetry.events.events(kind="rule_rejected"))

    # the rest of the rollout sails: the processor swaps to the clean set
    assert proc.poll_updates() == 1
    assert proc.num_rules == rs.num_rules + 1

    # a submit where EVERY change is rejected publishes nothing
    monster2 = Rule(6, "monster2", "Y" * 60 + "[a-p][a-p][a-p]",
                    fields=("content1",))
    h2 = upd.submit(upd.current_ruleset.with_rules([monster2]),
                    asynchronous=False)
    assert not h2.published
    assert "every submitted change was rejected" in h2.error

    # control-topology spans/histograms observed submit + poll latencies
    assert telemetry.histogram("fluxsieve_updater_compile_seconds").count >= 1
    assert telemetry.histogram("fluxsieve_updater_publish_seconds").count >= 1
    assert telemetry.histogram("fluxsieve_match_poll_seconds").count >= 1


def test_bus_drop_dup_reorder_and_at_least_once():
    bus = ControlBus()
    for i in range(3):
        bus.publish("t", {"i": i})

    faults.inject("bus.deliver", "drop", times=1, topic="t")
    assert bus.poll("t", "g") == []          # delivery delayed, not lost
    msgs = bus.poll("t", "g")                # uncommitted window redelivers
    assert [m.value["i"] for m in msgs] == [0, 1, 2]

    faults.inject("bus.deliver", "dup", times=1, topic="t")
    msgs = bus.poll("t", "g2")
    assert [m.value["i"] for m in msgs] == [0, 1, 2, 0, 1, 2]

    faults.inject("bus.deliver", "reorder", times=1, topic="t")
    msgs = bus.poll("t", "g3")
    assert [m.value["i"] for m in msgs] == [2, 1, 0]

    # a filter on another topic leaves this one untouched
    faults.inject("bus.deliver", "drop", topic="other")
    assert [m.value["i"] for m in bus.poll("t", "g4")] == [0, 1, 2]


def test_suppressed_errors_are_counted():
    c = telemetry.counter("fluxsieve_errors_suppressed_total",
                          labels={"site": "test.site"})
    before = c.value
    telemetry.suppressed("test.site", ValueError("boom"))
    assert c.value == before + 1
    evs = telemetry.events.events(kind="error_suppressed")
    assert any(e.get("site") == "test.site" and "boom" in e.get("error", "")
               for e in evs)
