"""Analytical-plane tests: the three physical paths agree with ground truth
across query types (Q1-Q4), modes (copy/count), and cache states (cold/hot);
zone-map pruning and version-consistency fallback behave correctly."""
import numpy as np
import pytest

from repro.core.matcher import compile_bundle
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine, substring_scan
from repro.core.query.mapper import QueryMapper
from repro.core.query.profiler import QueryProfiler
from repro.core.query.store import SegmentStore, build_text_index, tokenize
from repro.core.records import encode_texts
from repro.core.stream_processor import StreamProcessor
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import IngestPipeline


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    spec = WorkloadSpec(num_records=6000, ultra_rate=1e-3, high_rate=1e-2,
                        seed=11, text_width=256)
    gen = LogGenerator(spec)
    rules = tuple(Rule(i, t.term, t.term, fields=(t.fieldname,))
                  for i, t in enumerate(spec.planted))
    rs = RuleSet(rules)
    proc = StreamProcessor(compile_bundle(rs, spec.content_fields))
    store = SegmentStore(segment_size=1500,
                         root=tmp_path_factory.mktemp("segments"),
                         index_fields=spec.content_fields)
    IngestPipeline(gen, store, proc).run(batch_size=1000)
    mapper = QueryMapper(rs, version_id=0)
    # hot_seconds tiny so the feedback-loop test is machine-speed agnostic
    engine = QueryEngine(store, mapper=mapper,
                         profiler=QueryProfiler(hot_count=3,
                                                hot_seconds=1e-6))
    return spec, gen, rs, store, engine


ALL_PATHS = ("full_scan", "text_index", "fluxsieve")


def test_substring_scan_basics():
    data = encode_texts(["hello world", "worldly", "wor", ""], 16)
    assert substring_scan(data, "world").tolist() == [True, True, False, False]
    assert substring_scan(data, "").tolist() == [False] * 4
    assert substring_scan(data, "x" * 20).tolist() == [False] * 4


def test_tokenize():
    assert tokenize("a-b c.d 10:22 x_y!") == ["a-b", "c.d", "10:22", "x_y"]


def test_q1_nonmatching(world):
    spec, _, _, _, engine = world
    q = Query(terms=(("content1", spec.absent_terms[0]),), mode="count")
    for path in ("full_scan", "text_index"):
        assert engine.execute(q, path=path).count == 0


@pytest.mark.parametrize("term_idx", [0, 1])     # ultra + high on content1
@pytest.mark.parametrize("mode", ["count", "copy"])
def test_q2_q3_all_paths_agree(world, term_idx, mode):
    spec, gen, _, _, engine = world
    t = spec.planted[term_idx]
    truth = gen.true_count(t)
    assert truth > 0, "workload must plant at least one match"
    q = Query(terms=((t.fieldname, t.term),), mode=mode)
    for path in ALL_PATHS:
        r = engine.execute(q, path=path)
        assert r.count == truth, (t.term, path)
        if mode == "copy":
            n = r.records.num_records if r.records.columns else 0
            assert n == truth
            # returned rows genuinely contain the term
            from repro.core.records import decode_texts
            for text in decode_texts(r.records.columns[t.fieldname]):
                assert t.term in text


def test_q4_multifield(world):
    spec, _, _, _, engine = world
    t1 = next(t for t in spec.planted if t.fieldname == "content1"
              and t.rate >= 1e-2)
    t2 = next(t for t in spec.planted if t.fieldname == "content2"
              and t.rate >= 1e-2)
    q = Query(terms=((t1.fieldname, t1.term), (t2.fieldname, t2.term)),
              mode="count")
    counts = {p: engine.execute(q, path=p).count for p in ALL_PATHS}
    assert len(set(counts.values())) == 1, counts


def test_cold_runs_and_pruning(world):
    spec, gen, _, store, engine = world
    t = spec.planted[0]                          # ultra-selective
    q = Query(terms=((t.fieldname, t.term),), mode="count")
    r_flux = engine.execute(q, path="fluxsieve", cold=True)
    r_scan = engine.execute(q, path="full_scan", cold=True)
    assert r_flux.count == r_scan.count == gen.true_count(t)
    # enriched path reads only bitmap columns of unpruned segments
    assert r_flux.bytes_read < r_scan.bytes_read / 10
    assert r_flux.segments_pruned + r_flux.segments_scanned == len(store.segments)


def test_auto_path_selection(world):
    spec, _, _, _, engine = world
    t = spec.planted[0]
    r = engine.execute(Query(terms=((t.fieldname, t.term),)), path="auto")
    assert r.path == "fluxsieve"
    r2 = engine.execute(Query(terms=(("content1", "notarule"),)), path="auto")
    assert r2.path == "text_index"


def test_fluxsieve_requires_rule(world):
    _, _, _, _, engine = world
    with pytest.raises(ValueError):
        engine.execute(Query(terms=(("content1", "unregistered"),)),
                       path="fluxsieve")


def test_consistency_fallback(tmp_path):
    """Records ingested BEFORE a rule existed must still be found: segments
    older than the rule fall back to scanning (paper §3.4 consistency)."""
    texts1 = ["old needle row", "plain"]
    texts2 = ["new needle row", "plain"]
    rs1 = RuleSet((Rule(0, "other", "zzz", fields=("content1",)),))
    rs2 = rs1.with_rules([Rule(1, "needle", "needle", fields=("content1",))])
    proc = StreamProcessor(compile_bundle(rs1, ("content1",)))
    store = SegmentStore(segment_size=2, root=tmp_path)
    from repro.core.records import RecordBatch
    b1 = RecordBatch({"timestamp": np.arange(2, dtype=np.int64),
                      "content1": encode_texts(texts1, 64)})
    store.append(proc.process(b1))
    proc.swap(compile_bundle(rs2, ("content1",)))
    b2 = RecordBatch({"timestamp": np.arange(2, 4, dtype=np.int64),
                      "content1": encode_texts(texts2, 64)})
    store.append(proc.process(b2))
    store.seal()

    mapper = QueryMapper(rs1, version_id=0)
    mapper.notify(rs2, version_id=1)
    engine = QueryEngine(store, mapper=mapper)
    r = engine.execute(Query(terms=(("content1", "needle"),), mode="count"),
                       path="fluxsieve")
    assert r.count == 2                          # old segment scanned, not missed
    assert r.segments_fallback == 1


def test_profiler_feedback_loop(world):
    """Hot uncovered predicate -> proposed rule -> (new engine) -> mapper."""
    spec, gen, rs, store, engine = world
    prof = engine.profiler
    q = Query(terms=(("content1", "hotterm"),), mode="count")
    for _ in range(4):
        engine.execute(q, path="full_scan")
    hot = [k for k, _ in prof.hot_predicates()]
    assert ("content1", "hotterm") in hot
    rs2 = prof.propose_rules(rs)
    assert any(r.pattern == "hotterm" for r in rs2.rules)
    # rules already covered are not re-proposed
    rs3 = prof.propose_rules(rs2)
    assert rs3 == rs2


def test_text_index_round_trip(tmp_path):
    data = encode_texts(["alpha beta", "beta gamma", "alpha"], 32)
    idx = build_text_index(data)
    assert idx["alpha"].tolist() == [0, 2]
    assert idx["beta"].tolist() == [0, 1]
    from repro.core.query.store import _load_index, _save_index
    _save_index(tmp_path / "i.npz", idx)
    idx2 = _load_index(tmp_path / "i.npz")
    assert {k: v.tolist() for k, v in idx.items()} == \
           {k: v.tolist() for k, v in idx2.items()}


def test_segment_spill_and_reload(world):
    spec, _, _, store, _ = world
    seg = store.segments[0]
    seg.drop_caches()
    col = seg.column("content1", cache=False)
    assert col.shape[0] == seg.num_records
    assert "content1" not in seg._columns       # cold read did not retain
    reloaded = SegmentStore.load(store.root)
    assert len(reloaded.segments) == len(store.segments)
    assert reloaded.segments[0].meta["ts_min"] == seg.meta["ts_min"]
