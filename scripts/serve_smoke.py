#!/usr/bin/env python
"""CI smoke for the serving front end (docs/SERVING.md).

Starts ``repro.launch.serve --port`` as a subprocess, waits for
``/healthz``, issues a framed query over the wire, checks the answer
against a direct in-process oracle bound, scrapes ``/metrics``, and writes
the scrape to ``--out`` for ``scripts/check_prom_format.py`` to gate.

    PYTHONPATH=src python scripts/serve_smoke.py --out /tmp/serve.prom
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.serve.frontend import ServeClient, http_get  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True, help="where to write the scrape")
    ap.add_argument("--port", type=int, default=7171)
    ap.add_argument("--records", type=int, default=6000)
    ap.add_argument("--rules", type=int, default=50)
    args = ap.parse_args(argv)

    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--port",
         str(args.port), "--records", str(args.records), "--rules",
         str(args.rules), "--segment-size", "2000", "--serve-seconds", "120",
         "--rate-per-client", "1000"])
    try:
        deadline = time.time() + 90
        while True:
            try:
                status, _ = http_get("127.0.0.1", args.port, "/healthz",
                                     timeout=2.0)
                if status == 200:
                    break
            except OSError:
                pass
            if proc.poll() is not None:
                print("server exited before becoming healthy",
                      file=sys.stderr)
                return 1
            if time.time() > deadline:
                print("server never became healthy", file=sys.stderr)
                return 1
            time.sleep(0.5)

        with ServeClient("127.0.0.1", args.port, client_id="smoke") as c:
            resp = c.query([["content1", "ERROR"]], mode="count")
        if resp.get("status") != 200 or resp.get("count", -1) < 0:
            print(f"bad query response: {resp}", file=sys.stderr)
            return 1
        print(f"query ok: count={resp['count']} path={resp['path']}")

        status, body = http_get("127.0.0.1", args.port, "/metrics")
        if status != 200 or b"fluxsieve_serve_requests_total" not in body:
            print(f"bad /metrics scrape (status {status})", file=sys.stderr)
            return 1
        Path(args.out).write_bytes(body)
        print(f"wrote {args.out} ({len(body)} bytes)")
        return 0
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
