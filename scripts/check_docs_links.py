#!/usr/bin/env python
"""Docs link check: every repo path named in the docs must exist.

Scans ARCHITECTURE.md, README.md, and docs/*.md for references to
``src/repro/...`` modules (plus ``tests/``, ``benchmarks/``, ``examples/``
files and relative markdown links) and fails CI when any named path has
drifted away from the tree — documentation that points at dead modules is
worse than no documentation.

    python scripts/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ["ARCHITECTURE.md", "README.md", *sorted(
    str(p.relative_to(ROOT)) for p in (ROOT / "docs").glob("*.md"))]

# path-like references in prose/diagrams/tables: src/repro/... etc., with
# or without a file suffix (bare directories must exist as directories)
PATH_RE = re.compile(
    r"\b((?:src/repro|tests|benchmarks|examples|scripts|docs)"
    r"(?:/[A-Za-z0-9_.\-]+)*)")
# relative markdown links: [text](target)
MDLINK_RE = re.compile(r"\]\(([^)#:\s]+)\)")
# shorthand module refs used inside prose once a plane section has
# established the src/repro/ prefix, e.g. `core/query/store.py`
SHORT_RE = re.compile(
    r"`((?:core|data|kernels|launch|models|serve|train|distributed|configs)"
    r"(?:/[A-Za-z0-9_.\-]+)+/?)`")


def check(doc: str) -> list:
    text = (ROOT / doc).read_text()
    missing = []
    refs = set(PATH_RE.findall(text)) | set(MDLINK_RE.findall(text))
    refs |= {f"src/repro/{m}" for m in SHORT_RE.findall(text)}
    for ref in sorted(refs):
        ref = ref.rstrip("/.,:")
        if not ref or ref.startswith("http"):
            continue
        if not (ROOT / ref).exists():
            missing.append(ref)
    return missing


def check_fault_sites() -> list:
    """Every fault-injection site the code defines must appear in the
    failure-domain matrix (docs/ROBUSTNESS.md) — an undocumented site is a
    blast radius nobody reasoned about.  Parsed textually (no import, so
    the check stays dependency-free)."""
    src = (ROOT / "src/repro/core/faults.py").read_text()
    m = re.search(r"^SITES = \((?P<body>.*?)\)", src, re.S | re.M)
    sites = re.findall(r'"([a-z_]+\.[a-z_]+)"', m.group("body"))
    doc = (ROOT / "docs/ROBUSTNESS.md").read_text()
    return [s for s in sites if f"`{s}`" not in doc]


def main() -> int:
    failures = 0
    for doc in DOCS:
        missing = check(doc)
        for ref in missing:
            print(f"{doc}: missing path {ref!r}", file=sys.stderr)
        failures += len(missing)
    for site in check_fault_sites():
        print(f"docs/ROBUSTNESS.md: fault site `{site}` is not documented "
              f"in the failure-domain matrix", file=sys.stderr)
        failures += 1
    if failures:
        print(f"docs link check FAILED: {failures} dead reference(s)",
              file=sys.stderr)
        return 1
    print(f"docs link check OK ({', '.join(DOCS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
