#!/usr/bin/env python
"""Prometheus text exposition format checker (CI gate for telemetry dumps).

Validates the ``metrics.prom`` artifact our exporters write (see
``src/repro/core/telemetry/export.py``): every non-comment line must be a
well-formed sample, every ``# TYPE`` must name a known metric kind, every
sample must belong to a declared metric (histogram samples via their
``_bucket``/``_sum``/``_count`` suffixes), histogram bucket series must be
cumulative with a terminal ``le="+Inf"``, and metric/label names must match
the Prometheus grammar.  Bucket lines may carry OpenMetrics exemplar
suffixes (`` # {span_id="1234"} 0.0371``) — validated when present, never
required.  Deliberately dependency-free — the point is that any scraper
would accept the file, checked without shipping one.

    python scripts/check_prom_format.py /tmp/telemetry/metrics.prom
"""
from __future__ import annotations

import math
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*?)\})?"
    r" (?P<value>[^ #]+)"
    r"(?: (?P<ts>-?\d+))?"
    r"(?: # \{(?P<ex_labels>[^}]*)\} (?P<ex_value>[^ ]+)"
    r"(?: (?P<ex_ts>-?\d+(?:\.\d+)?))?)?$")
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
KINDS = {"counter", "gauge", "histogram", "summary", "untyped"}
HIST_SUFFIXES = ("_bucket", "_sum", "_count")


def _parse_labels(raw: str, err) -> dict:
    labels = {}
    matched = "".join(m.group(0) for m in LABEL_RE.finditer(raw))
    if raw.replace(",", "").replace(" ", "") != \
            matched.replace(",", "").replace(" ", ""):
        err(f"malformed label set {{{raw}}}")
    for m in LABEL_RE.finditer(raw):
        labels[m.group(1)] = m.group(2)
    return labels


def _base_name(name: str, types: dict) -> str:
    """Resolve a sample name to its declared metric (histogram samples
    carry suffixes the TYPE line does not)."""
    if name in types:
        return name
    for suf in HIST_SUFFIXES:
        if name.endswith(suf) and name[: -len(suf)] in types:
            return name[: -len(suf)]
    return name


def check_text(text: str) -> list:
    """-> list of 'line N: message' problems (empty = valid)."""
    problems = []
    types = {}      # metric name -> kind
    buckets = {}    # (name, non-le labels) -> [(le, cum)]
    for n, line in enumerate(text.splitlines(), 1):
        def err(msg, n=n):
            problems.append(f"line {n}: {msg}")
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                err(f"unrecognized comment {line!r} "
                    "(only # HELP / # TYPE carry meaning)")
                continue
            if parts[1] == "TYPE":
                name, kind = parts[2], (parts[3] if len(parts) > 3 else "")
                if not NAME_RE.match(name):
                    err(f"invalid metric name {name!r}")
                if kind not in KINDS:
                    err(f"invalid TYPE {kind!r} for {name}")
                if name in types:
                    err(f"duplicate TYPE for {name}")
                types[name] = kind
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            err(f"malformed sample line {line!r}")
            continue
        name = m.group("name")
        labels = _parse_labels(m.group("labels"), err) \
            if m.group("labels") is not None else {}
        try:
            value = float(m.group("value"))
        except ValueError:
            err(f"non-numeric value {m.group('value')!r}")
            continue
        if m.group("ex_labels") is not None:
            _parse_labels(m.group("ex_labels"), err)
            try:
                float(m.group("ex_value"))
            except ValueError:
                err(f"non-numeric exemplar value {m.group('ex_value')!r}")
            if not name.endswith("_bucket"):
                err(f"exemplar on non-bucket sample {name}")
        base = _base_name(name, types)
        if base not in types:
            err(f"sample {name} has no preceding # TYPE")
            continue
        if types[base] == "histogram" and name == f"{base}_bucket":
            if "le" not in labels:
                err(f"{name} sample missing the le label")
                continue
            le = math.inf if labels["le"] == "+Inf" else float(labels["le"])
            key = (base, tuple(sorted((k, v) for k, v in labels.items()
                                      if k != "le")))
            buckets.setdefault(key, []).append((le, value, n))
    for (name, series), rows in sorted(buckets.items()):
        rows.sort(key=lambda r: r[0])
        if rows[-1][0] != math.inf:
            problems.append(f"line {rows[-1][2]}: histogram {name}"
                            f"{dict(series)} lacks an le=\"+Inf\" bucket")
        cums = [v for _, v, _ in rows]
        if cums != sorted(cums):
            problems.append(f"line {rows[0][2]}: histogram {name}"
                            f"{dict(series)} buckets are not cumulative")
    return problems


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if len(argv) != 1:
        print(__doc__, file=sys.stderr)
        return 2
    text = open(argv[0]).read()
    problems = check_text(text)
    for p in problems:
        print(f"{argv[0]}: {p}", file=sys.stderr)
    if problems:
        print(f"prometheus format check FAILED: {len(problems)} problem(s)",
              file=sys.stderr)
        return 1
    samples = sum(1 for line in text.splitlines()
                  if line.strip() and not line.startswith("#"))
    print(f"prometheus format check OK ({argv[0]}: {samples} samples)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
