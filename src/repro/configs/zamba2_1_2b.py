"""Zamba2-1.2B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,           # mamba2 layers; shared attn interleaved every 6
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    shared_attn_interval=6,
    supports_decode=True,
    subquadratic=True,       # SSD states are O(1); shared-attn KV seq-sharded
    source="arXiv:2411.15242; hf",
))
