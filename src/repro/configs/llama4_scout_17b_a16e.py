"""Llama-4 Scout 17B-active/16E — MoE top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,               # shared-path MLP width
    vocab_size=202048,
    num_experts=16,
    num_shared_experts=1,
    moe_top_k=1,
    d_ff_expert=8192,
    rope_theta=500_000.0,
    supports_decode=True,
    subquadratic=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
