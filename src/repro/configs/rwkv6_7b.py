"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,            # head_size 64
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,              # channel-mix width
    vocab_size=65536,
    causal=True,
    supports_decode=True,
    subquadratic=True,       # O(1) recurrent state -> long_500k runs
    source="arXiv:2404.05892; hf",
))
