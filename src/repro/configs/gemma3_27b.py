"""Gemma-3 27B — 5:1 local:global attention, 128k ctx [hf:google/gemma-3-1b-pt; unverified]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    global_interval=6,       # 5 local (SWA) : 1 global
    swa_window=1024,
    rope_theta=1_000_000.0,
    supports_decode=True,
    subquadratic=False,      # global layers are full attention -> long_500k skipped
    source="hf:google/gemma-3-1b-pt; unverified",
))
