"""InternVL2-76B — InternViT + InternLM2 backbone [arXiv:2404.16821; unverified].

The InternViT frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings occupying the first ``frontend_tokens`` positions.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision_stub",
    frontend_tokens=256,
    supports_decode=True,
    subquadratic=False,
    source="arXiv:2404.16821; unverified",
))
