"""DeepSeekMoE 16B — 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,               # nominal per-expert width (spec: d_ff=1408)
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    moe_top_k=6,
    d_ff_expert=1408,
    first_k_dense=1,         # layer 0 is a dense MLP (d_ff_dense = 8 * 1408)
    supports_decode=True,
    subquadratic=False,
    source="arXiv:2401.06066; hf",
))
