"""HuBERT X-Large — encoder-only (w2v2 arch) [arXiv:2106.07447; unverified].

The conv feature-extractor frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, S, frontend_dim); a learned linear projects
them into the backbone. Encoder-only -> decode shapes are skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,            # bidirectional encoder
    frontend="audio_stub",
    frontend_dim=1280,
    supports_decode=False,
    subquadratic=False,
    source="arXiv:2106.07447; unverified",
))
