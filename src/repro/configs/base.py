"""Architecture configuration system.

Every assigned architecture is expressed as an ``ArchConfig`` — a frozen,
hashable description of the model *and* of how its layer stack is assembled
(``stack()`` -> scan segments).  The same config object drives:

  * parameter initialization / shape derivation (models/model.py)
  * train_step / serve_step construction (train/, serve/)
  * the multi-pod dry-run (launch/dryrun.py) via ``input_specs()``
  * smoke tests (reduced() shrinks every dimension but keeps the family).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

# Mixer kinds.
ATTN = "attn"            # full (causal or bidirectional) attention
SWA = "swa"              # sliding-window attention
RWKV6 = "rwkv6"          # RWKV-6 "Finch" token-shift + WKV6 recurrence
MAMBA2 = "mamba2"        # Mamba-2 SSD block
SHARED_ATTN = "shared_attn"  # full attention with weights shared across sites

# MLP kinds.
DENSE = "dense"          # SwiGLU MLP
MOE = "moe"              # routed experts (+ optional shared experts)
NONE = "none"            # mixer subsumes the MLP (rwkv6 channel-mix is its own)


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside a scan segment body."""
    mixer: str
    mlp: str = DENSE


@dataclass(frozen=True)
class StackSegment:
    """``repeat`` iterations of a scan whose body applies ``layers`` in order."""
    repeat: int
    layers: tuple  # tuple[LayerSpec, ...]


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set for the LM family)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # attention options
    causal: bool = True
    swa_window: int = 1_024
    global_interval: int = 0       # gemma3: every Nth layer is global (5:1 -> 6)
    rope_theta: float = 10_000.0

    # MoE options
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0         # leading layers that stay dense (deepseek: 1)
    capacity_factor: float = 1.25

    # SSM options
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    shared_attn_interval: int = 0  # zamba2: shared attention every N mamba layers

    # modality frontend ("none" | "vision_stub" | "audio_stub")
    frontend: str = "none"
    frontend_tokens: int = 256     # vision: patches in the prefix
    frontend_dim: int = 1_280      # audio: frame-embedding dim

    # capabilities
    supports_decode: bool = True
    subquadratic: bool = False     # can run long_500k

    # numerics
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # full-attention KV cache storage: bfloat16 | int8 (+bf16 per-token
    # scales; §Perf hillclimb C — halves decode HBM traffic)
    kv_cache_dtype: str = "bfloat16"

    # citation string from the assignment table
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- layer plan ---------------------------------------------------------
    def stack(self) -> tuple:
        """Return the scan-segment plan for this architecture."""
        mlp = MOE if self.num_experts > 0 else DENSE
        if self.family == "ssm":                      # rwkv6: mixer includes its own channel-mix
            return (StackSegment(self.num_layers, (LayerSpec(RWKV6, NONE),)),)
        if self.family == "hybrid":                   # zamba2
            iv = self.shared_attn_interval
            groups, rem = divmod(self.num_layers, iv)
            segs = []
            if groups:
                segs.append(StackSegment(groups, tuple([LayerSpec(MAMBA2, DENSE)] * iv
                                                       + [LayerSpec(SHARED_ATTN, DENSE)])))
            if rem:
                segs.append(StackSegment(1, tuple([LayerSpec(MAMBA2, DENSE)] * rem)))
            return tuple(segs)
        if self.global_interval > 1:                  # gemma3 local:global mix
            iv = self.global_interval
            groups, rem = divmod(self.num_layers, iv)
            segs = []
            if groups:
                segs.append(StackSegment(groups, tuple([LayerSpec(SWA, mlp)] * (iv - 1)
                                                       + [LayerSpec(ATTN, mlp)])))
            if rem:
                segs.append(StackSegment(1, tuple([LayerSpec(SWA, mlp)] * rem)))
            return tuple(segs)
        if self.num_experts > 0 and self.first_k_dense > 0:
            return (StackSegment(1, tuple([LayerSpec(ATTN, DENSE)] * self.first_k_dense)),
                    StackSegment(self.num_layers - self.first_k_dense, (LayerSpec(ATTN, MOE),)))
        return (StackSegment(self.num_layers, (LayerSpec(ATTN, mlp),)),)

    # -- bookkeeping ---------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count N (embedding included once)."""
        total = self.vocab_size * self.d_model        # embedding (tied head)
        for seg in self.stack():
            for spec in seg.layers:
                total += seg.repeat * _layer_params(self, spec)
        total += self.d_model                          # final norm
        if self.frontend == "audio_stub":
            total += self.frontend_dim * self.d_model
        # shared attention params counted once
        if any(s.mixer == SHARED_ATTN for seg in self.stack() for s in seg.layers):
            total += _attn_params(self)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-to experts count)."""
        if self.num_experts == 0:
            return self.param_count()
        total = self.vocab_size * self.d_model + self.d_model
        for seg in self.stack():
            for spec in seg.layers:
                n = _attn_params(self) if spec.mixer in (ATTN, SWA, SHARED_ATTN) else _mixer_params(self, spec.mixer)
                n += 2 * self.d_model  # norms
                if spec.mlp == DENSE:
                    n += 3 * self.d_model * self.d_ff
                elif spec.mlp == MOE:
                    n += self.num_shared_experts * 3 * self.d_model * self.d_ff_expert
                    n += self.moe_top_k * 3 * self.d_model * self.d_ff_expert
                    n += self.d_model * self.num_experts  # router
                total += seg.repeat * n
        return total

    def shape_supported(self, shape_name: str) -> bool:
        spec = SHAPES[shape_name]
        if spec.kind == "decode":
            if not self.supports_decode:
                return False
            if spec.seq_len > 100_000 and not self.subquadratic:
                return False
        return True

    def skip_reason(self, shape_name: str) -> str:
        spec = SHAPES[shape_name]
        if spec.kind == "decode" and not self.supports_decode:
            return "encoder-only architecture has no decode step"
        if spec.kind == "decode" and spec.seq_len > 100_000 and not self.subquadratic:
            return ("full-attention KV cache at 524288 tokens exceeds HBM; "
                    "arch has no sub-quadratic path (see DESIGN.md)")
        return ""

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=max(2, min(4, self.num_layers)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // self.num_heads)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            frontend_tokens=8,
            frontend_dim=64,
            swa_window=32,
        )
        if self.num_experts > 0:
            kw.update(num_experts=8, d_ff_expert=64,
                      moe_top_k=min(self.moe_top_k, 2),
                      num_shared_experts=min(self.num_shared_experts, 1),
                      first_k_dense=min(self.first_k_dense, 1))
        if self.ssm_state > 0:
            kw.update(ssm_state=16)
        if self.shared_attn_interval > 0:
            kw.update(shared_attn_interval=2, num_layers=4)
        if self.global_interval > 0:
            kw.update(global_interval=2, num_layers=4)
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)


def _attn_params(cfg: ArchConfig) -> int:
    q = cfg.d_model * cfg.num_heads * cfg.head_dim
    kv = 2 * cfg.d_model * cfg.num_kv_heads * cfg.head_dim
    o = cfg.num_heads * cfg.head_dim * cfg.d_model
    return q + kv + o


def _mixer_params(cfg: ArchConfig, mixer: str) -> int:
    d = cfg.d_model
    if mixer in (ATTN, SWA, SHARED_ATTN):
        return _attn_params(cfg)
    if mixer == RWKV6:
        # time-mix: wr/wk/wv/wg/wo (5 d^2) + decay lora (128d) + small vecs;
        # channel-mix: cm_r (d^2) + cm_k/cm_v (2 d*d_ff)
        from repro.models.ssm import RWKV_LORA_RANK
        tm = 5 * d * d + 2 * d * RWKV_LORA_RANK + 10 * d
        cm = d * d + 2 * d * cfg.d_ff
        return tm + cm
    if mixer == MAMBA2:
        d_in = cfg.ssm_expand * d
        return d * (2 * d_in + 2 * cfg.ssm_state) + d_in * d + d_in * cfg.ssm_conv
    raise ValueError(mixer)


def _layer_params(cfg: ArchConfig, spec: LayerSpec) -> int:
    n = 2 * cfg.d_model  # norms
    if spec.mixer == SHARED_ATTN:
        pass  # shared weights counted once by caller
    else:
        n += _mixer_params(cfg, spec.mixer)
    if spec.mlp == DENSE:
        n += 3 * cfg.d_model * cfg.d_ff
    elif spec.mlp == MOE:
        n += cfg.d_model * cfg.num_experts
        n += (cfg.num_experts + cfg.num_shared_experts) * 3 * cfg.d_model * cfg.d_ff_expert
    return n


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; never allocate)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Model inputs for one (arch x shape) cell as ShapeDtypeStructs.

    train  -> {tokens, labels [, vision_embeds | frames]}
    prefill-> {tokens [, vision_embeds | frames]}
    decode -> {tokens (B,1), cache_len scalar}  (the KV cache itself is part of
              the serve state, built by serve.kv_cache.cache_specs)
    """
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    compute = jnp.bfloat16

    if spec.kind == "train":
        out = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    elif spec.kind == "prefill":
        out = {"tokens": sds((B, S), i32)}
    else:  # decode: one new token against a cache of S
        out = {"tokens": sds((B, 1), i32), "cache_len": sds((), i32)}

    if cfg.frontend == "vision_stub" and spec.kind != "decode":
        out["vision_embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), compute)
        out["tokens"] = sds((B, S - cfg.frontend_tokens), i32)
        if spec.kind == "train":
            out["labels"] = sds((B, S - cfg.frontend_tokens), i32)
    if cfg.frontend == "audio_stub" and spec.kind != "decode":
        # precomputed frame embeddings replace the token stream entirely
        out = {"frames": sds((B, S, cfg.frontend_dim), compute)}
        if spec.kind == "train":
            out["labels"] = sds((B, S), i32)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import for registration side effects
    from repro.configs import (  # noqa: F401
        rwkv6_7b, phi3_medium_14b, gemma3_27b, yi_34b, phi3_mini_3_8b,
        llama4_scout_17b_a16e, deepseek_moe_16b, zamba2_1_2b,
        internvl2_76b, hubert_xlarge,
    )
