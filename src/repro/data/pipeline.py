"""Streaming pipelines wiring the FluxSieve stream processor into (a) the
analytical plane and (b) LM training — DESIGN.md §3.

``IngestPipeline`` is the paper's deployment: source -> StreamProcessor
(match + enrich) -> SegmentStore, with per-stage throughput/CPU accounting
(benchmarks read these for the Fig-5 overhead analysis).

``TrainDataPipeline`` is the framework integration: the same enriched
stream feeds LM training; rule bitmaps ride along each batch so trainers
can subselect (``include_rules`` / ``exclude_rules``) without rescanning
bytes — ingest-time data curation (quality/PII filters) as a first-class
data-plane feature.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import enrichment
from repro.core.records import RecordBatch
from repro.core.stream_processor import ENRICH_COLUMN, StreamProcessor
from repro.core.query.store import SegmentStore
from repro.data import tokenizer
from repro.data.generator import LogGenerator


@dataclass
class StageTimes:
    generate_s: float = 0.0
    process_s: float = 0.0
    store_s: float = 0.0
    records: int = 0
    cpu_s: float = 0.0
    wall_s: float = 0.0

    def throughput(self) -> float:
        total = self.generate_s + self.process_s + self.store_s
        return self.records / total if total else 0.0

    def sustained_rate(self) -> float:
        return self.records / self.wall_s if self.wall_s else 0.0

    def cpu_busy_fraction(self) -> float:
        return self.cpu_s / self.wall_s if self.wall_s else 0.0


class IngestPipeline:
    """generator -> [stream processor] -> segment store.

    ``processor=None`` is the paper's *baseline* lane (decode + write only);
    with a processor it is the FluxSieve lane (match + enrich + write)."""

    def __init__(self, generator: LogGenerator, store: SegmentStore,
                 processor: StreamProcessor = None):
        self.generator = generator
        self.store = store
        self.processor = processor
        if processor is not None and store.version_rules is None:
            # share the processor's live version->rules registry so seals
            # stamp rule-aware coverage metadata (``rules_known``) that the
            # mapper and the maintenance plane consume
            store.version_rules = processor.version_rules
        self.times = StageTimes()

    def run(self, *, batch_size: int = 4096, limit: int = None,
            poll_updates: bool = True, target_rate: float = None) -> StageTimes:
        """``target_rate`` (records/s) paces the source like the paper's
        fixed-rate Kafka input (Fig 5: 10k events/s); without it the
        pipeline runs saturated."""
        t = self.times
        cpu0 = time.process_time()
        wall0 = time.perf_counter()
        total = limit or self.generator.spec.num_records
        start = 0
        while start < total:
            n = min(batch_size, total - start)
            t0 = time.perf_counter()
            batch = self.generator.batch(start, n)
            t1 = time.perf_counter()
            if self.processor is not None:
                if poll_updates:
                    self.processor.poll_updates()  # control topology
                batch = self.processor.process(batch)
            t2 = time.perf_counter()
            self.store.append(batch)
            t3 = time.perf_counter()
            t.generate_s += t1 - t0
            t.process_s += t2 - t1
            t.store_s += t3 - t2
            t.records += n
            start += n
            if target_rate:
                ahead = start / target_rate - (time.perf_counter() - wall0)
                if ahead > 0:
                    time.sleep(ahead)
        self.store.seal()
        t.cpu_s = time.process_time() - cpu0
        t.wall_s = time.perf_counter() - wall0
        return t


class TrainDataPipeline:
    """Enriched log stream -> packed LM token batches.

    Rule bitmaps ride along; ``include_rules``/``exclude_rules`` subselect
    records by precomputed enrichment before tokenization (no byte rescans).
    """

    def __init__(self, generator: LogGenerator,
                 processor: StreamProcessor = None, *,
                 include_rules=None, exclude_rules=None):
        self.generator = generator
        self.processor = processor
        self.include_rules = tuple(include_rules or ())
        self.exclude_rules = tuple(exclude_rules or ())
        if (self.include_rules or self.exclude_rules) and processor is None:
            raise ValueError("rule-based selection needs a stream processor")

    def _select(self, batch: RecordBatch) -> RecordBatch:
        if not (self.include_rules or self.exclude_rules):
            return batch
        bm = batch.columns[ENRICH_COLUMN]
        n_rules = self.processor.num_rules
        keep = np.ones(len(batch), bool)
        if self.include_rules:
            mask = enrichment.rule_mask(self.include_rules, n_rules)
            keep &= (bm & mask[None]).any(axis=1)
        if self.exclude_rules:
            mask = enrichment.rule_mask(self.exclude_rules, n_rules)
            keep &= ~(bm & mask[None]).any(axis=1)
        return batch.select(keep)

    def batches(self, *, seq_len: int, batch_size: int,
                records_per_step: int = 2048, limit_steps: int = None):
        """Yield {'tokens': (B, S), 'labels': (B, S)} train batches."""
        start = 0
        step = 0
        spec = self.generator.spec
        while limit_steps is None or step < limit_steps:
            raw = self.generator.batch(start % spec.num_records,
                                       records_per_step)
            start += records_per_step
            if self.processor is not None:
                self.processor.poll_updates()
                raw = self.processor.process(raw)
            raw = self._select(raw)
            if len(raw) == 0:
                continue
            text = np.concatenate([raw.columns[f] for f in raw.text_fields],
                                  axis=1)
            rows = tokenizer.encode_bytes(text)
            tokens, labels = tokenizer.pack_sequences(rows, seq_len, batch_size)
            yield {"tokens": tokens, "labels": labels}
            step += 1
