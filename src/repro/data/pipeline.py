"""Streaming pipelines wiring the FluxSieve stream processor into (a) the
analytical plane and (b) LM training — DESIGN.md §3.

``IngestPipeline`` is the paper's deployment: source -> StreamProcessor
(match + enrich) -> SegmentStore, with per-stage throughput/CPU accounting
(benchmarks read these for the Fig-5 overhead analysis).

``TrainDataPipeline`` is the framework integration: the same enriched
stream feeds LM training; rule bitmaps ride along each batch so trainers
can subselect (``include_rules`` / ``exclude_rules``) without rescanning
bytes — ingest-time data curation (quality/PII filters) as a first-class
data-plane feature.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import enrichment, telemetry
from repro.core.records import RecordBatch
from repro.core.stream_processor import ENRICH_COLUMN, StreamProcessor
from repro.core.query.store import SegmentStore
from repro.data import tokenizer
from repro.data.generator import LogGenerator

# per-batch stage latencies (one observe per batch, not per record) plus
# throughput/overlap counters — the snapshot-side view of StageTimes
_STAGE_HIST = {
    stage: telemetry.histogram(
        "fluxsieve_ingest_stage_seconds", labels={"stage": stage},
        help="Per-batch host seconds by ingest stage.")
    for stage in ("generate", "dispatch", "finalize_wait", "store")
}
_INGEST_RECORDS = telemetry.counter(
    "fluxsieve_ingest_records_total",
    help="Records ingested through the pipeline.")
_INGEST_BATCHES = telemetry.counter(
    "fluxsieve_ingest_batches_total",
    help="Batches pushed through the ingest loop.")
_OVERLAP_S = telemetry.counter(
    "fluxsieve_ingest_overlap_seconds_total",
    help="Host seconds spent generating/storing while a dispatched match "
         "was still in flight (double-buffering overlap).")


@dataclass
class StageTimes:
    """Per-stage host time.  With the pipelined (double-buffered) loop,
    ``process_s`` is dispatch time plus the time *blocked* waiting for a
    result; device compute hidden behind generate/store shows up in
    ``overlap_s`` instead (host seconds spent generating or storing while a
    dispatched match was still in flight), so the stage sum stays an honest
    account of where the wall clock went."""
    generate_s: float = 0.0
    process_s: float = 0.0
    store_s: float = 0.0
    overlap_s: float = 0.0
    records: int = 0
    cpu_s: float = 0.0
    wall_s: float = 0.0

    def throughput(self) -> float:
        total = self.generate_s + self.process_s + self.store_s
        return self.records / total if total else 0.0

    def sustained_rate(self) -> float:
        return self.records / self.wall_s if self.wall_s else 0.0

    def cpu_busy_fraction(self) -> float:
        return self.cpu_s / self.wall_s if self.wall_s else 0.0


class IngestPipeline:
    """generator -> [stream processor] -> segment store.

    ``processor=None`` is the paper's *baseline* lane (decode + write only);
    with a processor it is the FluxSieve lane (match + enrich + write).
    The FluxSieve lane is double-buffered: JAX's async dispatch lets the
    device match batch *k* while the host appends batch *k-1* to the
    SegmentStore — the bitmap stays a device array until the append-side
    ``finalize`` materializes it (one D2H per batch)."""

    def __init__(self, generator: LogGenerator, store: SegmentStore,
                 processor: StreamProcessor = None):
        self.generator = generator
        self.store = store
        self.processor = processor
        if processor is not None and store.version_rules is None:
            # share the processor's live version->rules registry so seals
            # stamp rule-aware coverage metadata (``rules_known``) that the
            # mapper and the maintenance plane consume
            store.version_rules = processor.version_rules
        self.times = StageTimes()

    def _flush(self, pending) -> tuple:
        """finalize + append one pending batch; -> (wait_s, store_s)."""
        t0 = time.perf_counter()
        with telemetry.span("ingest/finalize_wait", cat="ingest"):
            out = self.processor.finalize(pending)
        t1 = time.perf_counter()
        with telemetry.span("ingest/store", cat="ingest"):
            self.store.append(out)
        t2 = time.perf_counter()
        _STAGE_HIST["finalize_wait"].observe(t1 - t0)
        _STAGE_HIST["store"].observe(t2 - t1)
        return t1 - t0, t2 - t1

    def run(self, *, batch_size: int = 4096, limit: int = None,
            poll_updates: bool = True, target_rate: float = None,
            pipelined: bool = True) -> StageTimes:
        """``target_rate`` (records/s) paces the source like the paper's
        fixed-rate Kafka input (Fig 5: 10k events/s); without it the
        pipeline runs saturated.  ``pipelined=False`` forces the strictly
        sequential generate->match->store loop (A/B accounting)."""
        t = self.times
        cpu0 = time.process_time()
        wall0 = time.perf_counter()
        total = limit or self.generator.spec.num_records
        start = 0
        pending = None              # batch k-1, dispatched but not stored
        while start < total:
            n = min(batch_size, total - start)
            t0 = time.perf_counter()
            with telemetry.span("ingest/generate", cat="ingest", n=n):
                batch = self.generator.batch(start, n)
            t1 = time.perf_counter()
            t.generate_s += t1 - t0
            _STAGE_HIST["generate"].observe(t1 - t0)
            # only device-side results can actually be in flight; host
            # backends (dfa_selective) matched synchronously at dispatch
            if pending is not None and pending.result.on_device:
                t.overlap_s += t1 - t0          # generated while k-1 matched
                _OVERLAP_S.inc(t1 - t0)
            if self.processor is None:
                with telemetry.span("ingest/store", cat="ingest"):
                    self.store.append(batch)
                store_s = time.perf_counter() - t1
                t.store_s += store_s
                _STAGE_HIST["store"].observe(store_s)
            else:
                td = time.perf_counter()
                if poll_updates:
                    self.processor.poll_updates()  # control topology
                with telemetry.span("ingest/dispatch", cat="ingest", n=n):
                    pb = self.processor.process_async(batch)
                dispatch_s = time.perf_counter() - td
                t.process_s += dispatch_s
                _STAGE_HIST["dispatch"].observe(dispatch_s)
                if pipelined:
                    if pending is not None:
                        wait_s, store_s = self._flush(pending)
                        t.process_s += wait_s
                        t.store_s += store_s
                        if pb.result.on_device:
                            t.overlap_s += store_s  # stored k-1, k in flight
                            _OVERLAP_S.inc(store_s)
                    pending = pb
                else:
                    wait_s, store_s = self._flush(pb)
                    t.process_s += wait_s
                    t.store_s += store_s
            t.records += n
            _INGEST_RECORDS.inc(n)
            _INGEST_BATCHES.inc()
            start += n
            if target_rate:
                ahead = start / target_rate - (time.perf_counter() - wall0)
                if ahead > 0:
                    time.sleep(ahead)
        if pending is not None:
            wait_s, store_s = self._flush(pending)
            t.process_s += wait_s
            t.store_s += store_s
        self.store.seal()
        t.cpu_s = time.process_time() - cpu0
        t.wall_s = time.perf_counter() - wall0
        return t


class TrainDataPipeline:
    """Enriched log stream -> packed LM token batches.

    Rule bitmaps ride along; ``include_rules``/``exclude_rules`` subselect
    records by precomputed enrichment before tokenization (no byte rescans).
    """

    def __init__(self, generator: LogGenerator,
                 processor: StreamProcessor = None, *,
                 include_rules=None, exclude_rules=None):
        self.generator = generator
        self.processor = processor
        self.include_rules = tuple(include_rules or ())
        self.exclude_rules = tuple(exclude_rules or ())
        if (self.include_rules or self.exclude_rules) and processor is None:
            raise ValueError("rule-based selection needs a stream processor")

    def _select(self, batch: RecordBatch) -> RecordBatch:
        if not (self.include_rules or self.exclude_rules):
            return batch
        bm = batch.columns[ENRICH_COLUMN]
        n_rules = self.processor.num_rules
        keep = np.ones(len(batch), bool)
        if self.include_rules:
            mask = enrichment.rule_mask(self.include_rules, n_rules)
            keep &= (bm & mask[None]).any(axis=1)
        if self.exclude_rules:
            mask = enrichment.rule_mask(self.exclude_rules, n_rules)
            keep &= ~(bm & mask[None]).any(axis=1)
        return batch.select(keep)

    def batches(self, *, seq_len: int, batch_size: int,
                records_per_step: int = 2048, limit_steps: int = None):
        """Yield {'tokens': (B, S), 'labels': (B, S)} train batches."""
        start = 0
        step = 0
        spec = self.generator.spec
        while limit_steps is None or step < limit_steps:
            raw = self.generator.batch(start % spec.num_records,
                                       records_per_step)
            start += records_per_step
            if self.processor is not None:
                self.processor.poll_updates()
                raw = self.processor.process(raw)
            raw = self._select(raw)
            if len(raw) == 0:
                continue
            text = np.concatenate([raw.columns[f] for f in raw.text_fields],
                                  axis=1)
            rows = tokenizer.encode_bytes(text)
            tokens, labels = tokenizer.pack_sequences(rows, seq_len, batch_size)
            yield {"tokens": tokens, "labels": labels}
            step += 1
