"""Streaming pipelines wiring the FluxSieve stream processor into (a) the
analytical plane and (b) LM training — DESIGN.md §3.

``IngestPipeline`` is the paper's deployment: source -> StreamProcessor
(match + enrich) -> SegmentStore, with per-stage throughput/CPU accounting
(benchmarks read these for the Fig-5 overhead analysis).

``TrainDataPipeline`` is the framework integration: the same enriched
stream feeds LM training; rule bitmaps ride along each batch so trainers
can subselect (``include_rules`` / ``exclude_rules``) without rescanning
bytes — ingest-time data curation (quality/PII filters) as a first-class
data-plane feature.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core import enrichment, faults, telemetry
from repro.core.faults import InjectedCrash
from repro.core.records import RecordBatch
from repro.core.stream_processor import (ENRICH_COLUMN, BatchMatchError,
                                         StreamProcessor)
from repro.core.query.store import INGEST_WAL_DIRNAME as WAL_DIRNAME
from repro.core.query.store import SegmentStore
from repro.data import tokenizer
from repro.data.generator import LogGenerator

QUARANTINE_DIRNAME = "quarantine"   # dead-letter home for unmatched batches

# per-batch stage latencies (one observe per batch, not per record) plus
# throughput/overlap counters — the snapshot-side view of StageTimes
_STAGE_HIST = {
    stage: telemetry.histogram(
        "fluxsieve_ingest_stage_seconds", labels={"stage": stage},
        help="Per-batch host seconds by ingest stage.")
    for stage in ("generate", "wal", "dispatch", "finalize_wait", "store")
}
_INGEST_RECORDS = telemetry.counter(
    "fluxsieve_ingest_records_total",
    help="Records ingested through the pipeline.")
_INGEST_BATCHES = telemetry.counter(
    "fluxsieve_ingest_batches_total",
    help="Batches pushed through the ingest loop.")
_OVERLAP_S = telemetry.counter(
    "fluxsieve_ingest_overlap_seconds_total",
    help="Host seconds spent generating/storing while a dispatched match "
         "was still in flight (double-buffering overlap).")
_WAL_WRITES = telemetry.counter(
    "fluxsieve_wal_writes_total",
    help="Batches journaled to the ingest WAL.")
_WAL_REPLAYED = telemetry.counter(
    "fluxsieve_wal_replayed_records_total",
    help="Records re-ingested from the WAL during crash recovery.")
_QUARANTINED = telemetry.counter(
    "fluxsieve_ingest_quarantined_total",
    help="Records dead-lettered after failing both match lanes.")


def _atomic_save_batch(path: Path, columns: dict) -> None:
    """Batch container: a name list then one raw ``np.save`` per column,
    concatenated in one file — ~4x cheaper than npz on the hot journal
    path (the zip container CRCs every member).  Written via tmp +
    ``os.replace``, the same all-or-nothing discipline as the manifest and
    the backfill checkpoint: a reader never observes a torn entry, a
    crashed writer leaves only a ``.tmp``."""
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.save(f, np.array(list(columns)))
        for v in columns.values():
            np.save(f, v)
    os.replace(tmp, path)


def _load_batch(path: Path) -> RecordBatch:
    with open(path, "rb") as f:
        names = np.load(f)
        return RecordBatch(columns={str(nm): np.load(f) for nm in names})


class IngestWAL:
    """Per-batch write-ahead journal for crash-safe ingest.

    The double-buffered ingest loop holds up to two batches of volatile
    state (batch *k* dispatched, batch *k-1* appending) and the store
    buffers rows in memory until a seal — so a kill can lose up to a
    segment's worth of source rows.  The WAL closes that window: each raw
    (pre-enrichment) batch is journaled *before* dispatch, and recovery
    replays every journaled row past the store's durability watermark.

    Exactly-once hinges on one invariant, owned by the store: the manifest
    ``sealed_rows`` watermark advances in the SAME atomic commit that
    registers a sealed segment.  Entry files are named
    ``batch-<row_start>-<nrows>.npy`` in *source-row* coordinates, so

      * ``truncate(W)`` deletes entries fully below the watermark,
      * ``replay(W)`` yields rows from exactly W (slicing the straddling
        entry), never re-ingesting a sealed row and never skipping an
        unsealed one.

    Requires enrich mode: the watermark counts source rows, which filter
    mode does not preserve through the store."""

    def __init__(self, root):
        self.dir = Path(root) / WAL_DIRNAME
        self.dir.mkdir(parents=True, exist_ok=True)

    def _path(self, row_start: int, n: int) -> Path:
        return self.dir / f"batch-{row_start:012d}-{n:08d}.npy"

    def append(self, row_start: int, batch: RecordBatch) -> None:
        faults.fire("ingest.wal_append", row_start=int(row_start))
        _atomic_save_batch(self._path(row_start, len(batch)), batch.columns)
        _WAL_WRITES.inc()

    def entries(self) -> list:
        """Sorted [(row_start, nrows, path)] of intact journal entries."""
        out = []
        for p in sorted(self.dir.glob("batch-*.npy")):
            try:
                _, start, n = p.stem.split("-")
                out.append((int(start), int(n), p))
            except ValueError:
                continue
        return out

    def truncate(self, durable_rows: int) -> None:
        """Reclaim entries whose rows are all durable (below the manifest
        watermark — sealed or quarantined)."""
        for row_start, n, p in self.entries():
            if row_start + n <= durable_rows:
                try:
                    p.unlink()
                except OSError as e:
                    telemetry.suppressed("ingest.wal_truncate", e)

    def replay(self, watermark: int):
        """Yield ``(row_start, RecordBatch)`` for journaled rows at or past
        the durability watermark, slicing the straddling entry so replay
        starts at exactly row ``watermark``."""
        for row_start, n, p in self.entries():
            if row_start + n <= watermark:
                continue
            batch = _load_batch(p)
            if row_start < watermark:
                batch = batch.slice(watermark - row_start, n)
                row_start = watermark
            yield row_start, batch

    def end(self) -> int:
        """Highest journaled source row (resume point for the source)."""
        entries = self.entries()
        if not entries:
            return 0
        row_start, n, _ = entries[-1]
        return row_start + n


@dataclass
class StageTimes:
    """Per-stage host time.  With the pipelined (double-buffered) loop,
    ``process_s`` is dispatch time plus the time *blocked* waiting for a
    result; device compute hidden behind generate/store shows up in
    ``overlap_s`` instead (host seconds spent generating or storing while a
    dispatched match was still in flight), so the stage sum stays an honest
    account of where the wall clock went."""
    generate_s: float = 0.0
    wal_s: float = 0.0
    process_s: float = 0.0
    store_s: float = 0.0
    overlap_s: float = 0.0
    records: int = 0
    cpu_s: float = 0.0
    wall_s: float = 0.0

    def throughput(self) -> float:
        total = self.generate_s + self.wal_s + self.process_s + self.store_s
        return self.records / total if total else 0.0

    def sustained_rate(self) -> float:
        return self.records / self.wall_s if self.wall_s else 0.0

    def cpu_busy_fraction(self) -> float:
        return self.cpu_s / self.wall_s if self.wall_s else 0.0


class IngestPipeline:
    """generator -> [stream processor] -> segment store.

    ``processor=None`` is the paper's *baseline* lane (decode + write only);
    with a processor it is the FluxSieve lane (match + enrich + write).
    The FluxSieve lane is double-buffered: JAX's async dispatch lets the
    device match batch *k* while the host appends batch *k-1* to the
    SegmentStore — the bitmap stays a device array until the append-side
    ``finalize`` materializes it (one D2H per batch).

    ``wal=True`` (rooted stores, enrich mode only) journals every raw
    batch before dispatch and truncates against the store's manifest
    watermark; after a kill, ``recover()`` replays the journal so every
    source row lands in a sealed segment exactly once.  Batches that fail
    BOTH match lanes (primary + oracle fallback) are dead-lettered to
    ``<root>/quarantine/`` and skipped — the stream keeps flowing."""

    def __init__(self, generator: LogGenerator, store: SegmentStore,
                 processor: StreamProcessor = None, *, wal: bool = False):
        self.generator = generator
        self.store = store
        self.processor = processor
        if processor is not None and store.version_rules is None:
            # share the processor's live version->rules registry so seals
            # stamp rule-aware coverage metadata (``rules_known``) that the
            # mapper and the maintenance plane consume
            store.version_rules = processor.version_rules
        self.wal = None
        if wal:
            if store.root is None:
                raise ValueError("the ingest WAL needs a rooted store "
                                 "(it lives next to the spill dirs)")
            if processor is not None and processor.mode == "filter":
                raise ValueError(
                    "the ingest WAL requires enrich mode: its durability "
                    "watermark counts source rows, which filter mode does "
                    "not preserve through the store")
            self.wal = IngestWAL(store.root)
        self.quarantined = 0
        self.times = StageTimes()

    def _flush(self, pending, row_start: int) -> tuple:
        """finalize + append one pending batch; -> (wait_s, store_s).
        A finalize failure (e.g. the D2H transfer) gets ONE synchronous
        re-run of the whole batch; a second failure dead-letters it."""
        t0 = time.perf_counter()
        with telemetry.span("ingest/finalize_wait", cat="ingest"):
            try:
                out = self.processor.finalize(pending)
            except InjectedCrash:
                raise
            except Exception as e:  # noqa: BLE001 — degrade, not crash
                out = self._refinalize(pending, row_start, e)
        t1 = time.perf_counter()
        with telemetry.span("ingest/store", cat="ingest"):
            if out is not None:
                faults.fire("ingest.append", n=len(out))
                self.store.append(out)
                if self.wal is not None:
                    self.wal.truncate(self.store.sealed_rows)
        t2 = time.perf_counter()
        _STAGE_HIST["finalize_wait"].observe(t1 - t0)
        _STAGE_HIST["store"].observe(t2 - t1)
        return t1 - t0, t2 - t1

    def _refinalize(self, pending, row_start: int, err):
        """Finalize failed: one fresh synchronous pass (re-dispatch + D2H),
        then quarantine.  Returns the enriched batch or None (dead-lettered)."""
        try:
            return self.processor.process(pending.batch)
        except InjectedCrash:
            raise
        except Exception as e:  # noqa: BLE001
            self._quarantine(row_start, pending.batch, e)
            return None

    def _quarantine(self, row_start: int, batch: RecordBatch, err) -> None:
        """Dead-letter a batch that no match lane could process: spill the
        raw rows to ``<root>/quarantine/`` and advance the durability
        watermark past them (they are durable — just not queryable), so
        the WAL truncates and recovery never replays them as lost."""
        if self.store.root is None:
            raise err   # no durable dead-letter home: fail loudly
        qdir = Path(self.store.root) / QUARANTINE_DIRNAME
        qdir.mkdir(parents=True, exist_ok=True)
        _atomic_save_batch(qdir / f"batch-{row_start:012d}-{len(batch):08d}.npy",
                      batch.columns)
        self.store.account_skipped_rows(len(batch))
        if self.wal is not None:
            self.wal.truncate(self.store.sealed_rows)
        self.quarantined += len(batch)
        _QUARANTINED.inc(len(batch))
        telemetry.emit("quarantine", plane="ingest", row_start=int(row_start),
                       records=len(batch),
                       error=f"{type(err).__name__}: {err}")

    def recover(self) -> int:
        """Replay journaled batches past the store's durability watermark
        (call on a freshly ``SegmentStore.load``-ed store after a crash).
        Replayed rows are re-enriched and sealed immediately — after this
        returns, everything journaled is durable.  Returns the source row
        ingest should resume from (pass as ``run(start=...)``)."""
        if self.wal is None:
            return self.store.sealed_rows
        watermark = self.store.sealed_rows
        resume = max(watermark, self.wal.end())
        replayed = 0
        with telemetry.span("ingest/wal_replay", cat="ingest"):
            for row_start, batch in self.wal.replay(watermark):
                if self.processor is not None:
                    try:
                        batch = self.processor.process(batch)
                    except InjectedCrash:
                        raise
                    except BatchMatchError as e:
                        self._quarantine(row_start, batch, e)
                        continue
                faults.fire("ingest.append", n=len(batch))
                self.store.append(batch)
                replayed += len(batch)
        if replayed:
            self.store.seal()
            _WAL_REPLAYED.inc(replayed)
            telemetry.emit("wal_replay", plane="ingest", records=replayed,
                           watermark=int(watermark), resume=int(resume))
        self.wal.truncate(self.store.sealed_rows)
        return resume

    def run(self, *, batch_size: int = 4096, limit: int = None,
            poll_updates: bool = True, target_rate: float = None,
            pipelined: bool = True, start: int = 0) -> StageTimes:
        """``target_rate`` (records/s) paces the source like the paper's
        fixed-rate Kafka input (Fig 5: 10k events/s); without it the
        pipeline runs saturated.  ``pipelined=False`` forces the strictly
        sequential generate->match->store loop (A/B accounting).
        ``start`` resumes the source mid-stream — crash recovery passes
        ``recover()``'s return value here."""
        t = self.times
        cpu0 = time.process_time()
        wall0 = time.perf_counter()
        total = limit or self.generator.spec.num_records
        done0 = start               # source rows ingested before this run
        pending = None              # batch k-1, dispatched but not stored
        pending_start = 0           # its source row (WAL/quarantine coords)
        while start < total:
            n = min(batch_size, total - start)
            t0 = time.perf_counter()
            with telemetry.span("ingest/generate", cat="ingest", n=n):
                batch = self.generator.batch(start, n)
            t1 = time.perf_counter()
            t.generate_s += t1 - t0
            _STAGE_HIST["generate"].observe(t1 - t0)
            # only device-side results can actually be in flight; host
            # backends (dfa_selective) matched synchronously at dispatch
            if pending is not None and pending.result.on_device:
                t.overlap_s += t1 - t0          # generated while k-1 matched
                _OVERLAP_S.inc(t1 - t0)
            if self.wal is not None:
                # journal FIRST: once the entry lands, a kill anywhere in
                # the dispatch/flush machinery below cannot lose the batch
                with telemetry.span("ingest/wal", cat="ingest", n=n):
                    self.wal.append(start, batch)
                wal_s = time.perf_counter() - t1
                t.wal_s += wal_s
                _STAGE_HIST["wal"].observe(wal_s)
            if self.processor is None:
                ts = time.perf_counter()
                with telemetry.span("ingest/store", cat="ingest"):
                    faults.fire("ingest.append", n=n)
                    self.store.append(batch)
                    if self.wal is not None:
                        self.wal.truncate(self.store.sealed_rows)
                store_s = time.perf_counter() - ts
                t.store_s += store_s
                _STAGE_HIST["store"].observe(store_s)
            else:
                td = time.perf_counter()
                if poll_updates:
                    self.processor.poll_updates()  # control topology
                with telemetry.span("ingest/dispatch", cat="ingest", n=n):
                    try:
                        pb = self.processor.process_async(batch)
                    except BatchMatchError as e:
                        # both lanes failed: drain k-1 first (its rows
                        # precede this batch — the watermark is a prefix),
                        # then dead-letter and keep the stream flowing
                        if pending is not None:
                            self._flush(pending, pending_start)
                            pending = None
                        self._quarantine(start, batch, e)
                        pb = None
                dispatch_s = time.perf_counter() - td
                t.process_s += dispatch_s
                _STAGE_HIST["dispatch"].observe(dispatch_s)
                if pb is None:
                    pass                        # quarantined above
                elif pipelined:
                    if pending is not None:
                        wait_s, store_s = self._flush(pending, pending_start)
                        t.process_s += wait_s
                        t.store_s += store_s
                        if pb.result.on_device:
                            t.overlap_s += store_s  # stored k-1, k in flight
                            _OVERLAP_S.inc(store_s)
                    pending, pending_start = pb, start
                else:
                    wait_s, store_s = self._flush(pb, start)
                    t.process_s += wait_s
                    t.store_s += store_s
            t.records += n
            _INGEST_RECORDS.inc(n)
            _INGEST_BATCHES.inc()
            start += n
            if target_rate:
                ahead = ((start - done0) / target_rate
                         - (time.perf_counter() - wall0))
                if ahead > 0:
                    time.sleep(ahead)
        if pending is not None:
            wait_s, store_s = self._flush(pending, pending_start)
            t.process_s += wait_s
            t.store_s += store_s
        self.store.seal()
        if self.wal is not None:
            self.wal.truncate(self.store.sealed_rows)
        t.cpu_s = time.process_time() - cpu0
        t.wall_s = time.perf_counter() - wall0
        return t


class TrainDataPipeline:
    """Enriched log stream -> packed LM token batches.

    Rule bitmaps ride along; ``include_rules``/``exclude_rules`` subselect
    records by precomputed enrichment before tokenization (no byte rescans).
    """

    def __init__(self, generator: LogGenerator,
                 processor: StreamProcessor = None, *,
                 include_rules=None, exclude_rules=None):
        self.generator = generator
        self.processor = processor
        self.include_rules = tuple(include_rules or ())
        self.exclude_rules = tuple(exclude_rules or ())
        if (self.include_rules or self.exclude_rules) and processor is None:
            raise ValueError("rule-based selection needs a stream processor")

    def _select(self, batch: RecordBatch) -> RecordBatch:
        if not (self.include_rules or self.exclude_rules):
            return batch
        bm = batch.columns[ENRICH_COLUMN]
        n_rules = self.processor.num_rules
        keep = np.ones(len(batch), bool)
        if self.include_rules:
            mask = enrichment.rule_mask(self.include_rules, n_rules)
            keep &= (bm & mask[None]).any(axis=1)
        if self.exclude_rules:
            mask = enrichment.rule_mask(self.exclude_rules, n_rules)
            keep &= ~(bm & mask[None]).any(axis=1)
        return batch.select(keep)

    def batches(self, *, seq_len: int, batch_size: int,
                records_per_step: int = 2048, limit_steps: int = None):
        """Yield {'tokens': (B, S), 'labels': (B, S)} train batches."""
        start = 0
        step = 0
        spec = self.generator.spec
        while limit_steps is None or step < limit_steps:
            raw = self.generator.batch(start % spec.num_records,
                                       records_per_step)
            start += records_per_step
            if self.processor is not None:
                self.processor.poll_updates()
                raw = self.processor.process(raw)
            raw = self._select(raw)
            if len(raw) == 0:
                continue
            text = np.concatenate([raw.columns[f] for f in raw.text_fields],
                                  axis=1)
            rows = tokenizer.encode_bytes(text)
            tokens, labels = tokenizer.pack_sequences(rows, seq_len, batch_size)
            yield {"tokens": tokens, "labels": labels}
            step += 1
