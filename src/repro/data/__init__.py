from repro.data.generator import LogGenerator, WorkloadSpec  # noqa: F401
from repro.data.pipeline import IngestPipeline, TrainDataPipeline  # noqa: F401
