"""Synthetic observability-log workload (paper §4.3).

Record schema: ``timestamp`` int64 (event time), ``status`` int32,
``event_type`` int32, and 2-5 ``content<i>`` free-text fields of ~60 words
each.  Content words are drawn from a Zipf-distributed vocabulary; **planted
terms** are injected at controlled selectivity so queries have exact,
verifiable ground truth:

  * ultra-high selectivity (paper §6.3.1): ~1e-6 match rate;
  * high selectivity (paper §6.3.2): one order of magnitude more;
  * non-matching terms (Q1): guaranteed absent from the corpus.

Everything is seeded and deterministic: the i-th record of a given spec is
identical across runs and processes (ground-truth counts can be recomputed).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.records import RecordBatch

WORDS_PER_FIELD = 60
VOCAB_SIZE = 8192


def _make_vocab(rng: np.random.Generator, n: int) -> list:
    """Deterministic pseudo-words, 3-10 chars."""
    alphabet = np.frombuffer(b"abcdefghijklmnopqrstuvwxyz", np.uint8)
    lengths = rng.integers(3, 11, size=n)
    out = []
    for i in range(n):
        chars = rng.integers(0, 26, size=lengths[i])
        out.append(alphabet[chars].tobytes().decode())
    return out


@dataclass(frozen=True)
class PlantedTerm:
    term: str
    fieldname: str
    rate: float          # fraction of records containing it


@dataclass
class WorkloadSpec:
    num_records: int = 100_000
    num_content_fields: int = 2
    text_width: int = 512
    seed: int = 7
    ultra_rate: float = 1e-5
    high_rate: float = 1e-4

    # filled by __post_init__
    planted: list = field(default_factory=list)
    absent_terms: list = field(default_factory=list)

    def __post_init__(self):
        if not self.planted:
            planted = []
            for i in range(1, self.num_content_fields + 1):
                f = f"content{i}"
                planted.append(PlantedTerm(f"ULTRAneedle{i}x", f, self.ultra_rate))
                planted.append(PlantedTerm(f"HIGHneedle{i}x", f, self.high_rate))
            self.planted = planted
        if not self.absent_terms:
            self.absent_terms = ["ZZZabsentterm1", "ZZZabsentterm2"]

    @property
    def content_fields(self) -> tuple:
        return tuple(f"content{i}" for i in range(1, self.num_content_fields + 1))


class LogGenerator:
    """Deterministic batch generator.  ``batch(start, n)`` is pure in
    (spec, start, n), so ground truth is recomputable anywhere."""

    def __init__(self, spec: WorkloadSpec):
        self.spec = spec
        vocab_rng = np.random.default_rng(spec.seed)
        self.vocab = _make_vocab(vocab_rng, VOCAB_SIZE)
        for t in spec.planted:
            if t.term in self.vocab:
                raise ValueError(f"planted term collides with vocab: {t.term}")
        # Zipf-ish word distribution over the vocab
        ranks = np.arange(1, VOCAB_SIZE + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.word_p = p / p.sum()
        # precompute byte rows for every vocab word (padded to max len + 1 space)
        self._vocab_arr = np.asarray(self.vocab)

    # -- ground truth ----------------------------------------------------
    def plant_mask(self, term: PlantedTerm, start: int, n: int) -> np.ndarray:
        """(n,) bool — which records in [start, start+n) contain the term.
        Pure in (spec, term, start, n): batch-boundary and process
        independent (stable hash, no PYTHONHASHSEED dependence)."""
        import hashlib
        th = int.from_bytes(
            hashlib.sha256(term.term.encode()).digest()[:4], "little")
        ids = np.arange(start, start + n, dtype=np.uint64)
        mix = ids * np.uint64(0x9E3779B97F4A7C15) + np.uint64(th)
        mix ^= mix >> np.uint64(31)
        mix *= np.uint64(0xBF58476D1CE4E5B9)
        mix ^= mix >> np.uint64(29)
        u = (mix >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        return u < term.rate

    def true_count(self, term: PlantedTerm, num_records: int = None) -> int:
        n = num_records or self.spec.num_records
        return int(self.plant_mask(term, 0, n).sum())

    # -- generation ----------------------------------------------------------
    def batch(self, start: int, n: int) -> RecordBatch:
        spec = self.spec
        rng = np.random.default_rng((spec.seed, start, 2))
        cols = {
            "timestamp": (start + np.arange(n)).astype(np.int64) * 1000,
            "status": rng.integers(0, 5, size=n).astype(np.int32),
            "event_type": rng.integers(0, 32, size=n).astype(np.int32),
        }
        for fieldname in spec.content_fields:
            cols[fieldname] = self._content_field(fieldname, start, n, rng)
        return RecordBatch(cols)

    def batches(self, batch_size: int, limit: int = None):
        total = limit or self.spec.num_records
        start = 0
        while start < total:
            n = min(batch_size, total - start)
            yield self.batch(start, n)
            start += n

    def _content_field(self, fieldname: str, start: int, n: int,
                       rng: np.random.Generator) -> np.ndarray:
        spec = self.spec
        words = rng.choice(self._vocab_arr, size=(n, WORDS_PER_FIELD),
                           p=self.word_p)
        # widen the fixed-width string dtype so planted terms never truncate
        words = words.astype("<U24")
        # plant terms at positions guaranteed inside the byte width
        # (first 30 words occupy <= 30 * (10+1) = 330 bytes < text_width)
        for t in spec.planted:
            if t.fieldname != fieldname:
                continue
            mask = self.plant_mask(t, start, n)
            idx = np.flatnonzero(mask)
            if len(idx):
                pos = rng.integers(0, min(30, WORDS_PER_FIELD), size=len(idx))
                words[idx, pos] = t.term
        out = np.zeros((n, spec.text_width), np.uint8)
        for i in range(n):
            line = " ".join(words[i])[:spec.text_width].encode()
            out[i, :len(line)] = np.frombuffer(line, np.uint8)
        return out
