"""Byte-level tokenizer for LM training over log text.

Vocabulary: 256 raw bytes + special tokens.  Arbitrary vocab sizes (the
assigned architectures range 504..262144) are handled by mapping bytes into
the low id range — the framework trains real models on real log bytes while
keeping each architecture's embedding table at its assigned size.
"""
from __future__ import annotations

import numpy as np

PAD = 0
BOS = 1
EOS = 2
BYTE_OFFSET = 3


def encode_bytes(data: np.ndarray, *, add_bos: bool = True) -> np.ndarray:
    """(N, L) uint8 text -> (N, L+1) int32 token ids (BOS prepended)."""
    toks = data.astype(np.int32) + BYTE_OFFSET
    toks = np.where(data == 0, PAD, toks)
    if add_bos:
        bos = np.full((data.shape[0], 1), BOS, np.int32)
        toks = np.concatenate([bos, toks], axis=1)
    return toks


def decode_tokens(tokens: np.ndarray) -> list:
    out = []
    for row in np.asarray(tokens):
        bs = bytes(int(t) - BYTE_OFFSET for t in row
                   if t >= BYTE_OFFSET and t < BYTE_OFFSET + 256)
        out.append(bs.decode("utf-8", "replace"))
    return out


def pack_sequences(token_rows: np.ndarray, seq_len: int,
                   batch: int) -> tuple:
    """Greedy-pack variable-content rows into (batch, seq_len) blocks.

    Returns (tokens, labels): labels are the next-token shift with PAD
    positions masked to -1 (ignored by the loss)."""
    flat = token_rows.reshape(-1)
    flat = flat[flat != PAD]
    need = batch * (seq_len + 1)
    if len(flat) < need:
        reps = -(-need // max(len(flat), 1))
        flat = np.tile(flat, reps)
    flat = flat[:need].reshape(batch, seq_len + 1)
    tokens = flat[:, :-1].astype(np.int32)
    labels = flat[:, 1:].astype(np.int32)
    return tokens, labels
