"""Serving drivers — both planes that live under ``repro.serve``:

**Front-end mode** (``--port``): build an enriched store from the synthetic
log workload, then serve it over the socket/HTTP front end
(``repro.serve.frontend``) with per-client admission control, bounded
backpressure, deadline shedding, and the ``/metrics`` Prometheus scrape —
the query plane's real ingress (docs/SERVING.md)::

    PYTHONPATH=src python -m repro.launch.serve --port 7171 \\
        --records 20000 --rules 200 --segment-size 4000 \\
        --max-inflight 8 --rate-per-client 100

**Model mode** (``--arch``): batched generation over log-derived prompts,
with the serving telemetry fed back through the FluxSieve ingestion path
(the paper's recurrent-dashboard loop over serving logs)::

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --reduced \\
        --requests 16 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.matcher import compile_bundle
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.store import SegmentStore
from repro.core.stream_processor import StreamProcessor
from repro.data import tokenizer
from repro.data.generator import LogGenerator, WorkloadSpec


def _serve_frontend(args) -> int:
    """Build a world (same construction as the benchmarks) and serve it."""
    from repro.data.pipeline import IngestPipeline
    from repro.launch.ingest import synth_ruleset
    from repro.serve.frontend import FrontEnd

    spec = WorkloadSpec(num_records=args.records)
    gen = LogGenerator(spec)
    ruleset = synth_ruleset(spec, args.rules)
    proc = StreamProcessor(compile_bundle(ruleset, spec.content_fields),
                           backend="dfa_ref")
    store = SegmentStore(segment_size=args.segment_size, root=args.store,
                         index_fields=spec.content_fields)
    times = IngestPipeline(gen, store, proc).run(batch_size=4096)
    print(f"ingested {times.records} records into {len(store.segments)} "
          f"segments ({times.throughput():,.0f} rec/s)")
    engine = QueryEngine(store, mapper=QueryMapper(ruleset),
                         shards=args.shards)

    def ingest_sink(batch):
        out = proc.process(batch)
        store.append(out)
        return len(batch)

    fe = FrontEnd(engine, host=args.host, port=args.port,
                  max_inflight=args.max_inflight, max_queue=args.max_queue,
                  rate_per_client=args.rate_per_client, burst=args.burst,
                  default_deadline_s=args.deadline,
                  ingest=ingest_sink).start()
    print(f"serving on {fe.host}:{fe.port} "
          f"(max_inflight={fe.max_inflight} max_queue={fe.max_queue} "
          f"rate_per_client={fe.admission.rate}/s "
          f"burst={fe.admission.burst}); routes: query/standing/ingest, "
          f"GET /metrics, GET /healthz", flush=True)
    try:
        if args.serve_seconds is not None:
            time.sleep(args.serve_seconds)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        fe.close()
        engine.close()
    return 0


def _serve_model(args) -> int:
    import jax

    from repro.models.model import Model
    from repro.serve.engine import Request, ServeEngine

    model = Model.from_name(args.arch, reduced=args.reduced)
    if not model.cfg.supports_decode:
        raise SystemExit(f"{model.cfg.name} is encoder-only; no decode")
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params, batch_size=args.batch,
                         max_cache=args.prompt_len + args.max_new + 1)

    # prompts from the log corpus (fixed-width -> equal-length buckets)
    wspec = WorkloadSpec(num_records=args.requests, seed=args.seed)
    gen = LogGenerator(wspec)
    raw = gen.batch(0, args.requests)
    toks = tokenizer.encode_bytes(raw.columns["content1"])[:, :args.prompt_len]
    toks = np.maximum(toks, 1) % model.cfg.vocab_size
    for i in range(args.requests):
        engine.submit(Request(i, toks[i].astype(np.int32),
                              max_new_tokens=args.max_new))
    responses = engine.run()
    for r in sorted(responses, key=lambda r: r.request_id)[:8]:
        print(f"req {r.request_id:3d}: {r.new_tokens} tokens, "
              f"prefill {r.prefill_ms:.1f} ms, decode {r.decode_ms:.1f} ms")
    print(f"served {len(responses)} requests")

    # telemetry -> FluxSieve ingestion -> analytical plane
    slow_rule = RuleSet((Rule(0, "served", "serve request", fields=("content1",)),))
    bundle = compile_bundle(slow_rule, ("content1",))
    proc = StreamProcessor(bundle, backend="dfa_ref")
    telemetry = proc.process(engine.telemetry_batch())
    store = SegmentStore(segment_size=1024)
    store.append(telemetry)
    store.seal()
    qe = QueryEngine(store, mapper=QueryMapper(slow_rule))
    res = qe.execute(Query(terms=(("content1", "serve request"),),
                           mode="count"), path="fluxsieve")
    print(f"telemetry dashboard: {res.count} serve records "
          f"({res.latency_s * 1e3:.2f} ms via {res.path})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    # front-end mode
    ap.add_argument("--port", type=int, default=None,
                    help="serve the query front end on this port "
                         "(0 = ephemeral; omit for model mode)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--records", type=int, default=20_000,
                    help="front end: synthetic records to ingest before "
                         "serving")
    ap.add_argument("--rules", type=int, default=200)
    ap.add_argument("--segment-size", type=int, default=4000)
    ap.add_argument("--shards", type=int, default=1,
                    help="front end: sharded query executor width")
    ap.add_argument("--store", default=None, help="spill directory")
    ap.add_argument("--max-inflight", type=int, default=8,
                    help="concurrent requests executing against the engine")
    ap.add_argument("--max-queue", type=int, default=32,
                    help="admitted requests allowed to wait for a slot "
                         "before queue_full shedding")
    ap.add_argument("--rate-per-client", type=float, default=100.0,
                    help="token-bucket refill rate per client id (req/s)")
    ap.add_argument("--burst", type=float, default=None,
                    help="token-bucket capacity (default: rate)")
    ap.add_argument("--deadline", type=float, default=5.0,
                    help="default request deadline seconds (clients may "
                         "override per request)")
    ap.add_argument("--serve-seconds", type=float, default=None,
                    help="serve for N seconds then exit (default: forever)")
    # model mode
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.port is not None:
        return _serve_frontend(args)
    if args.arch is None:
        ap.error("pass --port (query front end) or --arch (model serving)")
    return _serve_model(args)


if __name__ == "__main__":
    raise SystemExit(main())
