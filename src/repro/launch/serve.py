"""Serving driver: batched generation over log-derived prompts, with the
serving telemetry fed back through the FluxSieve ingestion path (the
paper's recurrent-dashboard loop over serving logs).

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --reduced \\
        --requests 16 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import base as cfgbase
from repro.core.matcher import compile_bundle
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.store import SegmentStore
from repro.core.stream_processor import StreamProcessor
from repro.data import tokenizer
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model = Model.from_name(args.arch, reduced=args.reduced)
    if not model.cfg.supports_decode:
        raise SystemExit(f"{model.cfg.name} is encoder-only; no decode")
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params, batch_size=args.batch,
                         max_cache=args.prompt_len + args.max_new + 1)

    # prompts from the log corpus (fixed-width -> equal-length buckets)
    wspec = WorkloadSpec(num_records=args.requests, seed=args.seed)
    gen = LogGenerator(wspec)
    raw = gen.batch(0, args.requests)
    toks = tokenizer.encode_bytes(raw.columns["content1"])[:, :args.prompt_len]
    toks = np.maximum(toks, 1) % model.cfg.vocab_size
    for i in range(args.requests):
        engine.submit(Request(i, toks[i].astype(np.int32),
                              max_new_tokens=args.max_new))
    responses = engine.run()
    for r in sorted(responses, key=lambda r: r.request_id)[:8]:
        print(f"req {r.request_id:3d}: {r.new_tokens} tokens, "
              f"prefill {r.prefill_ms:.1f} ms, decode {r.decode_ms:.1f} ms")
    print(f"served {len(responses)} requests")

    # telemetry -> FluxSieve ingestion -> analytical plane
    slow_rule = RuleSet((Rule(0, "served", "serve request", fields=("content1",)),))
    bundle = compile_bundle(slow_rule, ("content1",))
    proc = StreamProcessor(bundle, backend="dfa_ref")
    telemetry = proc.process(engine.telemetry_batch())
    store = SegmentStore(segment_size=1024)
    store.append(telemetry)
    store.seal()
    qe = QueryEngine(store, mapper=QueryMapper(slow_rule))
    res = qe.execute(Query(terms=(("content1", "serve request"),),
                           mode="count"), path="fluxsieve")
    print(f"telemetry dashboard: {res.count} serve records "
          f"({res.latency_s * 1e3:.2f} ms via {res.path})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
