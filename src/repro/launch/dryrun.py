import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and derive roofline terms from the compiled
artifact.  No arrays are allocated: parameters, optimizer state, caches, and
inputs are ShapeDtypeStructs with production shardings attached.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/

Exit code is non-zero if any attempted cell fails (skips are not failures).
"""  # noqa: E402 — XLA_FLAGS must precede every jax-importing module

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import base as cfgbase
from repro.distributed import sharding
from repro.launch import hlo, roofline
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.serve import kv_cache
from repro.serve.serve_step import (build_decode_step, build_encode_step,
                                    build_prefill_step)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainStepConfig, batch_sharding,
                                    build_train_step, state_shardings)


def _sds(shape, dtype, ns=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=ns)


def _attach(spec_tree, shard_tree):
    return jax.tree.map(lambda s, ns: _sds(s.shape, s.dtype, ns),
                        spec_tree, shard_tree)


def _batch_specs(model, shape_name, mesh, rules):
    """Input ShapeDtypeStructs with batch sharding (replicated when the
    batch dim does not divide the data axes — e.g. long_500k B=1)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    specs = model.input_specs(shape_name)
    n_data = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_data *= mesh.shape[a]
    bspec = sharding.logical_to_spec(("batch",), mesh, rules)

    def attach(s):
        if s.shape and s.shape[0] % n_data == 0 and s.shape[0] > 1:
            ns = NamedSharding(mesh, bspec)
        else:
            ns = NamedSharding(mesh, P())
        return _sds(s.shape, s.dtype, ns)

    return jax.tree.map(attach, specs)


def _state_specs(model, ts_cfg, mesh, rules):
    import jax.numpy as jnp
    p = model.param_specs()
    f32 = jnp.float32
    specs = {"params": p,
             "opt": {"mu": jax.tree.map(lambda s: _sds(s.shape, f32), p),
                     "nu": jax.tree.map(lambda s: _sds(s.shape, f32), p),
                     "count": _sds((), jnp.int32)},
             "step": _sds((), jnp.int32)}
    if ts_cfg.grad_compression == "int8":
        specs["grad_err"] = jax.tree.map(lambda s: _sds(s.shape, f32), p)
    return _attach(specs, state_shardings(model, ts_cfg, mesh, rules))


def lower_cell(model_or_arch, shape_name: str, mesh, *,
               ts_cfg: TrainStepConfig = None,
               rules=sharding.DEFAULT_RULES, unroll: bool = False):
    """-> (lowered, kind).  Raises on sharding/lowering errors."""
    import dataclasses
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    model = (Model.from_name(model_or_arch)
             if isinstance(model_or_arch, str) else model_or_arch)
    spec = cfgbase.SHAPES[shape_name]
    ts_cfg = ts_cfg or TrainStepConfig(optimizer=OptimizerConfig())
    if unroll:
        ts_cfg = dataclasses.replace(ts_cfg, unroll=True)

    if spec.kind == "train":
        step = build_train_step(model, ts_cfg, mesh, rules)
        state = _state_specs(model, ts_cfg, mesh, rules)
        batch = _batch_specs(model, shape_name, mesh, rules)
        return step.lower(state, batch), "train_step"

    p_specs = _attach(model.param_specs(),
                      model.param_shardings(mesh, rules))
    if spec.kind == "prefill":
        batch = _batch_specs(model, shape_name, mesh, rules)
        if not model.cfg.supports_decode:      # encoder-only: full forward
            step = build_encode_step(model, mesh, rules, unroll=unroll)
            return step.lower(p_specs, batch), "encode_step"
        step = build_prefill_step(model, mesh, rules, unroll=unroll)
        return step.lower(p_specs, batch), "prefill_step"

    # decode: one token against a cache of seq_len
    B = spec.global_batch
    caches = kv_cache.cache_specs(model, B, spec.seq_len, mesh, rules)
    tok_tree = _batch_specs(model, shape_name, mesh, rules)
    step = build_decode_step(model, mesh, rules, donate=False, unroll=unroll)
    cache_len = _sds((), jnp.int32, NamedSharding(mesh, P()))
    return step.lower(p_specs, tok_tree["tokens"], caches,
                      cache_len), "decode_step"


def _probe_plan(cfg):
    """Layer counts for the two cost probes + the extrapolation variable.

    Per-segment HLO cost is affine in the segment repeat count, so two
    small unrolled probes recover the full model exactly:
        cost(n) = a + b * n;  b = (c2 - c1) / (n2 - n1);  cost(n_full).
    The probe layer counts preserve the segment structure (remainder
    segments, leading dense layers) so 'a' is identical across probes."""
    L = cfg.num_layers
    if cfg.global_interval > 1:
        unit, base = cfg.global_interval, L % cfg.global_interval
    elif cfg.shared_attn_interval > 0:
        unit, base = cfg.shared_attn_interval, L % cfg.shared_attn_interval
    elif cfg.first_k_dense > 0:
        unit, base = 1, cfg.first_k_dense
    else:
        unit, base = 1, 0
    n_full = (L - base) // unit
    # larger probes sit in XLA's asymptotic fusion regime (per-layer cost
    # drifts upward at tiny depths — see EXPERIMENTS.md §Roofline method);
    # interval archs pay >= 6 layers per unit so 1:2 units is already deep
    if unit == 1:
        n1 = min(4, max(1, n_full - 1))
        n2 = min(8, n_full)
    else:
        n1, n2 = 1, 2
    if n2 <= n1:
        n1, n2 = max(1, n2 - 1), n2
    return base + unit * n1, base + unit * n2, n1, n2, n_full


def _cost_sample(compiled):
    cost = compiled.cost_analysis()
    stats = hlo.parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": dict(stats.bytes_by_kind),
            "coll_count": dict(stats.count_by_kind)}


def _extrapolate(s1, s2, n1, n2, n_full):
    def ab(c1, c2):
        b = (c2 - c1) / (n2 - n1)
        a = c1 - b * n1
        return max(a + b * n_full, 0.0)

    kinds = set(s1["coll_bytes"]) | set(s2["coll_bytes"])
    return {
        "flops": ab(s1["flops"], s2["flops"]),
        "bytes": ab(s1["bytes"], s2["bytes"]),
        "coll_bytes": {k: ab(s1["coll_bytes"].get(k, 0),
                             s2["coll_bytes"].get(k, 0)) for k in kinds},
        "coll_count": {k: ab(s1["coll_count"].get(k, 0),
                             s2["coll_count"].get(k, 0)) for k in kinds},
    }


def _probe_cost(cfg, shape_name, mesh, ts_cfg, rules=sharding.DEFAULT_RULES):
    """Extrapolated full-model cost from two small unrolled probes.

    Probes run at microbatches=1: per-step FLOPs/bytes are identical to the
    accumulated configuration; the FSDP param-gather collective component is
    counted once (the mb=1 lower bound — microbatching multiplies it by the
    accumulation count, called out in EXPERIMENTS.md §Perf)."""
    import dataclasses
    ts_probe = (dataclasses.replace(ts_cfg, microbatches=1)
                if ts_cfg is not None else None)
    L1, L2, n1, n2, n_full = _probe_plan(cfg)
    samples = []
    for Lp in (L1, L2):
        pcfg = dataclasses.replace(cfg, name=f"{cfg.name}-probe{Lp}",
                                   num_layers=Lp)
        lowered, _ = lower_cell(Model(pcfg), shape_name, mesh,
                                ts_cfg=ts_probe, rules=rules, unroll=True)
        samples.append(_cost_sample(lowered.compile()))
    return _extrapolate(samples[0], samples[1], n1, n2, n_full)


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             ts_cfg: TrainStepConfig = None, out_dir=None,
             verbose: bool = True, cost_mode: str = "probe",
             rules=sharding.DEFAULT_RULES, tag: str = "") -> dict:
    """Production (scanned) compile proves the sharding + memory fit; the
    cost pass (probe-extrapolated unrolled lowering) yields honest
    FLOP/byte/collective accounting (XLA cost analysis counts while-loop
    bodies once — DESIGN.md §Roofline method)."""
    cfg = cfgbase.get_config(arch)
    spec = cfgbase.SHAPES[shape_name]
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not cfg.shape_supported(shape_name):
        cell.update(status="skip", reason=cfg.skip_reason(shape_name))
        return cell
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    t0 = time.time()
    lowered, kind = lower_cell(arch, shape_name, mesh, ts_cfg=ts_cfg,
                               rules=rules)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    if cost_mode == "probe":
        sample = _probe_cost(cfg, shape_name, mesh, ts_cfg, rules)
    elif cost_mode == "unroll":
        lowered_u, _ = lower_cell(arch, shape_name, mesh, ts_cfg=ts_cfg,
                                  rules=rules, unroll=True)
        sample = _cost_sample(lowered_u.compile())
    else:  # scan: cheap, under-counts loop bodies
        sample = _cost_sample(compiled)
    t3 = time.time()
    mf = roofline.model_flops(cfg, spec)
    terms = roofline.RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        device_flops=sample["flops"], device_bytes=sample["bytes"],
        device_collective_bytes=sum(sample["coll_bytes"].values()),
        collective_detail={
            "total_bytes": sum(sample["coll_bytes"].values()),
            **{f"{k}_bytes": v for k, v in sorted(sample["coll_bytes"].items())},
            **{f"{k}_count": v for k, v in sorted(sample["coll_count"].items())}},
        memory_per_device={
            k: int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")},
        model_flops_global=mf)
    cell.update(status="ok", kind=kind, chips=chips,
                lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
                cost_s=round(t3 - t2, 2), cost_mode=cost_mode,
                roofline=terms.to_dict(),
                memory_analysis=str(mem))
    if verbose:
        print(terms.row(), flush=True)
        print(f"    mem/device: {terms.memory_per_device} "
              f"collectives: {terms.collective_detail}", flush=True)
    if out_dir:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        name = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        (out / name).write_text(json.dumps(cell, indent=1))
    return cell


def all_cells():
    for arch in cfgbase.list_configs():
        for shape in cfgbase.SHAPES:
            yield arch, shape


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "int8"))
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--cost-mode", default="probe",
                    choices=("probe", "unroll", "scan"),
                    help="probe: extrapolate cost from two small unrolled "
                         "probes (default); unroll: full unrolled compile "
                         "(slow); scan: cheap but under-counts loop bodies")
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose result JSON already exists")
    args = ap.parse_args(argv)

    ts_cfg = TrainStepConfig(microbatches=args.microbatches,
                             remat=not args.no_remat,
                             grad_compression=args.grad_compression,
                             optimizer=OptimizerConfig())
    if args.all:
        cells = list(all_cells())
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    failures = 0
    for arch, shape in cells:
        for mesh_name in meshes:
            if args.skip_existing:
                suffix = f"__{args.tag}" if args.tag else ""
                fn = Path(args.out) / f"{arch}__{shape}__{mesh_name}{suffix}.json"
                if fn.exists():
                    continue
            try:
                cell = run_cell(arch, shape, mesh_name, ts_cfg=ts_cfg,
                                out_dir=args.out, cost_mode=args.cost_mode,
                                tag=args.tag)
                if cell["status"] == "skip":
                    print(f"{arch:24s} {shape:12s} {mesh_name:10s} "
                          f"SKIP: {cell['reason']}", flush=True)
            except Exception:
                failures += 1
                print(f"{arch:24s} {shape:12s} {mesh_name:10s} FAILED",
                      flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
