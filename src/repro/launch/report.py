"""Render the roofline table + dry-run summary from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import base as cfgbase

HBM_PER_CHIP = 16 * 2**30  # v5e


def load_cells(dirpath, tag: str = "") -> list:
    cells = []
    for f in sorted(Path(dirpath).glob("*.json")):
        parts = f.stem.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        cells.append(json.loads(f.read_text()))
    return cells


def mem_total(cell) -> int:
    m = cell["roofline"]["memory_per_device"]
    return (m.get("argument_size_in_bytes", 0)
            + m.get("temp_size_in_bytes", 0)
            + m.get("output_size_in_bytes", 0))


def roofline_row(cell) -> str:
    r = cell["roofline"]
    mem_gib = mem_total(cell) / 2**30
    fits = "Y" if mem_total(cell) <= HBM_PER_CHIP else "OVER"
    if cell.get("cost_mode") == "scan":
        # scan-mode cells prove compile + memory only; XLA counts loop
        # bodies once so the cost columns would be meaningless
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"n/a | n/a | n/a | compile+mem proof | n/a | n/a | "
                f"{mem_gib:.1f} ({fits}) |")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.1%} | {r['roofline_fraction']:.2%} | "
            f"{mem_gib:.1f} ({fits}) |")


def skipped_rows() -> list:
    out = []
    for arch in cfgbase.list_configs():
        cfg = cfgbase.get_config(arch)
        for shape in cfgbase.SHAPES:
            if not cfg.shape_supported(shape):
                out.append(f"| {arch} | {shape} | — | "
                           f"skip: {cfg.skip_reason(shape)[:60]}… |")
    return out


HEADER = ("| arch | shape | mesh | compute s | memory s | collective s | "
          "dominant | useful | roofline | mem/dev GiB |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    cells = load_cells(args.dir, args.tag)
    print(HEADER)
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if c.get("status") == "ok":
            print(roofline_row(c))
    print()
    print("Skipped cells (assignment-mandated):")
    for row in skipped_rows():
        print(row)
    oks = [c for c in cells if c.get("status") == "ok"]
    print(f"\n{len(oks)} cells compiled OK")
    return 0


if __name__ == "__main__":
    main()
