"""End-to-end training driver.

Trains an architecture (reduced or full config) on the FluxSieve-enriched
log stream with checkpoint/restart, straggler monitoring, and optional
rule-based data curation:

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --reduced \\
        --steps 50 --batch 8 --seq 256 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cfgbase
from repro.core.matcher import compile_bundle
from repro.core.patterns import Rule, RuleSet
from repro.core.stream_processor import StreamProcessor
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import TrainDataPipeline
from repro.models.model import Model
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.fault_tolerance import StragglerMonitor
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainStepConfig, build_train_step, init_state


def default_ruleset(spec: WorkloadSpec) -> RuleSet:
    """Rules for the planted workload terms (quality/PII-filter stand-ins)."""
    rules = []
    for i, t in enumerate(spec.planted):
        rules.append(Rule(i, t.term, t.term, fields=(t.fieldname,)))
    return RuleSet(tuple(rules))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True,
                    help=f"one of {cfgbase.list_configs()}")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--exclude-rules", type=int, nargs="*", default=None,
                    help="drop records matching these rule ids (curation)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model = Model.from_name(args.arch, reduced=args.reduced)
    print(f"arch={model.cfg.name} params={model.param_count()/1e6:.1f}M")

    # data plane: enriched log stream
    wspec = WorkloadSpec(num_records=50_000, ultra_rate=1e-3, high_rate=1e-2,
                         seed=args.seed)
    gen = LogGenerator(wspec)
    ruleset = default_ruleset(wspec)
    bundle = compile_bundle(ruleset, wspec.content_fields)
    proc = StreamProcessor(bundle, backend="dfa_ref")
    pipe = TrainDataPipeline(gen, proc, exclude_rules=args.exclude_rules)

    ts_cfg = TrainStepConfig(
        microbatches=args.microbatches,
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=args.steps // 10 + 1,
                                  total_steps=args.steps))
    state = init_state(model, jax.random.key(args.seed), ts_cfg)
    step_fn = build_train_step(model, ts_cfg)

    start = 0
    saver = None
    if args.ckpt:
        saver = AsyncCheckpointer(args.ckpt)
        restored = latest_step(args.ckpt)
        if restored is not None:
            state, _ = restore_checkpoint(args.ckpt, restored, state)
            start = restored
            print(f"restored step {start}")

    monitor = StragglerMonitor()
    host = "host-0"
    it = pipe.batches(seq_len=args.seq, batch_size=args.batch,
                      limit_steps=args.steps - start)
    import jax.numpy as jnp
    for i, batch in enumerate(it, start=start):
        t0 = time.perf_counter()
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray, batch))
        dt = time.perf_counter() - t0
        monitor.report(host, dt)
        if saver and (i + 1) % args.save_every == 0:
            saver.save(i + 1, state, {"arch": model.cfg.name})
        print(f"step {i + 1:5d} loss {float(metrics['loss']):.4f} "
              f"lr {float(metrics['lr']):.2e} {dt * 1e3:7.1f} ms "
              f"tok/s {args.batch * args.seq / dt:,.0f}")
    if saver:
        saver.save(args.steps, state, {"arch": model.cfg.name})
        saver.wait()
    if monitor.stragglers():
        print("stragglers:", monitor.stragglers())
    print(f"processed {proc.stats.records_in} records, "
          f"{proc.stats.records_matched} matched rules")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
