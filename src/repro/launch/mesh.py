"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state.  The caller is responsible for the placeholder
device count (launch/dryrun.py sets XLA_FLAGS before any import).

Mesh shapes (TPU v5e pods):
  single pod : (16, 16)       axes (data, model)   = 256 chips
  multi  pod : (2, 16, 16)    axes (pod, data, model) = 512 chips
"""
from __future__ import annotations

import jax

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, found {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before the first jax import (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over whatever devices exist (tests on 1 CPU device)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
