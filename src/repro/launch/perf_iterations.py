import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb iterations on the model plane — each iteration re-lowers
one dry-run cell with a candidate change and writes a tagged result JSON for
before/after comparison against the baseline cell.

    PYTHONPATH=src python -m repro.launch.perf_iterations --iter b1
    b1: phi3-mini train_4k, pure-FSDP rules (no TP) — kills the Megatron
        activation all-reduces for a model that does not need TP at 3.8B.
    b2: phi3-mini train_4k on the multi-pod mesh, int8+error-feedback
        cross-pod gradient psum vs the fp32 GSPMD all-reduce.
    b3: phi3-mini train_4k, FSDP + remat policy keeping checkpointed dots
        (fewer collective replays in backward).
    c1: yi-34b decode_32k with int8 KV cache (+bf16 scales).
    c2: yi-34b decode_32k int8 KV + pure-data decode sharding.
"""  # noqa: E402

import argparse
import dataclasses
import sys

from repro.configs import base as cfgbase
from repro.distributed.sharding import DEFAULT_RULES, ShardingRules
from repro.launch import dryrun
from repro.models.model import Model
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainStepConfig

FSDP_RULES = ShardingRules(tuple(dict(DEFAULT_RULES.rules, **{
    "batch": ("pod", "data", "model"),    # all chips carry batch DP
    "embed": ("data", "model"),           # params fully FSDP-sharded
    "mlp": None, "heads": None, "kv_heads": None, "vocab": None,
    "expert": None, "expert_mlp": ("data", "model"),
    "heads_act": None, "mlp_act": None, "vocab_act": None,
    "kv_seq": "model",                    # decode KV stays seq-sharded
}).items()))

# Serving posture: weights RESIDENT (TP-sharded over model, replicated over
# data) — no per-step FSDP parameter all-gathers at decode time.
SERVE_RULES = ShardingRules(tuple(dict(DEFAULT_RULES.rules, **{
    "embed": None,
    "expert_mlp": None,
}).items()))


def run(name: str, out_dir: str = "results/dryrun") -> dict:
    opt = OptimizerConfig()
    if name == "b1":
        ts = TrainStepConfig(microbatches=1, optimizer=opt)
        return dryrun.run_cell("phi3-mini-3.8b", "train_4k", "single",
                               ts_cfg=ts, out_dir=out_dir, rules=FSDP_RULES,
                               tag="b1_fsdp")
    if name == "b2":
        ts = TrainStepConfig(microbatches=8, grad_compression="int8",
                             optimizer=opt)
        return dryrun.run_cell("phi3-mini-3.8b", "train_4k", "multi",
                               ts_cfg=ts, out_dir=out_dir,
                               tag="b2_int8grad")
    if name == "b2base":
        ts = TrainStepConfig(microbatches=8, optimizer=opt)
        return dryrun.run_cell("phi3-mini-3.8b", "train_4k", "multi",
                               ts_cfg=ts, out_dir=out_dir, tag="b2_base")
    if name == "b3":
        ts = TrainStepConfig(microbatches=1, optimizer=opt)
        return dryrun.run_cell("phi3-mini-3.8b", "train_4k", "single",
                               ts_cfg=ts, out_dir=out_dir, rules=FSDP_RULES,
                               tag="b3_fsdp_mb8")
    if name == "c0":    # re-baselined with result-size AG accounting
        return dryrun.run_cell("yi-34b", "decode_32k", "single",
                               out_dir=out_dir, tag="c0_base")
    if name in ("c1", "c2"):
        cfg = dataclasses.replace(cfgbase.get_config("yi-34b"),
                                  kv_cache_dtype="int8")
        # register a variant config under a tagged name
        cfgbase.register(dataclasses.replace(cfg, name="yi-34b-kvq"))
        rules = SERVE_RULES if name == "c2" else DEFAULT_RULES
        return dryrun.run_cell("yi-34b-kvq", "decode_32k", "single",
                               out_dir=out_dir, rules=rules,
                               tag=f"{name}_int8kv")
    if name == "c2base":  # resident weights, bf16 cache (isolate the rules)
        return dryrun.run_cell("yi-34b", "decode_32k", "single",
                               out_dir=out_dir, rules=SERVE_RULES,
                               tag="c2_base_resident")
    raise SystemExit(f"unknown iteration {name}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iter", required=True,
                    choices=("b1", "b2", "b2base", "b3", "c0", "c1", "c2", "c2base"))
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)
    cell = run(args.iter, args.out)
    return 0 if cell.get("status") == "ok" else 1


if __name__ == "__main__":
    sys.exit(main())
