"""Minimal HLO-text parser for collective accounting.

``compiled.cost_analysis()`` has no collective term, so we parse the
partitioned module text: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction
contributes the byte size of its operands (per the task spec).  Shapes are
post-partitioning, i.e. per-device; multiply by the device count for the
global volume.

Also classifies volume by mesh axis when replica_groups are recoverable —
cross-pod vs in-pod traffic feed different rooflines (DCN vs ICI).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*\)|\S+)\s+([\w\-]+)")
_OPERAND_RE = re.compile(r"\(([^)]*)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """'bf16[256,4096]{1,0}' -> bytes; tuples sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    count_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    instructions: list = field(default_factory=list)  # (kind, bytes, line)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "total_count": self.total_count,
                **{f"{k}_bytes": v for k, v in sorted(self.bytes_by_kind.items())},
                **{f"{k}_count": v for k, v in sorted(self.count_by_kind.items())}}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective instruction in the module."""
    # first pass: instruction name -> result shape (for operand lookups)
    shapes: dict = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_shape, op = m.group(1), m.group(2), m.group(3)
        kind = next((c for c in COLLECTIVES
                     if op == c or op.startswith(c + "-")), None)
        if kind is None:
            continue
        if kind == "all-gather":
            # the operand is the 1/N local shard; per-device traffic is
            # ~(N-1)/N x result — count the RESULT size (upper bound) so
            # FSDP param gathers are not under-counted N-fold
            nbytes = shape_bytes(result_shape)
        else:
            # operands: text between the first '(' after the op name and ')'
            rest = line[line.index(op) + len(op):]
            om = _OPERAND_RE.search(rest)
            nbytes = 0
            if om:
                for operand in om.group(1).split(","):
                    operand = operand.strip().lstrip("%")
                    # operands may carry inline types: 'bf16[8,128] %x.3'
                    if "[" in operand:
                        nbytes += shape_bytes(operand)
                    else:
                        ref = shapes.get(operand)
                        if ref:
                            nbytes += shape_bytes(ref)
            if nbytes == 0:  # fall back to result size (all-reduce: equal)
                nbytes = shape_bytes(result_shape)
        stats.bytes_by_kind[kind] += nbytes
        stats.count_by_kind[kind] += 1
        stats.instructions.append((kind, nbytes, line.strip()[:160]))
    return stats
