"""Standalone FluxSieve ingestion driver — the paper's deployment shape:
source -> stream processor (multi-pattern match + enrich) -> columnar store,
with the updater feedback loop live (profiler promotes hot predicates).

    PYTHONPATH=src python -m repro.launch.ingest --records 100000 \\
        --rules 1000 --mode enrich --store /tmp/segments
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.control_plane import ControlBus
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.profiler import QueryProfiler
from repro.core.query.store import SegmentStore
from repro.core.stream_processor import StreamProcessor
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import IngestPipeline


def synth_ruleset(spec: WorkloadSpec, num_rules: int) -> RuleSet:
    """Planted-term rules + filler literal rules (the paper evaluates
    1000-pattern rule sets; filler rules match nothing by construction)."""
    rules = [Rule(i, t.term, t.term, fields=(t.fieldname,))
             for i, t in enumerate(spec.planted)]
    k = len(rules)
    for i in range(k, num_rules):
        rules.append(Rule(i, f"filler{i}", f"QQfiller{i:04d}qq", fields=("*",)))
    return RuleSet(tuple(rules))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--records", type=int, default=100_000)
    ap.add_argument("--rules", type=int, default=1000)
    ap.add_argument("--mode", default="enrich", choices=("enrich", "filter"))
    ap.add_argument("--backend", default="dfa_ref",
                    choices=("dfa", "dfa_ref", "shift_or", "parallel"))
    ap.add_argument("--store", default=None, help="spill directory")
    ap.add_argument("--segment-size", type=int, default=50_000)
    ap.add_argument("--batch-size", type=int, default=4096)
    ap.add_argument("--fields", type=int, default=2)
    args = ap.parse_args(argv)

    spec = WorkloadSpec(num_records=args.records,
                        num_content_fields=args.fields)
    gen = LogGenerator(spec)
    ruleset = synth_ruleset(spec, args.rules)
    t0 = time.perf_counter()
    bundle = compile_bundle(ruleset, spec.content_fields)
    print(f"compiled {ruleset.num_rules} rules in "
          f"{time.perf_counter() - t0:.2f}s "
          f"({sum(e.num_states for e in bundle.engines.values())} DFA states)")

    bus, ostore = ControlBus(), ObjectStore()
    updater = MatcherUpdater(ostore, bus, spec.content_fields,
                             initial=ruleset)
    proc = StreamProcessor(bundle, mode=args.mode, backend=args.backend,
                           bus=bus, store=ostore)
    store = SegmentStore(segment_size=args.segment_size, root=args.store)
    pipe = IngestPipeline(gen, store, proc)
    times = pipe.run(batch_size=args.batch_size)
    print(f"ingested {times.records} records in "
          f"{times.generate_s + times.process_s + times.store_s:.2f}s "
          f"({times.throughput():,.0f} rec/s; "
          f"match+enrich {times.process_s:.2f}s; cpu {times.cpu_s:.2f}s)")
    print(f"segments: {len(store.segments)}, matched "
          f"{proc.stats.records_matched}/{proc.stats.records_in}")

    # query the enriched store through the mapper
    mapper = QueryMapper(ruleset)
    profiler = QueryProfiler()
    qe = QueryEngine(store, mapper=mapper, profiler=profiler)
    term = spec.planted[0]
    res = qe.execute(Query(terms=((term.fieldname, term.term),),
                           mode="count"))
    truth = gen.true_count(term)
    print(f"query[{term.term}] path={res.path} count={res.count} "
          f"(truth {truth}) in {res.latency_s * 1e3:.2f} ms")
    assert res.count == truth
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
