"""Standalone FluxSieve ingestion driver — the paper's deployment shape:
source -> stream processor (multi-pattern match + enrich) -> columnar store,
with the updater feedback loop live (profiler promotes hot predicates).

    PYTHONPATH=src python -m repro.launch.ingest --records 100000 \\
        --rules 1000 --mode enrich --store /tmp/segments
"""
from __future__ import annotations

import argparse
import os
import threading
import time
from pathlib import Path

import numpy as np

from repro.core import telemetry
from repro.core.control_plane import (CONTROL_DIRNAME, ControlBus,
                                      DurableControlBus)
from repro.core.maintenance import (Compactor, MaintenancePolicy,
                                    MaintenanceScheduler,
                                    MaintenanceWorkerPool,
                                    ProcessMaintenancePool, RetentionPolicy,
                                    RetentionWorker, SpillGC)
from repro.core.matcher import compile_bundle
from repro.core.object_store import ObjectStore
from repro.core.patterns import Rule, RuleSet
from repro.core.query.engine import Query, QueryEngine
from repro.core.query.mapper import QueryMapper
from repro.core.query.profiler import QueryProfiler
from repro.core.query.store import (INGEST_WAL_DIRNAME, MANIFEST_NAME,
                                    SegmentStore)
from repro.core.stream_processor import StreamProcessor
from repro.core.updater import MatcherUpdater
from repro.data.generator import LogGenerator, WorkloadSpec
from repro.data.pipeline import IngestPipeline


def synth_ruleset(spec: WorkloadSpec, num_rules: int) -> RuleSet:
    """Planted-term rules + filler literal rules (the paper evaluates
    1000-pattern rule sets; filler rules match nothing by construction)."""
    rules = [Rule(i, t.term, t.term, fields=(t.fieldname,))
             for i, t in enumerate(spec.planted)]
    k = len(rules)
    for i in range(k, num_rules):
        rules.append(Rule(i, f"filler{i}", f"QQfiller{i:04d}qq", fields=("*",)))
    return RuleSet(tuple(rules))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--records", type=int, default=100_000)
    ap.add_argument("--rules", type=int, default=1000)
    ap.add_argument("--mode", default="enrich", choices=("enrich", "filter"))
    ap.add_argument("--backend", default="dfa_ref",
                    choices=("dfa", "dfa_ref", "shift_or", "parallel"))
    ap.add_argument("--store", default=None, help="spill directory")
    ap.add_argument("--wal", action="store_true",
                    help="crash-safe ingest: journal every raw batch to "
                         "<store>/ingest-wal before dispatch and truncate "
                         "against the manifest watermark (needs --store; "
                         "enrich mode only)")
    ap.add_argument("--segment-size", type=int, default=50_000)
    ap.add_argument("--batch-size", type=int, default=4096)
    ap.add_argument("--fields", type=int, default=2)
    ap.add_argument("--maintenance", action="store_true",
                    help="run the segment maintenance plane after ingest: "
                         "hold back one rule, activate it late, backfill "
                         "sealed segments (plus a compaction pass)")
    ap.add_argument("--maintenance-workers", type=int, default=1,
                    metavar="N",
                    help="distributed maintenance: N leased backfill "
                         "workers sharding segments by id hash, each with "
                         "its own consumer-group offsets and per-shard "
                         "convergence ack")
    ap.add_argument("--worker-model",
                    default=os.environ.get("FLUXSIEVE_WORKER_MODEL",
                                           "thread"),
                    choices=("thread", "process"),
                    help="maintenance worker substrate: 'thread' shares one "
                         "interpreter; 'process' runs each worker as a "
                         "spawn process over the durable control plane "
                         "(file-backed bus + leases under <store>/, needs "
                         "--store) — escapes the GIL and survives SIGKILL")
    ap.add_argument("--retention", type=int, default=None, metavar="AGE",
                    help="event-time TTL (timestamp-column units): after "
                         "maintenance, retire segments older than AGE past "
                         "the newest sealed timestamp, purge straddling "
                         "rows via compaction, and GC drained spill dirs")
    ap.add_argument("--metrics-dump", default=None, metavar="DIR",
                    help="write metrics.prom / snapshot.json / trace.json "
                         "into DIR at the end of the run")
    ap.add_argument("--metrics-interval", type=float, default=None,
                    metavar="S",
                    help="with --metrics-dump: additionally rewrite "
                         "metrics.prom every S seconds while running")
    args = ap.parse_args(argv)

    stop_dumper = None
    if args.metrics_dump and args.metrics_interval:
        stop_dumper = threading.Event()

        def _periodic():
            while not stop_dumper.wait(args.metrics_interval):
                telemetry.write_dump(args.metrics_dump)

        threading.Thread(target=_periodic, daemon=True,
                         name="metrics-dumper").start()

    spec = WorkloadSpec(num_records=args.records,
                        num_content_fields=args.fields)
    gen = LogGenerator(spec)
    full_ruleset = synth_ruleset(spec, args.rules)
    late_rule = None
    if args.maintenance:
        # hold one planted rule back so historical segments need backfill
        late_rule = next(r for r in full_ruleset.rules
                         if r.rule_id == len(spec.planted) - 1)
        ruleset = full_ruleset.without_ids([late_rule.rule_id])
    else:
        ruleset = full_ruleset
    t0 = time.perf_counter()
    bundle = compile_bundle(ruleset, spec.content_fields)
    print(f"compiled {ruleset.num_rules} rules in "
          f"{time.perf_counter() - t0:.2f}s "
          f"({sum(e.num_states for e in bundle.engines.values())} DFA states)")

    if args.wal and args.store is None:
        ap.error("--wal needs --store (the journal lives next to the "
                 "spill dirs)")
    if args.worker_model == "process" and args.store is None:
        ap.error("--worker-model process needs --store (worker processes "
                 "coordinate through the durable bus/leases under it)")
    root = Path(args.store) if args.store is not None else None
    if args.worker_model == "process":
        # durable control plane: worker processes open the same files
        bus = DurableControlBus(root / CONTROL_DIRNAME)
        ostore = ObjectStore(root=root / "objects")
    else:
        bus, ostore = ControlBus(), ObjectStore()
    updater = MatcherUpdater(ostore, bus, spec.content_fields,
                             initial=ruleset)
    proc = StreamProcessor(bundle, mode=args.mode, backend=args.backend,
                           bus=bus, store=ostore)
    if root is not None and ((root / MANIFEST_NAME).exists()
                             or (root / INGEST_WAL_DIRNAME).exists()):
        # restart over a populated root: reopen the committed store (a
        # fresh SegmentStore here would disown every durable segment on
        # its first manifest commit)
        store = SegmentStore.load(root, segment_size=args.segment_size)
    else:
        store = SegmentStore(segment_size=args.segment_size, root=args.store)
    pipe = IngestPipeline(gen, store, proc, wal=args.wal)
    start = pipe.recover() if args.wal else 0
    times = pipe.run(batch_size=args.batch_size, start=start)
    print(f"ingested {times.records} records in "
          f"{times.generate_s + times.process_s + times.store_s:.2f}s "
          f"({times.throughput():,.0f} rec/s; "
          f"match+enrich {times.process_s:.2f}s; cpu {times.cpu_s:.2f}s)")
    print(f"segments: {len(store.segments)}, matched "
          f"{proc.stats.records_matched}/{proc.stats.records_in}")

    # query the enriched store through the mapper
    mapper = QueryMapper(ruleset)
    profiler = QueryProfiler()
    qe = QueryEngine(store, mapper=mapper, profiler=profiler)
    term = spec.planted[0]
    res = qe.execute(Query(terms=((term.fieldname, term.term),),
                           mode="count"))
    truth = gen.true_count(term)
    print(f"query[{term.term}] path={res.path} count={res.count} "
          f"(truth {truth}) in {res.latency_s * 1e3:.2f} ms")
    assert res.count == truth

    pool = None
    if args.maintenance:
        # late rule activation: historical segments fall back until the
        # maintenance plane re-enriches them
        planted = spec.planted[late_rule.rule_id]
        q = Query(terms=((planted.fieldname, planted.term),), mode="count")
        # the invariant is store-level: fluxsieve == full scan over what was
        # ingested.  (In filter mode records matching ONLY the late rule were
        # dropped before it existed — backfill cannot resurrect them, so the
        # generator's ground truth is not the reference.)
        late_truth = qe.execute(q, path="full_scan").count
        if args.mode == "enrich":
            assert late_truth == gen.true_count(planted)
        handle = updater.submit(full_ruleset, asynchronous=False)
        assert handle.published, handle.error
        proc.poll_updates()
        mapper.notify(full_ruleset, version_id=proc.active_version_id)
        r_pre = qe.execute(q, path="fluxsieve")
        print(f"maintenance: late rule {late_rule.name!r} pre-backfill "
              f"count={r_pre.count} (truth {late_truth}) "
              f"fallback_segments={r_pre.segments_fallback} "
              f"{r_pre.latency_s * 1e3:.2f} ms")
        scheduler = MaintenanceScheduler(
            profiler, MaintenancePolicy(max_records_per_cycle=args.segment_size))
        if args.worker_model == "process":
            pool = ProcessMaintenancePool(
                root, store=store, objects_root=root / "objects",
                num_workers=args.maintenance_workers,
                policy=scheduler.policy, backend=args.backend)
        else:
            pool = MaintenanceWorkerPool(store, bus, ostore,
                                         num_workers=args.maintenance_workers,
                                         scheduler=scheduler,
                                         backend=args.backend)
        rep = pool.run_until_converged()
        print(f"maintenance: backfilled {rep.segments_backfilled} segments "
              f"({rep.records} records, {rep.bytes_rewritten / 1e6:.1f} MB) "
              f"across {len(pool.worker_ids)} {args.worker_model} worker(s) "
              f"in {rep.seconds:.2f}s; acked={rep.acked}")
        status = updater.await_maintenance(rep.version, pool.worker_ids)
        r_post = qe.execute(q, path="fluxsieve")
        print(f"maintenance: post-backfill count={r_post.count} "
              f"fallback_segments={r_post.segments_fallback} "
              f"{r_post.latency_s * 1e3:.2f} ms "
              f"(rollout complete={status.complete})")
        assert r_post.count == r_pre.count == late_truth
        assert r_post.segments_fallback == 0
        crep = Compactor(store, leases=pool.leases).run_cycle()
        print(f"maintenance: compaction merged {crep.segments_in} -> "
              f"{crep.segments_out} segments "
              f"({len(store.segments)} total now)")
        r_c = qe.execute(q)
        assert r_c.count == late_truth
        if args.retention is not None:
            before = store.num_records
            ret = RetentionWorker(store,
                                  RetentionPolicy(max_age=args.retention),
                                  leases=pool.leases)
            rrep = ret.run_cycle()
            prep = Compactor(store, leases=pool.leases).run_cycle()
            grep_ = SpillGC(store, arrangements=qe.arrangements,
                            grace_s=0.0).run_cycle()
            print(f"retention: horizon={rrep.horizon} expired "
                  f"{rrep.segments_expired} segments "
                  f"({rrep.records_expired} records), purged "
                  f"{prep.rows_purged} straddler rows, GC deleted "
                  f"{grep_.dirs_deleted} spill dirs "
                  f"({store.num_records}/{before} records retained)")
    if stop_dumper is not None:
        stop_dumper.set()
    if args.metrics_dump:
        if args.worker_model == "process" and pool is not None:
            # each worker process dumps under its own prefix, the parent
            # under "parent.", then everything folds into merged.* —
            # one snapshot covering every process
            pool.write_dumps(args.metrics_dump)
            telemetry.write_dump(args.metrics_dump, prefix="parent.")
            paths = telemetry.merge_dumps(args.metrics_dump)
        else:
            paths = telemetry.write_dump(args.metrics_dump)
        print(f"telemetry: wrote {', '.join(sorted(paths.values()))}")
    if pool is not None and args.worker_model == "process":
        pool.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
