"""Roofline terms from a compiled dry-run artifact (task spec §Roofline).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  XLA's cost analysis and the partitioned HLO module are
per-device, so global quantities are per-device x chips; the spec's ratios

    compute    = HLO_FLOPs        / (chips x peak)
    memory     = HLO_bytes        / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

reduce to per-device quantities over per-chip rates.  ``model_flops`` is
6·N·D (train) / 2·N·D (forward-only), N = active params, D = tokens.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device (partitioned-module) measurements
    device_flops: float
    device_bytes: float
    device_collective_bytes: float
    collective_detail: dict = field(default_factory=dict)
    # memory fit
    memory_per_device: dict = field(default_factory=dict)
    # usefulness
    model_flops_global: float = 0.0

    # -- spec terms ------------------------------------------------------
    @property
    def compute_s(self) -> float:
        return self.device_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.device_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.device_collective_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: the dominant term (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_global — remat/redundancy waste."""
        total = self.device_flops * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOPs at the dominant-term step time."""
        if self.step_s == 0:
            return 0.0
        achieved = self.model_flops_global / self.step_s
        return achieved / (self.chips * PEAK_FLOPS)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 step_s=self.step_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d

    def row(self) -> str:
        return (f"{self.arch:24s} {self.shape:12s} {self.mesh:10s} "
                f"compute {self.compute_s:9.4f}s  memory {self.memory_s:9.4f}s  "
                f"collective {self.collective_s:9.4f}s  -> {self.dominant:10s} "
                f"useful {self.useful_flops_ratio:6.1%}  "
                f"roofline {self.roofline_fraction:6.1%}")


def model_flops(cfg, shape_spec) -> float:
    """6·N_active·D for train, 2·N_active·D forward-only."""
    n = cfg.active_param_count()
    if shape_spec.kind == "train":
        d = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n * d
    if shape_spec.kind == "prefill":
        d = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n * d
    # decode: one new token per sequence
    return 2.0 * n * shape_spec.global_batch


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  compiled, collective_stats, model_flops_global: float
                  ) -> RooflineTerms:
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    mem_d = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_d[k] = int(v)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        device_flops=float(cost.get("flops", 0.0)),
        device_bytes=float(cost.get("bytes accessed", 0.0)),
        device_collective_bytes=float(collective_stats.total_bytes),
        collective_detail=collective_stats.summary(),
        memory_per_device=mem_d,
        model_flops_global=model_flops_global)


def save_json(path, terms_list) -> None:
    with open(path, "w") as f:
        json.dump([t.to_dict() for t in terms_list], f, indent=1)
