"""Checkpointing: atomic, checksummed, async-capable, elastic-restore.

Layout (one directory per step)::

    <root>/step-000123/
        manifest.json     {step, leaves: {path: {shape,dtype,sha256,file}},
                           meta: {...}}
        <leaf files>.npy  one per pytree leaf

Guarantees:
  * **atomic publish** — written into ``step-N.tmp`` then ``os.replace``d,
    so a crash mid-save never corrupts the latest valid checkpoint;
  * **integrity** — per-leaf sha256 in the manifest, verified on restore;
    a corrupt/partial checkpoint is skipped by ``latest_step``;
  * **elastic restore** — leaves are saved unsharded; ``restore_checkpoint``
    re-shards onto any target mesh via ``jax.device_put`` (checkpoint taken
    on N hosts restores on M — resharding is just a different device_put);
  * **async save** — ``AsyncCheckpointer`` snapshots to host memory
    synchronously (cheap) and writes in a background thread, overlapping
    I/O with the next training steps.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_STEP_RE = re.compile(r"^step-(\d+)$")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


def _leaf_file(i: int) -> str:
    return f"leaf-{i:05d}.npy"


def save_checkpoint(root, step: int, tree, meta: dict = None) -> Path:
    """Blocking save.  Returns the published directory."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step-{step:08d}"
    tmp = root / f"step-{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten(tree)
    leaves = {}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fn = _leaf_file(i)
        np.save(tmp / fn, arr)
        leaves[path] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                        "sha256": hashlib.sha256(
                            np.ascontiguousarray(arr).tobytes()).hexdigest(),
                        "file": fn}
    manifest = {"step": step, "leaves": leaves, "meta": meta or {}}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    return final


def is_valid(ckpt_dir) -> bool:
    """Structural + integrity validation (used to skip corrupt checkpoints)."""
    d = Path(ckpt_dir)
    mf = d / "manifest.json"
    if not mf.exists():
        return False
    try:
        manifest = json.loads(mf.read_text())
        for info in manifest["leaves"].values():
            f = d / info["file"]
            if not f.exists():
                return False
            arr = np.load(f)
            if hashlib.sha256(np.ascontiguousarray(arr).tobytes()
                              ).hexdigest() != info["sha256"]:
                return False
    except Exception:  # noqa: BLE001 — any parse/shape error means corrupt
        return False
    return True


def list_steps(root) -> list:
    root = Path(root)
    if not root.is_dir():
        return []
    out = []
    for d in root.iterdir():
        m = _STEP_RE.match(d.name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root, *, validate: bool = True):
    """Newest step whose checkpoint passes validation (or None)."""
    root = Path(root)
    for step in reversed(list_steps(root)):
        if not validate or is_valid(root / f"step-{step:08d}"):
            return step
    return None


def restore_checkpoint(root, step: int, like, shardings=None, *,
                       verify: bool = True):
    """Restore the pytree saved at `step` into the structure of `like`.

    `like` provides the treedef (values ignored; may be ShapeDtypeStructs).
    `shardings` (optional pytree of NamedSharding) re-shards every leaf for
    the *current* mesh — elastic restore across different topologies."""
    d = Path(root) / f"step-{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat, treedef = _flatten(like)
    leaves = []
    for path, _ in flat:
        info = manifest["leaves"].get(path)
        if info is None:
            raise KeyError(f"checkpoint {d} missing leaf {path}")
        arr = np.load(d / info["file"])
        if verify:
            sha = hashlib.sha256(np.ascontiguousarray(arr).tobytes()
                                 ).hexdigest()
            if sha != info["sha256"]:
                raise ValueError(f"checkpoint leaf {path} corrupt")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, manifest["meta"]


def prune_checkpoints(root, keep: int = 3) -> int:
    steps = list_steps(root)
    drop = steps[:-keep] if keep else steps
    for s in drop:
        shutil.rmtree(Path(root) / f"step-{s:08d}", ignore_errors=True)
    return len(drop)


class AsyncCheckpointer:
    """Snapshot synchronously (device->host copy), write in the background."""

    def __init__(self, root, *, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self._thread = None
        self._error = None

    def save(self, step: int, tree, meta: dict = None) -> None:
        self.wait()                                # one in flight at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.root, step, host_tree, meta)
                prune_checkpoints(self.root, self.keep)
            except Exception as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
