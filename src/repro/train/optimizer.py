"""AdamW with decoupled weight decay + warmup-cosine schedule.

Functional, pytree-based, framework-free.  Optimizer moments inherit the
parameter sharding (same logical axes), so FSDP shards optimizer state
exactly like ZeRO: ``opt_logical_tree`` mirrors the param logical tree.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptimizerConfig, step):
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params):
    """-> {mu, nu, count}.  Moments are fp32 zeros shaped like params."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_logical_tree(param_logical):
    """Optimizer-state logical tree mirroring the params (FSDP-style)."""
    return {
        "mu": param_logical,
        "nu": param_logical,
        "count": (),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    """-> (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = lr_at(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
