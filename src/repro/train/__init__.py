from repro.train.optimizer import OptimizerConfig, adamw_init, adamw_update, lr_at  # noqa: F401
from repro.train.train_step import TrainStepConfig, build_train_step  # noqa: F401
from repro.train.checkpoint import (save_checkpoint, restore_checkpoint,  # noqa: F401
                                    latest_step, AsyncCheckpointer)
