"""Fault tolerance for 1000+-node posture: restart-from-checkpoint, elastic
mesh selection, and straggler detection.

``RestartManager`` wraps the training loop: on any step failure it restores
the newest *valid* checkpoint (corrupt/partial ones are skipped by the
integrity check) and replays.  ``ElasticMesh`` picks the best mesh for the
devices that are actually healthy — a checkpoint taken on the full mesh
restores onto the survivor mesh because leaves are saved unsharded
(checkpoint.py).  ``StragglerMonitor`` keeps per-host EWMA step times and
flags hosts slower than k x median — the hook a scheduler uses to evict and
re-spawn (mitigation at the framework layer is restart-on-smaller-mesh).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import jax

from repro.train import checkpoint as ckpt


# ---------------------------------------------------------------------------
# Elastic mesh selection
# ---------------------------------------------------------------------------

def largest_mesh_shape(n_devices: int, *, model_parallel: int,
                       pods: int = 1) -> tuple:
    """Largest (pod, data, model) grid that fits `n_devices` devices while
    preserving the model-parallel degree (params must still fit)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel={model_parallel}")
    per_pod = n_devices // pods
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError("not enough devices per pod for the model axis")
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)


def make_elastic_mesh(devices, *, model_parallel: int, pods: int = 1):
    """Build the largest valid mesh from the (possibly reduced) device set."""
    shape = largest_mesh_shape(len(devices), model_parallel=model_parallel,
                               pods=pods)
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    n = math.prod(shape)
    import numpy as np
    dev_grid = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_grid, axes)


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------

@dataclass
class HostStats:
    ewma_s: float = 0.0
    samples: int = 0


class StragglerMonitor:
    """Per-host EWMA of step wall time; flags hosts > k x median EWMA."""

    def __init__(self, *, alpha: float = 0.3, threshold: float = 1.5,
                 min_samples: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples
        self.hosts: dict = {}

    def report(self, host: str, step_seconds: float) -> None:
        st = self.hosts.setdefault(host, HostStats())
        if st.samples == 0:
            st.ewma_s = step_seconds
        else:
            st.ewma_s = (1 - self.alpha) * st.ewma_s + self.alpha * step_seconds
        st.samples += 1

    def stragglers(self) -> list:
        ready = {h: s for h, s in self.hosts.items()
                 if s.samples >= self.min_samples}
        if len(ready) < 2:
            return []
        ewmas = sorted(s.ewma_s for s in ready.values())
        median = ewmas[len(ewmas) // 2]
        return sorted(h for h, s in ready.items()
                      if s.ewma_s > self.threshold * median)


# ---------------------------------------------------------------------------
# Restart orchestration
# ---------------------------------------------------------------------------

@dataclass
class RunReport:
    final_step: int
    restarts: int
    failures: list = field(default_factory=list)


class RestartManager:
    """Run a step function with checkpoint/restart semantics.

    ``step_fn(state, step) -> state`` may raise; the manager restores the
    newest valid checkpoint and resumes.  ``save_every`` controls the
    checkpoint cadence; ``max_restarts`` bounds the retry budget."""

    def __init__(self, ckpt_root, *, save_every: int = 10,
                 max_restarts: int = 3, keep: int = 3):
        self.root = ckpt_root
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.saver = ckpt.AsyncCheckpointer(ckpt_root, keep=keep)

    def run(self, init_state, step_fn, num_steps: int, *,
            state_like=None, shardings=None, meta: dict = None) -> tuple:
        """-> (final state, RunReport)."""
        report = RunReport(final_step=0, restarts=0)
        state = init_state
        like = state_like if state_like is not None else init_state
        start = 0
        restored = ckpt.latest_step(self.root)
        if restored is not None:
            state, m = ckpt.restore_checkpoint(self.root, restored, like,
                                               shardings)
            start = restored
        step = start
        while step < num_steps:
            try:
                state = step_fn(state, step)
                step += 1
                if step % self.save_every == 0 or step == num_steps:
                    self.saver.save(step, state, {**(meta or {}),
                                                  "step": step})
            except Exception as e:  # noqa: BLE001 — any step failure
                report.failures.append((step, f"{type(e).__name__}: {e}"))
                if report.restarts >= self.max_restarts:
                    raise
                report.restarts += 1
                self.saver.wait()
                restored = ckpt.latest_step(self.root)
                if restored is None:
                    state, step = init_state, 0
                else:
                    state, _ = ckpt.restore_checkpoint(self.root, restored,
                                                       like, shardings)
                    step = restored
        self.saver.wait()
        report.final_step = step
        return state, report
