"""Train-step construction: value_and_grad + microbatch accumulation +
remat + optional compressed cross-pod gradient reduction, jitted with
in/out shardings derived from the logical axis trees.

Distribution posture (DESIGN.md §5):
  * batch sharded over ``(pod, data)``;
  * params FSDP-sharded over ``data`` (gathered per-layer inside the scan);
  * TP/EP over ``model`` via logical rules + shard_map MoE;
  * gradient reduction over ``pod`` is GSPMD's hierarchical all-reduce, or —
    with ``grad_compression='int8'`` — an explicit error-feedback int8
    psum inside a shard_map manual over the pod axis only (params are
    pod-replicated, so their pod-manual view is P(); the batch splits its
    leading dim over 'pod'; 'data'/'model' stay under GSPMD inside).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding
from repro.models import transformer as T
from repro.models.model import Model
from repro.train import compression
from repro.train.optimizer import OptimizerConfig, adamw_init, adamw_update

_BATCH_LOGICAL = ("batch", "seq")


@dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    remat: bool = True
    unroll: bool = False                # cost-accounting lowering (dry-run)
    grad_compression: str = "none"      # none | int8
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)


def batch_sharding(mesh, rules=sharding.DEFAULT_RULES):
    return NamedSharding(mesh,
                         sharding.logical_to_spec(_BATCH_LOGICAL, mesh, rules))


def state_shardings(model: Model, ts_cfg: TrainStepConfig, mesh,
                    rules=sharding.DEFAULT_RULES):
    p_sh = model.param_shardings(mesh, rules)
    rep = NamedSharding(mesh, P())
    out = {"params": p_sh,
           "opt": {"mu": p_sh, "nu": p_sh, "count": rep},
           "step": rep}
    if ts_cfg.grad_compression == "int8":
        out["grad_err"] = p_sh
    return out


def init_state(model: Model, key, ts_cfg: TrainStepConfig, mesh=None,
               rules=sharding.DEFAULT_RULES):
    """Materialize params + optimizer state (host init; production restores
    from a checkpoint — see train/checkpoint.py)."""
    params = model.init(key)
    opt = adamw_init(params)
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    if ts_cfg.grad_compression == "int8":
        state["grad_err"] = compression.zeros_like_err(params)
    if mesh is not None:
        state = jax.device_put(state, state_shardings(model, ts_cfg, mesh,
                                                      rules))
    return state


def _split_microbatches(batch, n):
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                        batch)


def build_train_step(model: Model, ts_cfg: TrainStepConfig, mesh=None,
                     rules=sharding.DEFAULT_RULES, donate: bool = True):
    """-> jitted train_step(state, batch) -> (new_state, metrics)."""

    def grads_of(params, batch, ctx):
        def loss_fn(p, mb):
            return model.loss(p, mb, ctx)

        if ts_cfg.microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        mbs = _split_microbatches(batch, ts_cfg.microbatches)

        def acc_body(carry, mb):
            g_acc, l_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        n = ts_cfg.microbatches
        if ts_cfg.unroll:
            # cost-accounting lowering: python-unroll the accumulation so
            # XLA's cost analysis sees every microbatch (see launch/dryrun)
            carry = (g0, 0.0)
            for i in range(n):
                mb = jax.tree.map(lambda x: x[i], mbs)
                carry, metrics = acc_body(carry, mb)
            g_sum, l_sum = carry
        else:
            (g_sum, l_sum), ms = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            metrics = jax.tree.map(lambda m: m[-1], ms)
        grads = jax.tree.map(lambda g: g / n, g_sum)
        return l_sum / n, metrics, grads

    def apply_updates(state, loss, metrics, grads, new_err=None):
        new_params, new_opt, opt_m = adamw_update(
            ts_cfg.optimizer, grads, state["opt"], state["params"])
        out = {"params": new_params, "opt": new_opt,
               "step": state["step"] + 1}
        if new_err is not None:
            out["grad_err"] = new_err
        return out, {**metrics, **opt_m, "loss": loss}

    compressed = (ts_cfg.grad_compression == "int8" and mesh is not None
                  and "pod" in mesh.axis_names)

    if not compressed:
        ctx = T.Context(mesh=mesh, rules=rules, remat=ts_cfg.remat,
                        unroll=ts_cfg.unroll)

        def train_step(state, batch):
            loss, metrics, grads = grads_of(state["params"], batch, ctx)
            return apply_updates(state, loss, metrics, grads)
    else:
        # inside the pod-manual region, 'pod' must not appear in constraints
        inner_rules = rules.replace(batch=("data",))
        ctx = T.Context(mesh=mesh, rules=inner_rules, remat=ts_cfg.remat,
                        unroll=ts_cfg.unroll)
        METRIC_KEYS = ("ce_loss", "lb_loss", "drop_frac")

        def train_step(state, batch):
            params, err = state["params"], state["grad_err"]
            p_zero = jax.tree.map(lambda _: P(), params)
            b_pod = jax.tree.map(lambda _: P("pod"), batch)
            m_zero = {k: P() for k in METRIC_KEYS}

            def pod_local(p, e, b):
                loss, metrics, grads = grads_of(p, b, ctx)
                grads, new_err = compression.compressed_psum(grads, "pod", e)
                loss = jax.lax.pmean(loss, "pod")
                metrics = {k: jax.lax.pmean(metrics[k], "pod")
                           for k in METRIC_KEYS}
                return loss, metrics, grads, new_err

            loss, metrics, grads, new_err = sharding.shard_map(
                pod_local, mesh=mesh, axis_names={"pod"},
                in_specs=(p_zero, p_zero, b_pod),
                out_specs=(P(), m_zero, p_zero, p_zero),
                check_vma=False)(params, err, batch)
            return apply_updates(state, loss, metrics, grads, new_err)

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0,) if donate else ())
    shardings = state_shardings(model, ts_cfg, mesh, rules)
    return jax.jit(train_step,
                   in_shardings=(shardings, None),
                   out_shardings=(shardings, None),
                   donate_argnums=(0,) if donate else ())
