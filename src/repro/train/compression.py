"""Error-feedback int8 gradient compression for the cross-pod reduction.

Cross-pod links (DCN) are the scarcest bandwidth in a multi-pod mesh; the
hierarchical reduction (ICI within pod, DCN across) moves
``bytes(grads) / pod`` per step across DCN.  Quantizing the cross-pod leg to
int8 with error feedback (residual carried into the next step) cuts that
term 4x vs fp32 / 2x vs bf16 with negligible quality loss at LM scale.

Implementation: the per-pod partial gradients are produced inside a
``shard_map`` that is *manual over the pod axis only* (data/model stay under
GSPMD), quantized per-leaf with a shared absmax scale, summed with
``psum('pod')`` as int32, and dequantized.  The quantization residual is
returned so the caller can stash it in the optimizer state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def quantize(x, err):
    """fp -> (int8 values, fp32 scale).  err is the carried residual."""
    xf = x.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(xf)) / INT8_MAX
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_name: str, err_tree):
    """Per-leaf int8 all-reduce over `axis_name` with error feedback.

    Call inside a shard_map manual over `axis_name`.  Returns
    (mean-reduced fp32 tree, new error tree)."""
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis_name)
    else:                       # jax 0.4.x: psum of 1 counts participants
        n = jax.lax.psum(1, axis_name)

    def leaf(g, err):
        gf = g.astype(jnp.float32) + err
        # share one absmax scale across participants (a scalar pmax is
        # negligible traffic) so the integer sum is exact in the shared grid
        scale = jnp.maximum(jnp.max(jnp.abs(gf)) / INT8_MAX, 1e-12)
        smax = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(gf / smax), -INT8_MAX, INT8_MAX).astype(jnp.int8)
        new_err = gf - q.astype(jnp.float32) * smax
        # 2 bytes on the wire: |q| <= 127 integers are exact in bf16 up to
        # sums of 256, i.e. 2-pod to 2-ish-hundred-pod reductions — half
        # the fp32 all-reduce this replaces.  (int16 would be equivalent
        # but trips an XLA SPMD partitioner check under partial-manual
        # shard_map on the CPU backend.)
        total = jax.lax.psum(q.astype(jnp.bfloat16), axis_name)
        return (total.astype(jnp.float32) * smax / n).astype(g.dtype), new_err

    flat, treedef = jax.tree.flatten(tree)
    flat_err = treedef.flatten_up_to(err_tree)
    out = [leaf(g, e) for g, e in zip(flat, flat_err)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def zeros_like_err(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
