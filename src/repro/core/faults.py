"""Deterministic fault-injection plane — named sites, seedable specs,
zero cost when disarmed.

FluxSieve moves filtering into the ingestion path, which turns ingest
failures into *data-loss* failures; the only way to trust the recovery
machinery (WAL replay, circuit breaking, partial queries) is to exercise it
deterministically.  This module is the process-wide registry the planes
consult at named **injection sites**:

    ``match.dispatch``          fused device dispatch (StreamProcessor)
    ``match.fallback``          degraded oracle-lane dispatch
    ``match.d2h``               result D2H transfer (finalize)
    ``ingest.wal_append``       write-ahead journal write
    ``ingest.append``           store append of an enriched batch
    ``store.spill``             sealed-segment spill I/O
    ``store.manifest_commit``   root-manifest commit
    ``bus.deliver``             control-bus delivery (drop/dup/reorder)
    ``bus.commit``              consumer-group offset commit (durable bus)
    ``maintenance.checkpoint``  backfill checkpoint write
    ``query.shard``             sharded query-executor shard entry
    ``standing.fold``           standing-query delta fold (epoch feed)
    ``serve.accept``            serving front-end connection accept
    ``serve.handle``            serving front-end request handler

Design mirrors ``telemetry.set_enabled``'s zero-cost-when-off discipline:
``fire``/``act`` early-return on a module-level flag, so a disarmed
production path pays one attribute read per site.  Specs are deterministic
(``every``/``times``/``after`` counters, or ``prob`` driven by a seeded
PRNG over the per-spec call sequence) so chaos tests replay exactly.

Two exception classes:

  * :class:`InjectedFault` (``RuntimeError``) — a *recoverable* simulated
    error; retry/breaker/fallback machinery is expected to absorb it;
  * :class:`InjectedCrash` (``BaseException``) — a simulated **process
    kill**.  It deliberately does NOT derive from ``Exception`` so no
    broad ``except Exception`` recovery handler can swallow it: the test
    harness catches it at top level, abandons the process state, and
    "restarts" by reloading from disk.

Profiles load from the ``FLUXSIEVE_FAULTS`` environment variable at import
(grammar: ``site:kind@key=val,key=val;site2:kind``), so CI can run the
whole tier-1 suite under periodic injected faults without code changes.

Every injected action bumps ``fluxsieve_faults_injected_total{site}`` and
emits a ``fault_injected`` event, so a chaos run's telemetry dump is the
record of what was actually injected.
"""
from __future__ import annotations

import os
import random
import threading
import time
from dataclasses import dataclass, field

from repro.core import telemetry

ENV_VAR = "FLUXSIEVE_FAULTS"

SITES = (
    "match.dispatch",
    "match.fallback",
    "match.d2h",
    "ingest.wal_append",
    "ingest.append",
    "store.spill",
    "store.manifest_commit",
    "bus.deliver",
    "bus.commit",
    "maintenance.checkpoint",
    "query.shard",
    "standing.fold",
    "serve.accept",
    "serve.handle",
)

# error/crash/stall raise or sleep at the site; drop/dup/reorder are
# *actions* interpreted by the control bus (``act``)
KINDS = ("error", "crash", "stall", "drop", "dup", "reorder")
_SPEC_KEYS = ("every", "times", "after", "prob", "seed", "delay")


class InjectedFault(RuntimeError):
    """A recoverable simulated failure (retry/fallback paths absorb it)."""


class InjectedCrash(BaseException):
    """A simulated hard process kill.  Derives from ``BaseException`` so
    broad ``except Exception`` recovery handlers cannot swallow it — only
    the chaos harness's top-level catch may."""


@dataclass
class FaultSpec:
    """One armed fault.  Fires on calls to ``site`` whose context matches
    ``where`` (string-compared), subject to:

      ``after``  skip the first N matching calls;
      ``every``  then fire every Nth matching call;
      ``prob``   else fire with probability p (seeded, deterministic in
                 call order);
      (neither)  fire on every matching call;
      ``times``  stop after N total fires (spec goes inert).
    """
    site: str
    kind: str = "error"
    every: int = None
    times: int = None
    after: int = 0
    prob: float = None
    seed: int = 0
    delay: float = 0.05         # stall kinds: seconds slept per fire
    where: dict = field(default_factory=dict)
    calls: int = 0              # matching calls seen
    fired: int = 0              # injections performed
    _rng: random.Random = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        self._rng = random.Random(self.seed)

    def matches(self, ctx: dict) -> bool:
        return all(str(ctx.get(k)) == str(v) for k, v in self.where.items())

    def should_fire(self) -> bool:
        """Advance the per-spec call counter; decide.  Caller holds the
        registry lock, so the counter sequence (and thus the PRNG draw
        order) is deterministic under a fixed call order."""
        self.calls += 1
        if self.times is not None and self.fired >= self.times:
            return False
        if self.calls <= self.after:
            return False
        if self.every is not None:
            fire = (self.calls - self.after) % self.every == 0
        elif self.prob is not None:
            fire = self._rng.random() < self.prob
        else:
            fire = True
        if fire:
            self.fired += 1
        return fire


_ARMED = False                  # zero-cost-when-off: the ONLY hot-path read
_LOCK = threading.Lock()
_SPECS: list = []

_INJECTED = {}                  # site -> counter handle (lazy per site)


def _injected_counter(site: str):
    c = _INJECTED.get(site)
    if c is None:
        c = telemetry.counter("fluxsieve_faults_injected_total",
                              labels={"site": site},
                              help="Faults injected, by site.")
        _INJECTED[site] = c
    return c


def armed() -> bool:
    return _ARMED


def inject(site: str, kind: str = "error", **kw) -> FaultSpec:
    """Arm one fault spec.  Keyword args split into spec parameters
    (``every``/``times``/``after``/``prob``/``seed``/``delay``) and
    context filters (everything else, e.g. ``topic="segment-maintenance"``
    — matched against the ``fire``/``act`` call's context)."""
    if site not in SITES:
        raise ValueError(f"unknown fault site {site!r} (sites: {SITES})")
    params = {k: kw.pop(k) for k in _SPEC_KEYS if k in kw}
    spec = FaultSpec(site=site, kind=kind, where=kw, **params)
    global _ARMED
    with _LOCK:
        _SPECS.append(spec)
        _ARMED = True
    return spec


def reset() -> None:
    """Disarm everything (tests call this in teardown)."""
    global _ARMED
    with _LOCK:
        _SPECS.clear()
        _ARMED = False


def specs() -> list:
    with _LOCK:
        return list(_SPECS)


def _select(site: str, ctx: dict) -> FaultSpec:
    with _LOCK:
        for spec in _SPECS:
            if spec.site == site and spec.matches(ctx) and spec.should_fire():
                return spec
    return None


def _record(spec: FaultSpec, ctx: dict) -> None:
    _injected_counter(spec.site).inc()
    telemetry.emit("fault_injected", plane=spec.site.split(".", 1)[0],
                   site=spec.site, fault=spec.kind, call=spec.calls, **{
                       k: v for k, v in ctx.items()
                       if isinstance(v, (str, int, float, bool))})


def fire(site: str, **ctx) -> None:
    """Hot-path injection point for error/crash/stall kinds.  Free when
    disarmed.  Raises :class:`InjectedFault`/:class:`InjectedCrash` or
    sleeps ``delay`` seconds (stall); drop/dup/reorder specs never fire
    here (they are bus actions — see ``act``)."""
    if not _ARMED:
        return
    spec = _select(site, ctx)
    if spec is None or spec.kind in ("drop", "dup", "reorder"):
        return
    _record(spec, ctx)
    if spec.kind == "stall":
        time.sleep(spec.delay)
        return
    detail = f"injected {spec.kind} at {site} (call {spec.calls})"
    if spec.kind == "crash":
        raise InjectedCrash(detail)
    raise InjectedFault(detail)


def act(site: str, **ctx) -> str:
    """Bus-delivery injection point: returns ``"drop"``/``"dup"``/
    ``"reorder"`` when an armed spec of that kind fires, else None.
    error/crash/stall specs at the same site behave as in ``fire``."""
    if not _ARMED:
        return None
    spec = _select(site, ctx)
    if spec is None:
        return None
    _record(spec, ctx)
    if spec.kind == "stall":
        time.sleep(spec.delay)
        return None
    if spec.kind == "crash":
        raise InjectedCrash(f"injected crash at {site} (call {spec.calls})")
    if spec.kind == "error":
        raise InjectedFault(f"injected error at {site} (call {spec.calls})")
    return spec.kind


# -- env profile ---------------------------------------------------------------
def load_profile(profile: str) -> list:
    """Parse and arm a ``FLUXSIEVE_FAULTS`` profile string.

    Grammar: ``site:kind[@key=val[,key=val...]][;...]`` — e.g.::

        match.dispatch:error@every=97;bus.deliver:dup@times=1,topic=segment-maintenance

    Numeric values parse as int/float; everything unrecognized as a spec
    parameter becomes a context filter."""
    armed_specs = []
    for part in profile.split(";"):
        part = part.strip()
        if not part:
            continue
        head, _, tail = part.partition("@")
        site, _, kind = head.partition(":")
        kw = {}
        for pair in filter(None, tail.split(",")):
            k, _, v = pair.partition("=")
            kw[k.strip()] = _coerce(v.strip())
        armed_specs.append(inject(site.strip(), (kind or "error").strip(),
                                  **kw))
    return armed_specs


def _coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    return v


if os.environ.get(ENV_VAR):
    load_profile(os.environ[ENV_VAR])


# -- circuit breaker -----------------------------------------------------------
class CircuitBreaker:
    """CLOSED -> OPEN -> HALF_OPEN breaker with batch-count-based probing
    (deterministic under test — no wall-clock cooldowns).

    CLOSED: primary lane allowed; ``failure_threshold`` *consecutive*
    batch failures (each already past its bounded retries) trip to OPEN.
    OPEN: every batch takes the fallback lane; every ``probe_interval``-th
    batch becomes a HALF_OPEN probe through the primary.  A probe success
    closes the breaker; a probe failure re-opens it.

    State is surfaced on ``fluxsieve_breaker_state{site}`` (0 closed,
    1 open, 2 half-open) plus ``breaker_trip``/``breaker_probe``/
    ``breaker_close`` events."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"
    _STATE_CODE = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}

    def __init__(self, *, site: str = "match.dispatch",
                 failure_threshold: int = 3, probe_interval: int = 8):
        self.site = site
        self.failure_threshold = int(failure_threshold)
        self.probe_interval = max(1, int(probe_interval))
        self.state = self.CLOSED
        self.trips = 0
        self._consecutive_failures = 0
        self._open_calls = 0
        self._lock = threading.Lock()
        self._gauge = telemetry.gauge(
            "fluxsieve_breaker_state", labels={"site": site},
            help="Circuit-breaker state (0 closed, 1 open, 2 half-open).")
        self._gauge.set(0)

    def _set_state(self, state: str) -> None:
        self.state = state
        self._gauge.set(self._STATE_CODE[state])

    def allow_primary(self) -> bool:
        """Per batch: may this batch try the primary lane?  In OPEN state
        every ``probe_interval``-th call transitions to HALF_OPEN and is
        let through as the probe."""
        with self._lock:
            if self.state == self.CLOSED:
                return True
            if self.state == self.HALF_OPEN:
                return False        # one probe in flight; rest use fallback
            self._open_calls += 1
            if self._open_calls % self.probe_interval == 0:
                self._set_state(self.HALF_OPEN)
                telemetry.emit("breaker_probe", plane="match",
                               site=self.site, after_calls=self._open_calls)
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._consecutive_failures = 0
            if self.state != self.CLOSED:
                self._set_state(self.CLOSED)
                self._open_calls = 0
                telemetry.emit("breaker_close", plane="match",
                               site=self.site)

    def record_failure(self, error: str = "") -> None:
        with self._lock:
            if self.state == self.HALF_OPEN:    # probe failed: back to OPEN
                self._set_state(self.OPEN)
                return
            self._consecutive_failures += 1
            if (self.state == self.CLOSED
                    and self._consecutive_failures >= self.failure_threshold):
                self._set_state(self.OPEN)
                self._open_calls = 0
                self.trips += 1
                telemetry.emit("breaker_trip", plane="match", site=self.site,
                               consecutive_failures=self._consecutive_failures,
                               error=error)
