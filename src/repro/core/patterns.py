"""Filtering rules and rule sets (paper §3.3-3.4).

A ``Rule`` is one filtering condition the analytical plane wants evaluated
in-stream.  Rules support literals, alternations (``a|b|c``), and a small
character-class subset (``[0-9]``, ``[a-z]``, ``.``) — the same "compilable
subset" philosophy Hyperscan applies; arbitrary PCRE is out of scope.

A ``RuleSet`` is a versioned, hashable collection; ``diff`` computes the
delta (paper §3.4 step 1) that drives engine recompilation.
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass, field, asdict
from typing import Iterable

_CLASS_RE = re.compile(r"\[([^\]]+)\]|\.")

_META = "|[].\\"


def escape(literal: str) -> str:
    """Escape a raw string so it matches literally (cf. re.escape)."""
    return "".join("\\" + c if c in _META else c for c in literal)


def rule_ident(rule: "Rule") -> str:
    """Content identity of a rule, independent of its id.

    Two rules with the same ident produce identical enrichment bits, so a
    segment whose bitmap was computed under one is valid under the other.
    Used by the per-segment ``rules_known`` coverage check and the
    maintenance plane's backfill delta: a *changed* rule (same id, new
    pattern) gets a new ident and is re-matched, not trusted.
    """
    payload = f"{rule.pattern}\x00{','.join(rule.fields)}\x00{rule.case_insensitive}"
    return hashlib.sha256(payload.encode()).hexdigest()[:12]


def ruleset_idents(ruleset: "RuleSet") -> dict:
    """str(rule_id) -> ident for every rule (string keys: JSON-stable)."""
    return {str(r.rule_id): rule_ident(r) for r in ruleset.rules}


def _unescape(s: str) -> str:
    out, i = [], 0
    while i < len(s):
        if s[i] == "\\" and i + 1 < len(s):
            out.append(s[i + 1])
            i += 2
        else:
            out.append(s[i])
            i += 1
    return "".join(out)


def _split_unescaped(s: str, sep: str) -> list:
    parts, cur, i = [], [], 0
    while i < len(s):
        if s[i] == "\\" and i + 1 < len(s):
            cur.append(s[i:i + 2])
            i += 2
            continue
        if s[i] == sep:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(s[i])
        i += 1
    parts.append("".join(cur))
    return parts


@dataclass(frozen=True)
class Rule:
    rule_id: int
    name: str
    pattern: str
    fields: tuple = ("*",)          # record fields to evaluate ("*" = all text)
    case_insensitive: bool = False

    def __post_init__(self):
        if not self.pattern:
            raise ValueError("empty pattern")
        if self.rule_id < 0:
            raise ValueError("rule_id must be >= 0")
        for lit in self.literals():
            if not lit:
                raise ValueError(f"rule {self.name}: empty alternation branch")
            if len(lit) > 256:
                raise ValueError(f"rule {self.name}: literal longer than 256 bytes")

    def literals(self) -> tuple:
        """Expand the pattern into the set of literal strings it matches.

        Alternation expands combinatorially; character classes expand to
        their members (bounded to keep compile cost sane — like Hyperscan's
        literal factoring, wide classes belong in the DFA, and we cap them).
        """
        out = []
        for branch in _split_unescaped(self.pattern, "|"):
            out.extend(_expand_classes(branch))
        if len(out) > 4096:
            raise ValueError(f"rule {self.name}: expands to >4096 literals")
        if self.case_insensitive:
            out = [x.lower() for x in out]
        return tuple(out)

    def matches(self, text: str) -> bool:
        """Pure-python oracle used by tests."""
        hay = text.lower() if self.case_insensitive else text
        return any(lit in hay for lit in self.literals())


def _expand_classes(branch: str) -> list:
    # find the first UNESCAPED class/dot; escaped metacharacters are literal
    i = 0
    m = None
    while i < len(branch):
        if branch[i] == "\\":
            i += 2
            continue
        m = _CLASS_RE.match(branch, i)
        if m:
            break
        i += 1
    if not m:
        return [_unescape(branch)]
    pre, post = branch[:m.start()], branch[m.end():]
    if m.group(0) == ".":
        members = [chr(c) for c in range(32, 127)]
    else:
        members = _class_members(m.group(1))
    if len(members) > 64:
        raise ValueError(f"character class too wide: {m.group(0)}")
    out = []
    for ch in members:
        out.extend(_expand_classes(pre + escape(ch) + post))
    return out


def _class_members(body: str) -> list:
    out = []
    i = 0
    while i < len(body):
        if i + 2 < len(body) and body[i + 1] == "-":
            out.extend(chr(c) for c in range(ord(body[i]), ord(body[i + 2]) + 1))
            i += 3
        else:
            out.append(body[i])
            i += 1
    return out


@dataclass(frozen=True)
class RuleSet:
    rules: tuple  # tuple[Rule, ...]

    def __post_init__(self):
        ids = [r.rule_id for r in self.rules]
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate rule_ids")

    @property
    def num_rules(self) -> int:
        return 0 if not self.rules else max(r.rule_id for r in self.rules) + 1

    def version_hash(self) -> str:
        payload = json.dumps([asdict(r) for r in sorted(self.rules, key=lambda r: r.rule_id)],
                             sort_keys=True, default=list)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def by_field(self) -> dict:
        """field name -> list[Rule] ('*' rules appear under '*')."""
        out: dict = {}
        for r in self.rules:
            for f in r.fields:
                out.setdefault(f, []).append(r)
        return out

    def rules_for_field(self, field_name: str) -> list:
        return [r for r in self.rules
                if "*" in r.fields or field_name in r.fields]

    def diff(self, other: "RuleSet") -> dict:
        """Delta from self -> other (paper §3.4 'Delta Computation')."""
        mine = {r.rule_id: r for r in self.rules}
        theirs = {r.rule_id: r for r in other.rules}
        added = [theirs[i] for i in theirs.keys() - mine.keys()]
        removed = [mine[i] for i in mine.keys() - theirs.keys()]
        changed = [theirs[i] for i in theirs.keys() & mine.keys()
                   if theirs[i] != mine[i]]
        return {"added": sorted(added, key=lambda r: r.rule_id),
                "removed": sorted(removed, key=lambda r: r.rule_id),
                "changed": sorted(changed, key=lambda r: r.rule_id)}

    def with_rules(self, new_rules: Iterable[Rule]) -> "RuleSet":
        by_id = {r.rule_id: r for r in self.rules}
        for r in new_rules:
            by_id[r.rule_id] = r
        return RuleSet(tuple(sorted(by_id.values(), key=lambda r: r.rule_id)))

    def without_ids(self, ids: Iterable[int]) -> "RuleSet":
        drop = set(ids)
        return RuleSet(tuple(r for r in self.rules if r.rule_id not in drop))

    def to_json(self) -> str:
        return json.dumps([asdict(r) for r in self.rules], default=list)

    @staticmethod
    def from_json(s: str) -> "RuleSet":
        return RuleSet(tuple(Rule(rule_id=r["rule_id"], name=r["name"],
                                  pattern=r["pattern"],
                                  fields=tuple(r.get("fields", ("*",))),
                                  case_insensitive=r.get("case_insensitive", False))
                             for r in json.loads(s)))
