"""Enrichment representations for match metadata (paper §3.1, §5.1, §6.1).

The native in-framework format is a **packed rule bitmap** — ``(N, W) uint32``
with bit ``r`` of word ``r // 32`` set iff rule ``r`` matched the record.
Fixed width, shardable, bit-addressable at query time, and maximally
RLE/bit-pack friendly for columnar storage (most records are all-zero under
high selectivity).

The paper's two materializations are provided for fidelity benchmarks:
  * Pinot layout  — one boolean column per rule (``to_bool_columns``);
  * DuckDB layout — a sparse ``matched_rule_ids INT[]`` array
    (``to_sparse_ids``: fixed-capacity, -1 padded — the jit-able analogue).
"""
from __future__ import annotations

import numpy as np

from repro.core.automaton import WORD_BITS, words_for_rules


def rule_mask(rule_ids, num_rules: int) -> np.ndarray:
    """Query-time mask: (W,) uint32 with the given rule bits set."""
    W = words_for_rules(num_rules)
    mask = np.zeros(W, np.uint32)
    for r in rule_ids:
        if not 0 <= r < num_rules:
            raise ValueError(f"rule id {r} out of range [0, {num_rules})")
        mask[r // WORD_BITS] |= np.uint32(1 << (r % WORD_BITS))
    return mask


def bitmap_get(bm: np.ndarray, rule_id: int) -> np.ndarray:
    """(N, W) -> (N,) bool for a single rule."""
    w, b = rule_id // WORD_BITS, rule_id % WORD_BITS
    return (np.asarray(bm)[:, w] >> np.uint32(b)) & np.uint32(1) != 0


def to_bool_columns(bm: np.ndarray, num_rules: int) -> np.ndarray:
    """Pinot layout: (N, W) uint32 -> (N, num_rules) bool."""
    bm = np.asarray(bm)
    N, W = bm.shape
    bits = np.unpackbits(bm.view(np.uint8).reshape(N, W, 4),
                         axis=-1, bitorder="little")       # (N, W, 32)
    return bits.reshape(N, W * WORD_BITS)[:, :num_rules].astype(bool)


def from_bool_columns(cols: np.ndarray) -> np.ndarray:
    """(N, num_rules) bool -> (N, W) uint32 packed bitmap."""
    cols = np.asarray(cols, bool)
    N, R = cols.shape
    W = words_for_rules(R)
    pad = np.zeros((N, W * WORD_BITS), np.uint8)
    pad[:, :R] = cols
    packed = np.packbits(pad.reshape(N, W, WORD_BITS), axis=-1,
                         bitorder="little")                # (N, W, 4) uint8
    return packed.reshape(N, W * 4).view(np.uint32)


def to_sparse_ids(bm: np.ndarray, max_matches: int = 8) -> np.ndarray:
    """DuckDB layout: (N, W) -> (N, max_matches) int32 rule ids, -1 padded.

    Records matching more than ``max_matches`` rules keep the lowest ids
    (benchmarks size the capacity so this never truncates)."""
    bm = np.asarray(bm)
    R = bm.shape[1] * WORD_BITS
    cols = to_bool_columns(bm, R)                          # (N, R)
    ids = np.argsort(~cols, axis=1, kind="stable")[:, :max_matches]
    valid = np.take_along_axis(cols, ids, axis=1)
    return np.where(valid, ids, -1).astype(np.int32)


def from_sparse_ids(ids: np.ndarray, num_rules: int) -> np.ndarray:
    ids = np.asarray(ids)
    N = ids.shape[0]
    W = words_for_rules(num_rules)
    bm = np.zeros((N, W), np.uint32)
    rows, cols = np.nonzero(ids >= 0)
    r = ids[rows, cols]
    np.bitwise_or.at(bm, (rows, r // WORD_BITS),
                     (np.uint32(1) << (r % WORD_BITS).astype(np.uint32)))
    return bm


def popcount(bm: np.ndarray) -> np.ndarray:
    """(N, W) -> (N,) number of matched rules per record."""
    bm = np.asarray(bm)
    return np.unpackbits(bm.view(np.uint8), axis=-1).sum(axis=-1)


def any_match(bm: np.ndarray) -> np.ndarray:
    """(N, W) -> (N,) bool: record matched at least one rule."""
    return np.asarray(bm).any(axis=1)


def storage_nbytes(bm: np.ndarray, layout: str, num_rules: int,
                   max_matches: int = 8) -> int:
    """Raw (pre-compression) footprint of each enrichment layout."""
    bm = np.asarray(bm)
    if layout == "bitmap":
        return bm.nbytes
    if layout == "bools":
        return bm.shape[0] * num_rules  # 1 byte per boolean column value
    if layout == "sparse":
        # list<int32> with per-row length prefix
        return int(popcount(bm).clip(max=max_matches).sum()) * 4 + bm.shape[0] * 4
    raise ValueError(layout)
