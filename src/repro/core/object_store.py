"""Versioned, immutable object store — the S3 stand-in (paper §3.4.1).

Semantics preserved from the paper's design:
  * every ``put`` creates a new immutable version (rollback + audit trail);
  * integrity: sha256 recorded at write, verified at read;
  * lifecycle policies: ``expire_versions`` archives old pattern versions.

Backed by a local directory (or memory for tests).  The layout is
``<root>/<key>/<v000001>.blob`` + ``.meta`` json, mirroring S3 object
versioning closely enough that swapping in a real client is a one-file change.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from dataclasses import dataclass
from pathlib import Path


@dataclass(frozen=True)
class ObjectRef:
    key: str
    version: int
    sha256: str
    size: int

    def to_dict(self) -> dict:
        return {"key": self.key, "version": self.version,
                "sha256": self.sha256, "size": self.size}

    @staticmethod
    def from_dict(d: dict) -> "ObjectRef":
        return ObjectRef(key=d["key"], version=int(d["version"]),
                         sha256=d["sha256"], size=int(d["size"]))


class IntegrityError(ValueError):
    pass


class ObjectStore:
    """put/get with versioning + checksums.  Thread-safe."""

    def __init__(self, root=None):
        self._lock = threading.RLock()
        self._root = Path(root) if root is not None else None
        self._mem: dict = {}  # (key, version) -> (bytes, meta)
        if self._root is not None:
            self._root.mkdir(parents=True, exist_ok=True)

    # -- write ---------------------------------------------------------------
    def put(self, key: str, data: bytes) -> ObjectRef:
        if self._root is not None:
            (self._root / key).mkdir(parents=True, exist_ok=True)
        with self._lock:
            version = self._latest_version(key) + 1
            sha = hashlib.sha256(data).hexdigest()
            meta = {"key": key, "version": version, "sha256": sha,
                    "size": len(data), "created": time.time()}
            if self._root is None:
                self._mem[(key, version)] = (bytes(data), meta)
            else:
                blob = self._path(key, version)
                tmp = blob.with_suffix(".tmp")
                tmp.write_bytes(data)
                os.replace(tmp, blob)  # atomic publish
                self._path(key, version, ".meta").write_text(json.dumps(meta))
            return ObjectRef(key=key, version=version, sha256=sha,
                             size=len(data))

    # -- read ----------------------------------------------------------------
    def get(self, ref: ObjectRef, *, verify: bool = True) -> bytes:
        data, meta = self._load(ref.key, ref.version)
        if verify:
            sha = hashlib.sha256(data).hexdigest()
            if sha != ref.sha256 or sha != meta["sha256"]:
                raise IntegrityError(
                    f"{ref.key} v{ref.version}: checksum mismatch")
        return data

    def get_latest(self, key: str) -> tuple:
        """-> (bytes, ObjectRef) of the newest version."""
        v = self._latest_version(key)
        if v == 0:
            raise KeyError(key)
        data, meta = self._load(key, v)
        return data, ObjectRef(key=key, version=v, sha256=meta["sha256"],
                               size=meta["size"])

    def head(self, key: str, version: int) -> dict:
        _, meta = self._load(key, version)
        return dict(meta)

    def list_versions(self, key: str) -> list:
        with self._lock:
            if self._root is None:
                return sorted(v for k, v in self._mem if k == key)
            d = self._root / key
            if not d.is_dir():
                return []
            return sorted(int(p.stem[1:]) for p in d.glob("v*.blob"))

    # -- lifecycle -----------------------------------------------------------
    def expire_versions(self, key: str, keep_latest: int = 3) -> int:
        """Archive (delete) all but the newest N versions.  Returns #removed."""
        with self._lock:
            versions = self.list_versions(key)
            drop = versions[:-keep_latest] if keep_latest else versions
            for v in drop:
                if self._root is None:
                    self._mem.pop((key, v), None)
                else:
                    self._path(key, v).unlink(missing_ok=True)
                    self._path(key, v, ".meta").unlink(missing_ok=True)
            return len(drop)

    # -- internals -----------------------------------------------------------
    def _path(self, key: str, version: int, suffix: str = ".blob") -> Path:
        return self._root / key / f"v{version:06d}{suffix}"

    def _latest_version(self, key: str) -> int:
        versions = self.list_versions(key)
        return versions[-1] if versions else 0

    def _load(self, key: str, version: int) -> tuple:
        with self._lock:
            if self._root is None:
                if (key, version) not in self._mem:
                    raise KeyError(f"{key} v{version}")
                return self._mem[(key, version)]
            blob = self._path(key, version)
            meta_p = self._path(key, version, ".meta")
            if not blob.exists():
                raise KeyError(f"{key} v{version}")
            return blob.read_bytes(), json.loads(meta_p.read_text())
