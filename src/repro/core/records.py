"""Record batches — the unit of flow through the streaming data plane.

A ``RecordBatch`` is a struct-of-arrays: scalar columns are 1-D numpy arrays,
text columns are fixed-width ``(N, L) uint8`` byte matrices (zero-padded).
Fixed width keeps every stage shape-stable (shardable, jit-friendly) and maps
directly onto the columnar analytical plane.  The paper's logical schema
(§4.3): ``timestamp`` (int64), ``status`` (int32), ``event_type`` (int32),
plus 2–5 ``content*`` text fields of ~60 words each.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

TEXT_WIDTH = 512  # default fixed byte width for content fields


def encode_texts(texts, width: int = TEXT_WIDTH) -> np.ndarray:
    """list[str|bytes] -> (N, width) uint8, zero padded / truncated."""
    out = np.zeros((len(texts), width), np.uint8)
    for i, t in enumerate(texts):
        b = t.encode("utf-8", "ignore") if isinstance(t, str) else bytes(t)
        b = b[:width]
        out[i, :len(b)] = np.frombuffer(b, np.uint8)
    return out


def decode_texts(data: np.ndarray) -> list:
    """(N, L) uint8 -> list[str] (padding stripped)."""
    out = []
    for row in np.asarray(data):
        b = row.tobytes().rstrip(b"\x00")
        out.append(b.decode("utf-8", "replace"))
    return out


@dataclass
class RecordBatch:
    """columns: name -> np.ndarray; text columns are (N, L) uint8 2-D."""
    columns: dict

    def __post_init__(self):
        ns = {k: v.shape[0] for k, v in self.columns.items()}
        if len(set(ns.values())) > 1:
            raise ValueError(f"ragged batch: {ns}")

    @property
    def num_records(self) -> int:
        if not self.columns:
            return 0
        return next(iter(self.columns.values())).shape[0]

    def __len__(self) -> int:
        return self.num_records

    @property
    def text_fields(self) -> tuple:
        return tuple(sorted(k for k, v in self.columns.items()
                            if v.ndim == 2 and v.dtype == np.uint8))

    @property
    def scalar_fields(self) -> tuple:
        return tuple(sorted(k for k, v in self.columns.items()
                            if not (v.ndim == 2 and v.dtype == np.uint8)))

    def with_column(self, name: str, values: np.ndarray) -> "RecordBatch":
        cols = dict(self.columns)
        cols[name] = values
        return RecordBatch(cols)

    def select(self, mask_or_idx: np.ndarray) -> "RecordBatch":
        return RecordBatch({k: v[mask_or_idx] for k, v in self.columns.items()})

    def slice(self, start: int, stop: int) -> "RecordBatch":
        return RecordBatch({k: v[start:stop] for k, v in self.columns.items()})

    @staticmethod
    def concat(batches) -> "RecordBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            return RecordBatch({})
        keys = batches[0].columns.keys()
        return RecordBatch({k: np.concatenate([b.columns[k] for b in batches])
                            for k in keys})

    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.columns.values())
