"""Control-plane bus — the Kafka stand-in (paper §3.4.1/§3.4.3).

Semantics preserved: named topics; ordered, durable, at-least-once delivery;
per-consumer-group offsets (poll without commit re-delivers); small messages
only (the payload is an ObjectRef, never the compiled engine itself — the
paper's "reference-based distribution model").

Two backends share one surface:

  * ``ControlBus`` — in-memory, thread-safe, in-process.  The default for
    tests and the thread worker model.
  * ``DurableControlBus`` — file-backed under a root directory so the same
    at-least-once contract holds across OS *processes*: each topic is an
    append-only JSONL log (appends serialized by an ``flock``), each
    (topic, group) committed offset is its own small JSON file written
    atomically (tmp + ``os.replace``, like the store manifest).  Any number
    of processes may open the same root; a process that crashes between
    processing and committing simply re-reads the uncommitted window on
    restart — exactly the redelivery the in-memory bus gives a thread that
    never called ``commit``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

from repro.core import faults

MATCHER_UPDATES = "matcher-updates"
MATCHER_ACKS = "matcher-acks"
# maintenance plane: engine updates fan out to backfill workers on their own
# topic (independent consumer-group offsets from the stream processors), and
# workers ack once historical segments are re-enriched for a version
SEGMENT_MAINTENANCE = "segment-maintenance"
MAINTENANCE_ACKS = "maintenance-acks"

# conventional location of the durable bus (and lease table) under a store
# root — launchers and process pools agree on <store_root>/<CONTROL_DIRNAME>
CONTROL_DIRNAME = "control-bus"


@dataclass(frozen=True)
class Message:
    topic: str
    offset: int
    value: dict
    timestamp: float


class ControlBus:
    def __init__(self):
        self._lock = threading.RLock()
        self._topics: dict = {}     # topic -> list[Message]
        self._offsets: dict = {}    # (topic, group) -> committed offset

    def publish(self, topic: str, value: dict) -> int:
        with self._lock:
            log = self._topics.setdefault(topic, [])
            msg = Message(topic=topic, offset=len(log), value=dict(value),
                          timestamp=time.time())
            log.append(msg)
            return msg.offset

    def poll(self, topic: str, group: str, max_messages: int = 100) -> list:
        """At-least-once: returns messages past the committed offset; the
        same messages are returned again until ``commit`` advances it.

        The ``bus.deliver`` fault site perturbs the polled window the ways
        a real broker can: ``drop`` (delayed delivery — nothing is lost,
        the uncommitted window redelivers next poll), ``dup`` (the window
        arrives twice — consumers must be idempotent under at-least-once),
        ``reorder`` (the window arrives reversed)."""
        with self._lock:
            log = self._topics.get(topic, [])
            start = self._offsets.get((topic, group), 0)
            msgs = list(log[start:start + max_messages])
        if faults.armed() and msgs:
            action = faults.act("bus.deliver", topic=topic, group=group)
            if action == "drop":
                msgs = []
            elif action == "dup":
                msgs = msgs + msgs
            elif action == "reorder":
                msgs = list(reversed(msgs))
        return msgs

    def commit(self, topic: str, group: str, offset: int) -> None:
        """Advance the group's committed offset (never rewinds).  The
        ``bus.commit`` fault site fires BEFORE the offset moves: a crash
        here models the classic consume/commit window — the work was done
        but the offset was not persisted, so the same messages redeliver
        (at-least-once, consumers must be idempotent)."""
        if faults.armed():
            faults.fire("bus.commit", topic=topic, group=group)
        with self._lock:
            cur = self._offsets.get((topic, group), 0)
            self._offsets[(topic, group)] = max(cur, offset + 1)

    def end_offset(self, topic: str) -> int:
        with self._lock:
            return len(self._topics.get(topic, []))

    def messages(self, topic: str, start: int = 0) -> list:
        """Raw log read (used by the updater to watch acks)."""
        with self._lock:
            return list(self._topics.get(topic, [])[start:])


class DurableControlBus:
    """File-backed ``ControlBus`` — same surface, cross-process semantics.

    Layout under ``root``::

        topics/<topic>.log    append-only JSONL, one message per line
        topics/<topic>.lock   flock serializing appends (and log repair)
        offsets/<topic>--<group>.json   committed offset, atomic replace

    Appends happen under the topic's ``flock`` and are flushed + fsynced
    before the lock drops, so a message whose ``publish`` returned is
    durable and every process sees a consistent prefix.  A writer killed
    mid-append can leave a torn (newline-less) final line; readers ignore
    it and the next publisher truncates it away under the lock — the torn
    message was never acknowledged to anyone, so nothing is lost.

    Offset commits are one small JSON file per (topic, group), written
    tmp + ``os.replace`` like the store manifest: a crash leaves either
    the old offset (redelivery — at-least-once) or the new one, never a
    torn file.  ``commit`` never rewinds an offset, so a delayed commit
    racing a newer one is harmless.

    Instances keep an in-process parse cache per topic (byte watermark +
    decoded messages) so polling is O(new bytes), not O(log).
    """

    def __init__(self, root):
        self.root = Path(root)
        self._topics_dir = self.root / "topics"
        self._offsets_dir = self.root / "offsets"
        self._topics_dir.mkdir(parents=True, exist_ok=True)
        self._offsets_dir.mkdir(parents=True, exist_ok=True)
        self._lock = threading.RLock()
        self._cache: dict = {}      # topic -> [Message] (parsed prefix)
        self._parsed: dict = {}     # topic -> byte watermark of the prefix

    # -- file plumbing -----------------------------------------------------
    def _log_path(self, topic: str) -> Path:
        return self._topics_dir / f"{topic}.log"

    def _offset_path(self, topic: str, group: str) -> Path:
        # groups contain "/" (e.g. "maintenance/maint-0"); keep one flat,
        # reversible file per (topic, group)
        safe = f"{topic}--{group}".replace("/", "__")
        return self._offsets_dir / f"{safe}.json"

    def _topic_flock(self, topic: str):
        import fcntl

        class _Held:
            def __init__(self, path):
                self._f = open(path, "a+")

            def __enter__(self):
                fcntl.flock(self._f.fileno(), fcntl.LOCK_EX)
                return self._f

            def __exit__(self, *exc):
                fcntl.flock(self._f.fileno(), fcntl.LOCK_UN)
                self._f.close()
                return False

        return _Held(self._topics_dir / f"{topic}.lock")

    def _refresh(self, topic: str) -> list:
        """Parse any bytes appended since the last look.  Returns the full
        decoded log.  A trailing torn line (no newline — a writer died
        mid-append) is left unparsed; the watermark stays before it."""
        msgs = self._cache.setdefault(topic, [])
        start = self._parsed.get(topic, 0)
        path = self._log_path(topic)
        try:
            with open(path, "rb") as f:
                f.seek(start)
                chunk = f.read()
        except FileNotFoundError:
            return msgs
        if not chunk:
            return msgs
        end = chunk.rfind(b"\n")
        if end < 0:
            return msgs                      # only a torn tail so far
        for line in chunk[:end].split(b"\n"):
            if not line.strip():
                continue
            rec = json.loads(line)
            msgs.append(Message(topic=topic, offset=int(rec["offset"]),
                                value=rec["value"],
                                timestamp=float(rec["timestamp"])))
        self._parsed[topic] = start + end + 1
        return msgs

    # -- bus surface -------------------------------------------------------
    def publish(self, topic: str, value: dict) -> int:
        with self._lock:
            with self._topic_flock(topic):
                path = self._log_path(topic)
                msgs = self._refresh(topic)
                # repair: drop a torn tail left by a killed writer before
                # appending after it (it was never durable/acknowledged)
                watermark = self._parsed.get(topic, 0)
                try:
                    size = path.stat().st_size
                except FileNotFoundError:
                    size = 0
                if size > watermark:
                    with open(path, "rb+") as f:
                        f.truncate(watermark)
                offset = len(msgs)
                rec = {"offset": offset, "value": dict(value),
                       "timestamp": time.time()}
                line = json.dumps(rec, sort_keys=True) + "\n"
                with open(path, "a", encoding="utf-8") as f:
                    f.write(line)
                    f.flush()
                    os.fsync(f.fileno())
                self._cache[topic].append(
                    Message(topic=topic, offset=offset, value=dict(value),
                            timestamp=rec["timestamp"]))
                self._parsed[topic] = watermark + len(line.encode("utf-8"))
                return offset

    def poll(self, topic: str, group: str, max_messages: int = 100) -> list:
        """Same contract (and the same ``bus.deliver`` fault hooks) as the
        in-memory bus: the uncommitted window, redelivered until commit."""
        with self._lock:
            log = self._refresh(topic)
            start = self._read_offset(topic, group)
            msgs = list(log[start:start + max_messages])
        if faults.armed() and msgs:
            action = faults.act("bus.deliver", topic=topic, group=group)
            if action == "drop":
                msgs = []
            elif action == "dup":
                msgs = msgs + msgs
            elif action == "reorder":
                msgs = list(reversed(msgs))
        return msgs

    def _read_offset(self, topic: str, group: str) -> int:
        try:
            state = json.loads(
                self._offset_path(topic, group).read_text("utf-8"))
            return int(state.get("offset", 0))
        except (FileNotFoundError, ValueError):
            return 0

    def commit(self, topic: str, group: str, offset: int) -> None:
        """Durably advance the group's offset (never rewinds).  The
        ``bus.commit`` fault site fires BEFORE the atomic replace: a crash
        in that window leaves the old offset on disk and the processed
        messages redeliver on restart — the at-least-once crash window the
        durable-bus tests exercise with real processes."""
        if faults.armed():
            faults.fire("bus.commit", topic=topic, group=group)
        with self._lock:
            path = self._offset_path(topic, group)
            cur = self._read_offset(topic, group)
            new = max(cur, int(offset) + 1)
            if new == cur:
                return
            tmp = path.with_name(path.name + ".tmp")
            tmp.write_text(json.dumps({"offset": new}), "utf-8")
            os.replace(tmp, path)

    def end_offset(self, topic: str) -> int:
        with self._lock:
            return len(self._refresh(topic))

    def messages(self, topic: str, start: int = 0) -> list:
        """Raw log read (used by the updater to watch acks and by workers
        for recovery replay)."""
        with self._lock:
            return list(self._refresh(topic)[start:])
