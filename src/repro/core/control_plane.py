"""Control-plane bus — the Kafka stand-in (paper §3.4.1/§3.4.3).

Semantics preserved: named topics; ordered, durable, at-least-once delivery;
per-consumer-group offsets (poll without commit re-delivers); small messages
only (the payload is an ObjectRef, never the compiled engine itself — the
paper's "reference-based distribution model").  Thread-safe, in-process.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.core import faults

MATCHER_UPDATES = "matcher-updates"
MATCHER_ACKS = "matcher-acks"
# maintenance plane: engine updates fan out to backfill workers on their own
# topic (independent consumer-group offsets from the stream processors), and
# workers ack once historical segments are re-enriched for a version
SEGMENT_MAINTENANCE = "segment-maintenance"
MAINTENANCE_ACKS = "maintenance-acks"


@dataclass(frozen=True)
class Message:
    topic: str
    offset: int
    value: dict
    timestamp: float


class ControlBus:
    def __init__(self):
        self._lock = threading.RLock()
        self._topics: dict = {}     # topic -> list[Message]
        self._offsets: dict = {}    # (topic, group) -> committed offset

    def publish(self, topic: str, value: dict) -> int:
        with self._lock:
            log = self._topics.setdefault(topic, [])
            msg = Message(topic=topic, offset=len(log), value=dict(value),
                          timestamp=time.time())
            log.append(msg)
            return msg.offset

    def poll(self, topic: str, group: str, max_messages: int = 100) -> list:
        """At-least-once: returns messages past the committed offset; the
        same messages are returned again until ``commit`` advances it.

        The ``bus.deliver`` fault site perturbs the polled window the ways
        a real broker can: ``drop`` (delayed delivery — nothing is lost,
        the uncommitted window redelivers next poll), ``dup`` (the window
        arrives twice — consumers must be idempotent under at-least-once),
        ``reorder`` (the window arrives reversed)."""
        with self._lock:
            log = self._topics.get(topic, [])
            start = self._offsets.get((topic, group), 0)
            msgs = list(log[start:start + max_messages])
        if faults.armed() and msgs:
            action = faults.act("bus.deliver", topic=topic, group=group)
            if action == "drop":
                msgs = []
            elif action == "dup":
                msgs = msgs + msgs
            elif action == "reorder":
                msgs = list(reversed(msgs))
        return msgs

    def commit(self, topic: str, group: str, offset: int) -> None:
        with self._lock:
            cur = self._offsets.get((topic, group), 0)
            self._offsets[(topic, group)] = max(cur, offset + 1)

    def end_offset(self, topic: str) -> int:
        with self._lock:
            return len(self._topics.get(topic, []))

    def messages(self, topic: str, start: int = 0) -> list:
        """Raw log read (used by the updater to watch acks)."""
        with self._lock:
            return list(self._topics.get(topic, [])[start:])
