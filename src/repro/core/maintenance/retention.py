"""Retention (TTL age-out) and spill-dir garbage collection.

The maintenance plane is the natural home for age-out: observability data
has a retention horizon, and enforcing it belongs off the ingest and query
paths, under the same lease/fencing discipline as every other segment
writer.

Two levels, LSM-style:

  * **segment expiry** — a sealed segment whose entire timestamp range
    predates the horizon is retired outright: one atomic
    ``SegmentStore.retire_segments`` (manifest commit is the commit point,
    a maintenance epoch is published, the spill dir is tombstoned for the
    GC).  In-flight readers holding the old segment list stay correct —
    the objects and files remain valid until the GC collects them;
  * **row tombstoning** — a segment *straddling* the horizon is stamped
    with a ``retention_cutoff`` in its metadata (a fenced, meta-only
    ``apply_update``).  Rows below the cutoff are logically expired; the
    :class:`~repro.core.maintenance.compactor.Compactor` physically drops
    them on its next rewrite of the segment (straddlers become compaction
    candidates even solo), re-deriving every index and zone map from the
    surviving rows.  Until that rewrite the rows remain visible on every
    query path — retention here is an eventual, compaction-enforced bound
    (the LSM tombstone model), never a torn per-path filter.

The horizon is **event time** (the ``timestamp`` column's units), computed
watermark-style from the newest sealed data — so tests and replays are
deterministic and a stalled ingest never silently expires the whole store.

``SpillGC`` closes the loop from PR 1's tombstone-don't-delete decision:
RETIRED spill dirs are kept on disk for in-flight readers, and deleted
only once (1) the manifest no longer lists the segment, (2) no leased
arrangement pins it (``ArrangementStore.pinned_segment_ids`` — the
epoch-drain signal), and (3) a grace window has passed since tombstoning
(covers readers outside the arrangement plane, e.g. cold copy-mode scans).
"""
from __future__ import annotations

import re
import shutil
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core import telemetry
from repro.core.maintenance.lease import FencedWriteError, LeaseManager
from repro.core.query.store import RETENTION_CUTOFF, RETIRED_MARKER  # noqa: F401 — re-exported; the planner reads the same key at plan time

_SEGDIR_RE = re.compile(r"segment-(\d+)$")

_RET_EXPIRED = telemetry.counter(
    "fluxsieve_maintenance_segments_expired_total",
    help="Whole segments retired by the retention plane.")
_RET_ROWS = telemetry.counter(
    "fluxsieve_maintenance_rows_tombstoned_total",
    help="Rows logically expired, awaiting compaction.")
_GC_DIRS = telemetry.counter(
    "fluxsieve_maintenance_gc_dirs_deleted_total",
    help="Drained RETIRED spill dirs deleted by the GC.")
_GC_BYTES = telemetry.counter(
    "fluxsieve_maintenance_gc_bytes_deleted_total",
    help="Bytes reclaimed by spill-dir GC.")
_GC_ORPHANS = telemetry.counter(
    "fluxsieve_maintenance_gc_orphans_deleted_total",
    help="Orphaned (never-registered) spill dirs swept by the GC.")

# RETENTION_CUTOFF (imported above, defined next to the segment metadata it
# stamps): rows with timestamp < cutoff are plan-time invisible immediately
# and physically dropped by the Compactor's next rewrite of the segment


@dataclass(frozen=True)
class RetentionPolicy:
    """``max_age``: event-time units (the ``timestamp`` column's) a record
    stays queryable past the store's newest sealed timestamp.  ``horizon``
    overrides the watermark computation with an absolute cutoff."""
    max_age: int = None
    horizon: int = None


@dataclass
class RetentionReport:
    horizon: int = None
    segments_expired: int = 0   # whole segments retired
    segments_marked: int = 0    # straddlers stamped with a cutoff
    rows_tombstoned: int = 0    # logically expired rows awaiting compaction
    records_expired: int = 0
    segments_contended: int = 0
    seconds: float = 0.0
    errors: list = field(default_factory=list)


class RetentionWorker:
    """One retention pass per ``run_cycle``; safe to co-run with backfill
    and compaction (writes are leased + fenced when ``leases`` is given,
    and ``retire_segments`` no-ops on races either way)."""

    def __init__(self, store, policy: RetentionPolicy, *,
                 leases: LeaseManager = None,
                 worker_id: str = "retention-0"):
        self.store = store
        self.policy = policy
        self.leases = leases
        self.worker_id = worker_id

    def horizon(self) -> int:
        """The event-time cutoff: explicit policy horizon, else watermark
        (newest sealed ``ts_max``) minus ``max_age``.  None = nothing to
        expire (no policy, or no timestamped segments yet)."""
        if self.policy.horizon is not None:
            return int(self.policy.horizon)
        if self.policy.max_age is None:
            return None
        newest = [s.meta["ts_max"] for s in list(self.store.segments)
                  if s.meta.get("ts_max") is not None]
        if not newest:
            return None
        return int(max(newest)) - int(self.policy.max_age)

    def run_cycle(self) -> RetentionReport:
        rep = RetentionReport()
        t0 = time.perf_counter()
        with telemetry.span("maintenance/retention_cycle", cat="maintenance",
                            worker=self.worker_id):
            horizon = self.horizon()
            rep.horizon = horizon
            if horizon is None:
                rep.seconds = time.perf_counter() - t0
                return rep
            for seg in list(self.store.segments):
                ts_min = seg.meta.get("ts_min")
                ts_max = seg.meta.get("ts_max")
                if ts_min is None or ts_max is None:
                    continue    # untimestamped segments never age out
                try:
                    if ts_max < horizon:
                        self._expire(seg, rep)
                    elif ts_min < horizon and \
                            seg.meta.get(RETENTION_CUTOFF) != horizon:
                        self._mark(seg, horizon, rep)
                except FencedWriteError:
                    rep.segments_contended += 1
                except Exception as e:  # noqa: BLE001 — per-segment isolation
                    if len(rep.errors) < 8:
                        rep.errors.append((seg.segment_id, str(e)))
        _RET_EXPIRED.inc(rep.segments_expired)
        _RET_ROWS.inc(rep.rows_tombstoned)
        rep.seconds = time.perf_counter() - t0
        return rep

    def _expire(self, seg, rep: RetentionReport) -> None:
        lease = self._acquire(seg)
        if lease is None and self.leases is not None:
            rep.segments_contended += 1
            return
        try:
            fence = self.leases.fence(lease) if lease is not None else None
            if self.store.retire_segments([seg], fence=fence):
                rep.segments_expired += 1
                rep.records_expired += seg.num_records
                telemetry.emit("segment_expired", plane="maintenance",
                               segment=seg.segment_id,
                               records=seg.num_records)
        finally:
            if lease is not None:
                self.leases.release(lease)

    def _mark(self, seg, horizon: int, rep: RetentionReport) -> None:
        lease = self._acquire(seg)
        if lease is None and self.leases is not None:
            rep.segments_contended += 1
            return
        try:
            fence = self.leases.fence(lease) if lease is not None else None
            seg.apply_update(meta_updates={RETENTION_CUTOFF: int(horizon)},
                             fence=fence)
            ts = np.asarray(seg.column("timestamp", cache=False))
            expired = int((ts < horizon).sum())
            rep.segments_marked += 1
            rep.rows_tombstoned += expired
        finally:
            if lease is not None:
                self.leases.release(lease)

    def _acquire(self, seg):
        if self.leases is None:
            return None
        return self.leases.acquire(seg.segment_id, self.worker_id)


@dataclass
class GCReport:
    dirs_deleted: int = 0
    bytes_deleted: int = 0
    dirs_kept_pinned: int = 0   # a leased arrangement still references it
    dirs_kept_grace: int = 0    # tombstone younger than the grace window
    orphans_deleted: int = 0    # never-registered dirs past the horizon
    seconds: float = 0.0


class SpillGC:
    """Deletes RETIRED spill dirs once no reader can reference them.

    A dir qualifies when its segment id is absent from the root manifest
    (membership already atomically revoked), no arrangement store reports
    it pinned (``pinned_segment_ids`` — segment ids referenced by
    refcounted device columns of in-flight leases; the deterministic
    epoch-drain signal), and its RETIRED tombstone is at least ``grace_s``
    old (readers outside the arrangement plane — cold copy-mode
    materialization, direct column reads — finish well inside it).

    **Orphan sweep**: a crash between a segment's spill and its manifest
    registration leaves a ``segment-*`` dir that no manifest lists and no
    tombstone marks — invisible to ``load``, untouched by the RETIRED
    path, leaked forever.  The sweep collects such dirs once they are
    older than ``orphan_grace_s`` (dir mtime — a *generous* horizon, far
    beyond any spill-to-commit window, so an in-flight seal is never shot
    down) — and ONLY when a root manifest actually exists on disk: in a
    pre-manifest store the unregistered dirs ARE the data.

    ``arrangements`` accepts one ``ArrangementStore`` or an iterable of
    them (one per engine is common)."""

    def __init__(self, store, *, arrangements=None, grace_s: float = 60.0,
                 orphan_grace_s: float = 3600.0, clock=time.time):
        self.store = store
        if arrangements is None:
            self.arrangements = ()
        elif hasattr(arrangements, "pinned_segment_ids"):
            self.arrangements = (arrangements,)
        else:
            self.arrangements = tuple(arrangements)
        self.grace_s = float(grace_s)
        self.orphan_grace_s = float(orphan_grace_s)
        self.clock = clock

    def run_cycle(self) -> GCReport:
        rep = GCReport()
        t0 = time.perf_counter()
        with telemetry.span("maintenance/gc_cycle", cat="maintenance"):
            root = self.store.root
            if root is None:
                rep.seconds = time.perf_counter() - t0
                return rep
            manifest = self.store.manifest
            valid = (manifest.segment_ids()
                     if manifest is not None else set())
            # the orphan sweep needs a durable authority on membership: a
            # manifest object always exists on a rooted store, but only an
            # on-disk manifest FILE proves this store registers its spills
            sweep_orphans = manifest is not None and manifest.path.exists()
            pinned = set()
            for arr in self.arrangements:
                pinned |= arr.pinned_segment_ids()
            now = self.clock()
            for d in sorted(Path(root).glob("segment-*")):
                marker = d / RETIRED_MARKER
                m = _SEGDIR_RE.search(d.name)
                sid = int(m.group(1)) if m else None
                if sid is not None and sid in valid:
                    continue    # manifest-listed: live, never collectable
                if not marker.exists():
                    # unregistered, untombstoned: an orphan from a crash
                    # between spill and manifest registration
                    if not sweep_orphans or sid is None:
                        continue
                    if sid in pinned:
                        rep.dirs_kept_pinned += 1
                        continue
                    try:
                        if now - d.stat().st_mtime < self.orphan_grace_s:
                            rep.dirs_kept_grace += 1
                            continue
                        size = sum(f.stat().st_size
                                   for f in d.glob("*") if f.is_file())
                        shutil.rmtree(d)
                        rep.orphans_deleted += 1
                        rep.bytes_deleted += size
                        _GC_ORPHANS.inc()
                        _GC_BYTES.inc(size)
                    except OSError as e:    # raced another GC / busy file
                        telemetry.suppressed("maintenance.gc_orphan", e)
                        continue
                    continue
                if sid is not None and sid in pinned:
                    rep.dirs_kept_pinned += 1
                    continue
                try:
                    if now - marker.stat().st_mtime < self.grace_s:
                        rep.dirs_kept_grace += 1
                        continue
                    size = sum(f.stat().st_size
                               for f in d.glob("*") if f.is_file())
                    shutil.rmtree(d)
                    rep.dirs_deleted += 1
                    rep.bytes_deleted += size
                    _GC_DIRS.inc()
                    _GC_BYTES.inc(size)
                except OSError as e:
                    telemetry.suppressed("maintenance.gc_retired", e)
                    continue    # raced another GC / busy file; retry next
        if rep.dirs_deleted or rep.orphans_deleted:
            telemetry.emit("gc_sweep", plane="maintenance",
                           dirs_deleted=rep.dirs_deleted,
                           orphans_deleted=rep.orphans_deleted,
                           bytes_deleted=rep.bytes_deleted)
        rep.seconds = time.perf_counter() - t0
        return rep
