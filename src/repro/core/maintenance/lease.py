"""Per-segment leases with epoch fencing — the maintenance plane's mutual
exclusion primitive.

Distributed maintenance workers (``MaintenanceWorkerPool``) shard work by
segment-id hash, so under a correct configuration two workers never target
the same segment.  Sharding alone, however, is a *policy*, not a guarantee:
a misconfigured pool, a worker restarted under a stale shard map, or a
paused worker resuming after its shard was reassigned can all aim two
writers at one segment.  Leases make exclusion explicit, and **epoch
fencing** makes it crash-safe:

  * ``acquire(segment_id, holder)`` grants a time-bounded lease and bumps
    the segment's **fencing epoch** — a monotonic per-segment counter that
    never moves backwards, persisted through the segment store's crash-safe
    manifest when one is attached (a process restart cannot re-issue an
    old epoch);
  * a crashed (or descheduled) worker's lease simply *expires*: the next
    ``acquire`` succeeds with a higher epoch instead of wedging the shard;
  * every segment **write** carries its lease's epoch as a fencing token
    (``Segment.apply_update(fence=...)``): the token is checked against the
    highest epoch ever issued for that segment, inside the segment's write
    lock, immediately before the first byte is mutated.  A worker that lost
    its lease — however late it wakes up — gets ``FencedWriteError`` rather
    than silently clobbering its successor's install.

This is the classic fencing-token discipline (Chubby / ZooKeeper lock
services): expiry alone never rejects a write — only the existence of a
*successor* epoch does — so a slow-but-uncontended worker is never failed
by clock skew, while a superseded one can never interleave with the new
holder.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

from repro.core import telemetry

_ACQUIRED = telemetry.counter(
    "fluxsieve_maintenance_leases_acquired_total",
    help="Maintenance leases granted.")
_CONTENDED = telemetry.counter(
    "fluxsieve_maintenance_leases_contended_total",
    help="Lease acquisitions refused while another holder's lease stood.")
_FENCED = telemetry.counter(
    "fluxsieve_maintenance_fencing_rejections_total",
    help="Writes rejected at the fencing barrier (stale epoch token).")


class FencedWriteError(RuntimeError):
    """A segment write presented a stale fencing token: the writer's lease
    was superseded (its epoch is below the highest issued for the segment).
    Workers treat this as "lost the race" — skip, never retry the write
    with the same lease."""


@dataclass
class Lease:
    """One granted lease.  ``epoch`` is the fencing token its writes carry;
    ``expires_at`` is advisory for the *next acquirer* (expiry makes the
    segment re-acquirable; it does not by itself invalidate writes)."""
    segment_id: int
    holder: str
    epoch: int
    expires_at: float
    released: bool = field(default=False, compare=False)


def shard_of(segment_id: int, num_shards: int) -> int:
    """Stable segment-id -> shard hash (Knuth multiplicative), shared by
    the pool and by anything that needs to predict worker ownership.  A
    plain modulo would correlate with the store's round-robin id
    allocation; the multiplicative mix keeps shards balanced under any id
    stride."""
    if num_shards <= 1:
        return 0
    return ((int(segment_id) * 2654435761) & 0xFFFFFFFF) % num_shards


class LeaseManager:
    """Thread-safe lease table + fencing-epoch registry.

    One instance coordinates every maintenance writer over a store
    (backfill workers, compactor, retention).  When ``manifest`` is given
    (the SegmentStore's crash-safe root manifest) an epoch is never
    granted above what is durably reserved on disk, so epochs survive
    process restarts — the manifest doubles as the durable fencing-token
    store.  Reservation is done in BLOCKS of ``epoch_block``: the
    persisted value is an upper bound on epochs ever issued, written once
    per block rather than once per acquire — N pool workers do not
    serialize on per-segment manifest I/O on the very path this plane
    parallelizes, and a restarted manager simply resumes ABOVE the bound
    (unused reserved epochs are skipped, monotonicity holds).

    ``clock`` is injectable (tests drive expiry deterministically)."""

    def __init__(self, *, ttl: float = 30.0, clock=time.monotonic,
                 manifest=None, epoch_block: int = 64):
        self.ttl = float(ttl)
        self.clock = clock
        self.manifest = manifest
        self.epoch_block = max(int(epoch_block), 1)
        self._lock = threading.Lock()
        self._leases: dict = {}     # segment_id -> Lease (latest granted)
        self._epochs: dict = {}     # segment_id -> highest issued epoch
        self._reserved: dict = {}   # segment_id -> highest epoch durable
        if manifest is not None:
            for sid, epoch in manifest.fences().items():
                self._epochs[int(sid)] = int(epoch)
                self._reserved[int(sid)] = int(epoch)

    # -- grant plane -------------------------------------------------------
    def acquire(self, segment_id: int, holder: str) -> Lease:
        """Try to lease ``segment_id``.  Returns ``None`` while another
        holder's unexpired lease stands (the caller skips the segment this
        cycle); otherwise grants a fresh lease one epoch above every epoch
        ever issued for the segment — which *immediately* fences any
        still-running previous holder."""
        sid = int(segment_id)
        with self._lock:
            now = self.clock()
            cur = self._leases.get(sid)
            if (cur is not None and not cur.released
                    and cur.holder != holder and cur.expires_at > now):
                _CONTENDED.inc()
                return None
            epoch = self._epochs.get(sid, 0) + 1
            if self.manifest is not None and \
                    epoch > self._reserved.get(sid, 0):
                # durability first: a covering reservation must be on disk
                # before any write can carry this epoch, or a crash+restart
                # could re-issue it.  Reserving a block amortizes the
                # manifest write to once per epoch_block acquires.
                bound = epoch + self.epoch_block - 1
                self.manifest.commit(fences={sid: bound})
                self._reserved[sid] = bound
            self._epochs[sid] = epoch
            lease = Lease(segment_id=sid, holder=holder, epoch=epoch,
                          expires_at=now + self.ttl)
            self._leases[sid] = lease
        _ACQUIRED.inc()
        telemetry.emit("lease_acquired", plane="maintenance",
                       segment=sid, holder=holder, epoch=epoch)
        return lease

    def renew(self, lease: Lease) -> bool:
        """Extend a still-current lease's expiry.  False once superseded."""
        with self._lock:
            if (lease.released
                    or self._epochs.get(lease.segment_id, 0) != lease.epoch):
                return False
            lease.expires_at = self.clock() + self.ttl
            return True

    def release(self, lease: Lease) -> None:
        """Give the lease up early (normal end-of-write path).  The epoch
        registry is untouched: fencing history never rewinds."""
        with self._lock:
            lease.released = True
            if self._leases.get(lease.segment_id) is lease:
                del self._leases[lease.segment_id]

    # -- fencing plane -----------------------------------------------------
    def check(self, lease: Lease) -> None:
        """The write barrier: raise ``FencedWriteError`` if ``lease`` was
        superseded by a higher epoch (or released).  Called by
        ``Segment.apply_update`` via ``fence=``, inside the segment's write
        lock, before the first mutation."""
        with self._lock:
            current = self._epochs.get(lease.segment_id, 0)
            if lease.released or lease.epoch < current:
                _FENCED.inc()
                telemetry.emit("fencing_rejection", plane="maintenance",
                               segment=lease.segment_id,
                               holder=lease.holder, token=lease.epoch,
                               current_epoch=current)
                raise FencedWriteError(
                    f"segment {lease.segment_id}: fencing token "
                    f"{lease.epoch} (holder {lease.holder!r}) superseded by "
                    f"epoch {current} — write rejected")

    def fence(self, lease: Lease):
        """Zero-arg fencing callable for ``Segment.apply_update(fence=)``."""
        return lambda: self.check(lease)

    def holder_of(self, segment_id: int):
        """Current unexpired holder (None when free) — observability."""
        with self._lock:
            cur = self._leases.get(int(segment_id))
            if (cur is None or cur.released
                    or cur.expires_at <= self.clock()):
                return None
            return cur.holder


class DurableLeaseManager:
    """Cross-process lease table + fencing-epoch registry — the same
    surface and fencing-token discipline as ``LeaseManager``, persisted as
    one JSON document so leases and epochs coordinate writers in
    *different OS processes*.

    Layout under ``root`` (conventionally the store's ``control-bus/``
    dir, next to the durable bus logs)::

        leases.json   {"epochs": {sid: int}, "leases": {sid: {...}}}
        leases.lock   flock serializing read-modify-write transactions

    Invariants carried over from the in-memory manager, made durable:

      * the epoch (and the lease that carries it) is written to disk —
        tmp + ``os.replace`` while the ``flock`` is held — BEFORE
        ``acquire`` returns, so a process restart can never re-issue an
        epoch some write may already carry;
      * ``check`` re-reads the durable state, so a SIGKILLed-then-
        restarted stale holder is fenced by the successor epoch another
        process granted while it was dead;
      * expiry alone never rejects a write — only a successor epoch does.

    The per-segment epoch registry lives here rather than in the store
    manifest (where ``LeaseManager`` reserves its blocks): the manifest's
    read-modify-write commit is single-writer by design, while this file
    has exactly one writer at a time *by construction* (the flock is held
    across the whole transaction).

    ``clock`` defaults to wall time — ``time.monotonic`` is not comparable
    across processes.
    """

    def __init__(self, root, *, ttl: float = 30.0, clock=time.time):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.path = self.root / "leases.json"
        self._lockpath = self.root / "leases.lock"
        self.ttl = float(ttl)
        self.clock = clock
        self._lock = threading.Lock()   # thread-safety within one process

    # -- durable state -----------------------------------------------------
    def _flock(self):
        import fcntl

        class _Held:
            def __init__(self, path):
                self._f = open(path, "a+")

            def __enter__(self):
                fcntl.flock(self._f.fileno(), fcntl.LOCK_EX)
                return self._f

            def __exit__(self, *exc):
                fcntl.flock(self._f.fileno(), fcntl.LOCK_UN)
                self._f.close()
                return False

        return _Held(self._lockpath)

    def _read(self) -> dict:
        try:
            state = json.loads(self.path.read_text("utf-8"))
        except (FileNotFoundError, ValueError):
            return {"epochs": {}, "leases": {}}
        state.setdefault("epochs", {})
        state.setdefault("leases", {})
        return state

    def _write(self, state: dict) -> None:
        tmp = self.path.with_name(self.path.name + ".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(state, f, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)

    # -- grant plane -------------------------------------------------------
    def acquire(self, segment_id: int, holder: str):
        sid = int(segment_id)
        key = str(sid)
        with self._lock, self._flock():
            state = self._read()
            now = self.clock()
            cur = state["leases"].get(key)
            if (cur is not None and not cur.get("released")
                    and cur["holder"] != holder
                    and float(cur["expires_at"]) > now):
                _CONTENDED.inc()
                return None
            epoch = int(state["epochs"].get(key, 0)) + 1
            expires_at = now + self.ttl
            state["epochs"][key] = epoch
            state["leases"][key] = {"holder": holder, "epoch": epoch,
                                    "expires_at": expires_at,
                                    "released": False}
            # durability first: epoch + lease hit disk before the grant
            # returns, so no write can ever carry an unpersisted epoch
            self._write(state)
        lease = Lease(segment_id=sid, holder=holder, epoch=epoch,
                      expires_at=expires_at)
        _ACQUIRED.inc()
        telemetry.emit("lease_acquired", plane="maintenance",
                       segment=sid, holder=holder, epoch=epoch)
        return lease

    def renew(self, lease: Lease) -> bool:
        key = str(lease.segment_id)
        with self._lock, self._flock():
            state = self._read()
            if (lease.released
                    or int(state["epochs"].get(key, 0)) != lease.epoch):
                return False
            lease.expires_at = self.clock() + self.ttl
            cur = state["leases"].get(key)
            if cur is not None and cur["epoch"] == lease.epoch:
                cur["expires_at"] = lease.expires_at
                self._write(state)
            return True

    def release(self, lease: Lease) -> None:
        key = str(lease.segment_id)
        with self._lock, self._flock():
            lease.released = True
            state = self._read()
            cur = state["leases"].get(key)
            if cur is not None and cur["epoch"] == lease.epoch:
                del state["leases"][key]
                self._write(state)

    # -- fencing plane -----------------------------------------------------
    def check(self, lease: Lease) -> None:
        """The write barrier, against the DURABLE epoch registry: a holder
        that slept through its own SIGKILL-and-restart still sees the
        successor's epoch, whichever process granted it."""
        with self._lock:
            state = self._read()
            current = int(state["epochs"].get(str(lease.segment_id), 0))
        if lease.released or lease.epoch < current:
            _FENCED.inc()
            telemetry.emit("fencing_rejection", plane="maintenance",
                           segment=lease.segment_id,
                           holder=lease.holder, token=lease.epoch,
                           current_epoch=current)
            raise FencedWriteError(
                f"segment {lease.segment_id}: fencing token "
                f"{lease.epoch} (holder {lease.holder!r}) superseded by "
                f"epoch {current} — write rejected")

    def fence(self, lease: Lease):
        """Zero-arg fencing callable for ``Segment.apply_update(fence=)``."""
        return lambda: self.check(lease)

    def holder_of(self, segment_id: int):
        """Current unexpired holder (None when free) — observability."""
        with self._lock:
            cur = self._read()["leases"].get(str(int(segment_id)))
        if (cur is None or cur.get("released")
                or float(cur["expires_at"]) <= self.clock()):
            return None
        return cur["holder"]
