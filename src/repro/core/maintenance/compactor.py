"""Compactor — merge small sealed segments into right-sized ones.

Long-running ingests (frequent seals, filter-mode pipelines, restarts)
degrade into thousands of tiny segments; per-segment overheads (zone-map
checks, file opens, index lookups) then dominate query latency.  The
compactor merges runs of *adjacent* undersized sealed segments into
right-sized ones, re-deriving every artifact a seal would produce — zone
maps, rule counts, rule postings, text indexes — via the store's own
segment-construction path, so a compacted segment is indistinguishable from
a natively sealed one.

Coverage metadata is the intersection of the inputs' ``rule_idents`` (a rule
is known for the merged segment only if every input knew it with the same
content identity), preserving the consistency invariant: queries return
byte-identical results before, during, and after compaction.

The swap is atomic AND crash-safe: the merged segment is fully built (and
spilled, but NOT registered in the root manifest) first; only
``SegmentStore.replace_segments`` commits "merged in, inputs out" — one
atomic manifest write — so a hard kill at any point leaves a reload
counting every record exactly once.  Input columns are pre-warmed into
memory so in-flight queries holding the old segment list keep working even
after the old spill dirs are retired (the ``SpillGC`` deletes them later).

The compactor is also the retention plane's muscle: a segment stamped with
a ``retention_cutoff`` (see ``maintenance.retention``) has its expired rows
physically dropped during the rewrite — such segments are compaction
candidates even solo, so a straddler is purged without waiting for small
neighbors.
"""
from __future__ import annotations

import shutil
import time
from dataclasses import dataclass

import numpy as np

from repro.core import telemetry
from repro.core.maintenance.lease import FencedWriteError, LeaseManager
from repro.core.maintenance.retention import RETENTION_CUTOFF
from repro.core.query.store import (Segment, SegmentStore, pack_known_bitmap,
                                    rules_known_for_versions)
from repro.core.records import RecordBatch
from repro.core.stream_processor import ENRICH_COLUMN

_MERGES = telemetry.counter(
    "fluxsieve_maintenance_compaction_merges_total",
    help="Compaction merges committed.")
_ROWS_PURGED = telemetry.counter(
    "fluxsieve_maintenance_rows_purged_total",
    help="Retention-tombstoned rows physically dropped by compaction.")
_COMPACT_BYTES = telemetry.counter(
    "fluxsieve_maintenance_compaction_bytes_total",
    help="Bytes rewritten by compaction merges.")


@dataclass
class CompactionReport:
    merges: int = 0
    merges_failed: int = 0      # group raised (e.g. corrupt spill file)
    merges_contended: int = 0   # a group member was leased elsewhere
    errors: list = None         # (segment ids, error) pairs, capped
    segments_in: int = 0
    segments_out: int = 0
    records: int = 0
    rows_purged: int = 0        # retention-tombstoned rows dropped
    bytes_rewritten: int = 0
    seconds: float = 0.0

    def __post_init__(self):
        if self.errors is None:
            self.errors = []


class Compactor:
    """``min_records``: a sealed segment smaller than this is a merge
    candidate (default: half the store's seal size).  ``target_records``:
    stop growing a merge group at this size (default: the seal size).
    ``leases``: when the distributed maintenance plane is live, group
    members are leased before the rewrite so compaction never races a
    backfill/retention writer on the same segment (contended groups are
    skipped, retried next cycle)."""

    def __init__(self, store: SegmentStore, *, min_records: int = None,
                 target_records: int = None, leases: LeaseManager = None,
                 worker_id: str = "compactor-0"):
        self.store = store
        self.min_records = (min_records if min_records is not None
                            else max(1, store.segment_size // 2))
        self.target_records = (target_records if target_records is not None
                               else store.segment_size)
        self.leases = leases
        self.worker_id = worker_id
        # failure memory (mirrors BackfillWorker._failed_ids): a permanently
        # failing merge group (e.g. corrupt spill file) must not be fully
        # re-read and re-failed every cycle, nor starve healthy groups
        self._failed_keys: set = set()  # tuple(segment ids) of failed groups

    @staticmethod
    def _schema(seg) -> dict:
        """Mergeable schema: name -> (dtype, per-record shape).  Comparing
        names alone would group e.g. mixed ``text_width`` segments whose
        ``np.concatenate`` then raises every cycle."""
        return {name: (dtype, tuple(shape[1:]))
                for name, (dtype, shape) in seg.meta["columns"].items()}

    @staticmethod
    def _needs_purge(seg) -> bool:
        """Retention stamped this segment: expired rows await the rewrite."""
        return (RETENTION_CUTOFF in seg.meta
                and "timestamp" in seg.meta["columns"])

    def candidate_groups(self) -> list:
        """Runs of adjacent compactable segments with identical schemas
        (column names AND dtypes/widths), greedily grown up to
        ``target_records``.  A run qualifies with >= 2 undersized members
        (the merge case) or with ANY retention-tombstoned member (the purge
        case — a straddler is rewritten solo rather than waiting for small
        neighbors)."""
        groups, run, run_n = [], [], 0

        def close(r):
            if len(r) >= 2 or any(self._needs_purge(s) for s in r):
                groups.append(r)

        for seg in list(self.store.segments):
            purge = self._needs_purge(seg)
            small = seg.num_records < self.min_records or purge
            fits = (run_n + seg.num_records <= self.target_records
                    or (purge and not run))
            same_schema = (not run
                           or self._schema(seg) == self._schema(run[0]))
            if small and fits and same_schema:
                run.append(seg)
                run_n += seg.num_records
            else:
                close(run)
                run, run_n = ([seg], seg.num_records) if small else ([], 0)
        close(run)
        return groups

    def run_cycle(self, *, max_merges: int = None,
                  max_bytes: int = None) -> CompactionReport:
        rep = CompactionReport()
        t0 = time.perf_counter()
        with telemetry.span("maintenance/compaction_cycle",
                            cat="maintenance", worker=self.worker_id):
            self._run_cycle(rep, max_merges, max_bytes)
        _MERGES.inc(rep.merges)
        _ROWS_PURGED.inc(rep.rows_purged)
        _COMPACT_BYTES.inc(rep.bytes_rewritten)
        rep.seconds = time.perf_counter() - t0
        return rep

    def _run_cycle(self, rep: CompactionReport, max_merges, max_bytes):
        used = 0
        groups = self.candidate_groups()
        # previously-failed groups only get budget once every fresh group
        # has been tried (deprioritized, not dropped: a transient failure —
        # a racing maintenance writer, a repaired file — should still heal)
        fresh = [g for g in groups if self._key(g) not in self._failed_keys]
        for group in fresh or groups:
            if max_merges is not None and rep.merges >= max_merges:
                break
            cost = sum(s.nbytes() for s in group)
            if max_bytes is not None and rep.merges and used + cost > max_bytes:
                break
            # per-group isolation: one corrupt spill file must not abort
            # the cycle for the remaining groups (same contract as the
            # BackfillWorker's per-segment isolation)
            try:
                state, purged = self._merge(group)
            except Exception as e:  # noqa: BLE001
                rep.merges_failed += 1
                self._failed_keys.add(self._key(group))
                if len(rep.errors) < 8:
                    rep.errors.append(
                        ([s.segment_id for s in group], str(e)))
                continue
            self._failed_keys.discard(self._key(group))
            if state == "contended":
                rep.merges_contended += 1
            elif state == "merged":
                rep.merges += 1
                rep.segments_in += len(group)
                rep.segments_out += 1
                rep.records += sum(s.num_records for s in group)
                rep.rows_purged += purged
                rep.bytes_rewritten += cost
                used += cost

    @staticmethod
    def _key(group: list) -> tuple:
        return tuple(s.segment_id for s in group)

    def _merge(self, group: list) -> tuple:
        """-> (state, rows purged); state in {"merged", "raced",
        "contended"}.  Leases every member first (when a LeaseManager is
        wired) so no backfill/retention writer can swap a member's
        enrichment between our column reads and the list swap; the commit
        itself re-checks every lease INSIDE the store lock (the fence), so
        a merge that outlived its lease TTL — its columns possibly read
        before a successor's install — can never commit."""
        leases = []
        fence = None
        if self.leases is not None:
            for s in group:
                lease = self.leases.acquire(s.segment_id, self.worker_id)
                if lease is None:
                    for held in leases:
                        self.leases.release(held)
                    return "contended", 0
                leases.append(lease)

            def fence():
                for held in leases:
                    self.leases.check(held)
        try:
            return self._merge_leased(group, fence)
        except FencedWriteError:
            return "contended", 0
        finally:
            for held in leases:
                self.leases.release(held)

    def _merge_leased(self, group: list, fence=None) -> tuple:
        # retention purge: drop rows below a member's tombstone cutoff; the
        # merged segment re-derives every artifact from the survivors
        masks, purged = [], 0
        for s in group:
            if self._needs_purge(s):
                ts = np.asarray(s.column("timestamp", cache=True))
                m = ts >= s.meta[RETENTION_CUTOFF]
                purged += int(len(m) - m.sum())
                masks.append(m)
            else:
                masks.append(None)
        # pre-warm every input column so readers holding the old segment
        # list stay served after the old spill dirs are retired
        names = sorted(group[0].meta["columns"])
        cols = {}
        for name in names:
            parts = [np.asarray(s.column(name, cache=True)) for s in group]
            parts = [p if m is None else p[m]
                     for p, m in zip(parts, masks)]
            if name == ENRICH_COLUMN:
                W = max(p.shape[1] for p in parts)
                parts = [np.pad(p, ((0, 0), (0, W - p.shape[1])))
                         for p in parts]
            cols[name] = np.concatenate(parts)
        # the merged segment spills UNREGISTERED: replace_segments' single
        # manifest commit below is the crash-safety commit point
        merged = self.store.make_segment_from_batch(RecordBatch(cols))
        try:
            self._fix_coverage(merged, group)
            swapped = self.store.replace_segments(group, merged, fence=fence)
        except Exception:
            # never leave an orphaned merged spill dir behind: a
            # pre-manifest load() would pick it up ALONGSIDE the un-retired
            # inputs and double-count
            if merged.path is not None:
                shutil.rmtree(merged.path, ignore_errors=True)
            raise
        if not swapped:
            # raced with another maintenance action — discard our artifact
            if merged.path is not None:
                shutil.rmtree(merged.path, ignore_errors=True)
            return "raced", 0
        return "merged", purged

    def _fix_coverage(self, merged: Segment, group: list) -> None:
        """Merged ``rules_known`` = intersection of the inputs' rule-ident
        maps.  This keeps *backfilled* coverage (which can exceed what the
        version registry implies) instead of re-deriving from versions."""
        maps = [s.meta.get("rule_idents") for s in group]
        if any(m is None for m in maps):
            return
        idents = rules_known_for_versions(
            {i: m for i, m in enumerate(maps)}, range(len(maps)))
        W = (merged.meta["columns"][ENRICH_COLUMN][1][1]
             if ENRICH_COLUMN in merged.meta["columns"] else 0)
        merged.apply_update(meta_updates={
            "rule_idents": idents,
            "rules_known": pack_known_bitmap(idents, max(W, 1)),
        })
