"""Compactor — merge small sealed segments into right-sized ones.

Long-running ingests (frequent seals, filter-mode pipelines, restarts)
degrade into thousands of tiny segments; per-segment overheads (zone-map
checks, file opens, index lookups) then dominate query latency.  The
compactor merges runs of *adjacent* undersized sealed segments into
right-sized ones, re-deriving every artifact a seal would produce — zone
maps, rule counts, rule postings, text indexes — via the store's own
segment-construction path, so a compacted segment is indistinguishable from
a natively sealed one.

Coverage metadata is the intersection of the inputs' ``rule_idents`` (a rule
is known for the merged segment only if every input knew it with the same
content identity), preserving the consistency invariant: queries return
byte-identical results before, during, and after compaction.

The swap is atomic: the merged segment is fully built (and spilled) first;
input columns are pre-warmed into memory so in-flight queries holding the
old segment list keep working even after the old spill dirs are retired.
"""
from __future__ import annotations

import shutil
import time
from dataclasses import dataclass

import numpy as np

from repro.core.query.store import (Segment, SegmentStore, pack_known_bitmap,
                                    rules_known_for_versions)
from repro.core.records import RecordBatch
from repro.core.stream_processor import ENRICH_COLUMN


@dataclass
class CompactionReport:
    merges: int = 0
    merges_failed: int = 0      # group raised (e.g. corrupt spill file)
    errors: list = None         # (segment ids, error) pairs, capped
    segments_in: int = 0
    segments_out: int = 0
    records: int = 0
    bytes_rewritten: int = 0
    seconds: float = 0.0

    def __post_init__(self):
        if self.errors is None:
            self.errors = []


class Compactor:
    """``min_records``: a sealed segment smaller than this is a merge
    candidate (default: half the store's seal size).  ``target_records``:
    stop growing a merge group at this size (default: the seal size)."""

    def __init__(self, store: SegmentStore, *, min_records: int = None,
                 target_records: int = None):
        self.store = store
        self.min_records = (min_records if min_records is not None
                            else max(1, store.segment_size // 2))
        self.target_records = (target_records if target_records is not None
                               else store.segment_size)
        # failure memory (mirrors BackfillWorker._failed_ids): a permanently
        # failing merge group (e.g. corrupt spill file) must not be fully
        # re-read and re-failed every cycle, nor starve healthy groups
        self._failed_keys: set = set()  # tuple(segment ids) of failed groups

    @staticmethod
    def _schema(seg) -> dict:
        """Mergeable schema: name -> (dtype, per-record shape).  Comparing
        names alone would group e.g. mixed ``text_width`` segments whose
        ``np.concatenate`` then raises every cycle."""
        return {name: (dtype, tuple(shape[1:]))
                for name, (dtype, shape) in seg.meta["columns"].items()}

    def candidate_groups(self) -> list:
        """Runs of >= 2 adjacent undersized segments with identical schemas
        (column names AND dtypes/widths), greedily grown up to
        ``target_records``."""
        groups, run, run_n = [], [], 0
        for seg in list(self.store.segments):
            small = seg.num_records < self.min_records
            fits = run_n + seg.num_records <= self.target_records
            same_schema = (not run
                           or self._schema(seg) == self._schema(run[0]))
            if small and fits and same_schema:
                run.append(seg)
                run_n += seg.num_records
            else:
                if len(run) >= 2:
                    groups.append(run)
                run, run_n = ([seg], seg.num_records) if small else ([], 0)
        if len(run) >= 2:
            groups.append(run)
        return groups

    def run_cycle(self, *, max_merges: int = None,
                  max_bytes: int = None) -> CompactionReport:
        rep = CompactionReport()
        t0 = time.perf_counter()
        used = 0
        groups = self.candidate_groups()
        # previously-failed groups only get budget once every fresh group
        # has been tried (deprioritized, not dropped: a transient failure —
        # a racing maintenance writer, a repaired file — should still heal)
        fresh = [g for g in groups if self._key(g) not in self._failed_keys]
        for group in fresh or groups:
            if max_merges is not None and rep.merges >= max_merges:
                break
            cost = sum(s.nbytes() for s in group)
            if max_bytes is not None and rep.merges and used + cost > max_bytes:
                break
            # per-group isolation: one corrupt spill file must not abort
            # the cycle for the remaining groups (same contract as the
            # BackfillWorker's per-segment isolation)
            try:
                ok = self._merge(group)
            except Exception as e:  # noqa: BLE001
                rep.merges_failed += 1
                self._failed_keys.add(self._key(group))
                if len(rep.errors) < 8:
                    rep.errors.append(
                        ([s.segment_id for s in group], str(e)))
                continue
            self._failed_keys.discard(self._key(group))
            if ok:
                rep.merges += 1
                rep.segments_in += len(group)
                rep.segments_out += 1
                rep.records += sum(s.num_records for s in group)
                rep.bytes_rewritten += cost
                used += cost
        rep.seconds = time.perf_counter() - t0
        return rep

    @staticmethod
    def _key(group: list) -> tuple:
        return tuple(s.segment_id for s in group)

    def _merge(self, group: list) -> bool:
        # pre-warm every input column so readers holding the old segment
        # list stay served after the old spill dirs are retired
        names = sorted(group[0].meta["columns"])
        cols = {}
        for name in names:
            parts = [np.asarray(s.column(name, cache=True)) for s in group]
            if name == ENRICH_COLUMN:
                W = max(p.shape[1] for p in parts)
                parts = [np.pad(p, ((0, 0), (0, W - p.shape[1])))
                         for p in parts]
            cols[name] = np.concatenate(parts)
        merged = self.store.make_segment_from_batch(RecordBatch(cols))
        try:
            self._fix_coverage(merged, group)
            swapped = self.store.replace_segments(group, merged)
        except Exception:
            # never leave an orphaned merged spill dir behind: load() would
            # pick it up ALONGSIDE the un-retired inputs and double-count
            if merged.path is not None:
                shutil.rmtree(merged.path, ignore_errors=True)
            raise
        if not swapped:
            # raced with another maintenance action — discard our artifact
            if merged.path is not None:
                shutil.rmtree(merged.path, ignore_errors=True)
            return False
        return True

    def _fix_coverage(self, merged: Segment, group: list) -> None:
        """Merged ``rules_known`` = intersection of the inputs' rule-ident
        maps.  This keeps *backfilled* coverage (which can exceed what the
        version registry implies) instead of re-deriving from versions."""
        maps = [s.meta.get("rule_idents") for s in group]
        if any(m is None for m in maps):
            return
        idents = rules_known_for_versions(
            {i: m for i, m in enumerate(maps)}, range(len(maps)))
        W = (merged.meta["columns"][ENRICH_COLUMN][1][1]
             if ENRICH_COLUMN in merged.meta["columns"] else 0)
        merged.apply_update(meta_updates={
            "rule_idents": idents,
            "rules_known": pack_known_bitmap(idents, max(W, 1)),
        })
