"""BackfillWorker — retroactive re-enrichment of sealed segments.

FluxSieve's consistency rule (paper §3.4 step 4) makes enrichment safe but
pessimistic: a segment sealed before a rule activated serves that rule via
full scan forever.  The backfill worker closes the gap off the ingest path
(Shared Arrangements' shared index maintenance / Fluid ETL's incremental
backfill, applied to the enrichment column):

  1. it consumes engine-update notifications on its OWN control-bus topic
     (``SEGMENT_MAINTENANCE``) with its own consumer-group offsets, fetching
     and validating the compiled artifact exactly like a stream processor;
  2. per sealed segment it diffs the activated ruleset against the segment's
     ``rule_idents`` (rule *content* identities, so changed patterns are
     re-matched, not trusted) and matches only the **delta** rules against
     the segment's text columns, reusing the compiled-matcher stack;
  3. it atomically rewrites the segment's ``rule_bitmap`` column plus every
     derived artifact — ``rule_bitmap_any`` zone map, ``rule_counts``, rule
     postings, ``rules_known`` — via ``Segment.apply_update``, so concurrent
     queries see either the fully-old or fully-new enrichment;
  4. once no sealed segment in ITS SHARD lags the active version it
     publishes an ack on ``MAINTENANCE_ACKS`` (the updater's
     ``await_maintenance`` watches it, one ack per worker id).

Maintenance plane v2 — distribution and durability:

  * **Sharding**: a worker owns the segments ``shard_of(segment_id,
    num_shards) == shard_index``; a ``MaintenanceWorkerPool`` runs N such
    workers over one store, each with its own consumer-group offsets
    (at-least-once delivery per worker, so a crashed worker's replacement
    re-reads the topic from its own committed offset);
  * **Leases + epoch fencing** (``maintenance.lease``): every install is
    guarded by a per-segment lease whose epoch is the fencing token carried
    into ``Segment.apply_update(fence=...)`` — two workers can never
    interleave writes on one segment, and a crashed worker's lease expires
    instead of wedging its shard;
  * **Incremental checkpointing**: long segments are matched in row-range
    passes (``rows_per_pass``); each partial pass persists a per-segment
    high-water mark + the partially rebuilt bitmap (atomically, next to the
    spill files), so a worker restart or a mid-segment budget cut resumes
    matching from the watermark instead of row 0.  The checkpoint is keyed
    on the target (version + delta), so a moved target invalidates it.

Invariant: a query result is byte-identical whether a segment is served via
backfilled bitmap, postings, metadata counts, or full-scan fallback — and
the install itself stays all-or-nothing (checkpoints stage work *outside*
the segment's visible artifacts; only the final ``apply_update`` swaps).
"""
from __future__ import annotations

import os
import time
import zipfile
from dataclasses import dataclass, field

import numpy as np

from repro.core import faults, telemetry
from repro.core.automaton import words_for_rules
from repro.core.control_plane import (ControlBus, MAINTENANCE_ACKS,
                                      SEGMENT_MAINTENANCE)
from repro.core.enrichment import rule_mask
from repro.core.maintenance.lease import (FencedWriteError, LeaseManager,
                                          shard_of)
from repro.core.matcher import EngineBundle, build_matchers, compile_bundle
from repro.core.object_store import ObjectRef, ObjectStore
from repro.core.patterns import RuleSet, ruleset_idents
from repro.core.query.store import (SegmentStore, derive_enrichment_meta,
                                    pack_known_bitmap)
from repro.core.stream_processor import ENRICH_COLUMN

# per-segment backfill checkpoint, stored NEXT TO the spill files (swapped
# atomically via tmp+os.replace); never part of the segment's visible state
CKPT_NAME = "backfill.ckpt.npz"

_BF_SEGMENTS = telemetry.counter(
    "fluxsieve_maintenance_segments_backfilled_total",
    help="Segments fully re-enriched by the backfill plane.")
_BF_ROWS = telemetry.counter(
    "fluxsieve_maintenance_rows_matched_total",
    help="Rows re-matched by backfill passes.")
_BF_ROWS_RESUMED = telemetry.counter(
    "fluxsieve_maintenance_rows_resumed_total",
    help="Rows skipped thanks to a backfill checkpoint resume.")
_BF_BYTES = telemetry.counter(
    "fluxsieve_maintenance_bytes_rewritten_total",
    help="Enrichment bytes rewritten by backfill installs.")
_BF_CHECKPOINTS = telemetry.counter(
    "fluxsieve_maintenance_checkpoints_total",
    help="Partial backfill passes persisted as checkpoints.")


@dataclass(frozen=True)
class _Target:
    """Latest activated ruleset the store should converge to."""
    version: str
    ruleset: RuleSet
    idents: dict            # str(rule_id) -> content identity


@dataclass
class BackfillReport:
    version: str = ""
    messages: int = 0
    segments_backfilled: int = 0
    segments_skipped: int = 0   # sealed w/o enrichment column (gauge): can
                                # never converge, served by scan paths only
    segments_failed: int = 0    # raised during backfill; retried next cycle
    segments_partial: int = 0   # row-budget cut mid-segment; checkpointed
    segments_contended: int = 0  # lease held (or fenced) by another worker
    errors: list = field(default_factory=list)   # (segment_id, error) pairs
    records: int = 0
    rows_matched: int = 0       # rows actually re-matched this cycle (a
                                # checkpoint resume makes this < records)
    rows_resumed: int = 0       # rows skipped thanks to a checkpoint
    bytes_rewritten: int = 0
    seconds: float = 0.0
    pending_after: int = 0      # pending in THIS worker's shard
    acked: bool = False


def merge_reports(total: BackfillReport, rep: BackfillReport,
                  *, sequential: bool = True) -> BackfillReport:
    """Accumulate ``rep`` into ``total``.  ``sequential`` merges cycles of
    ONE worker over time (gauges take the latest value); the pool merges
    same-cycle reports of MANY workers (gauges sum across shards)."""
    total.version = rep.version or total.version
    total.messages += rep.messages
    total.segments_backfilled += rep.segments_backfilled
    total.segments_failed += rep.segments_failed
    total.segments_partial += rep.segments_partial
    total.segments_contended += rep.segments_contended
    total.errors.extend(rep.errors[:max(0, 8 - len(total.errors))])
    total.records += rep.records
    total.rows_matched += rep.rows_matched
    total.rows_resumed += rep.rows_resumed
    total.bytes_rewritten += rep.bytes_rewritten
    total.seconds += rep.seconds
    if sequential:
        total.segments_skipped = rep.segments_skipped
        total.pending_after = rep.pending_after
        total.acked = total.acked or rep.acked
    else:
        total.segments_skipped = max(total.segments_skipped,
                                     rep.segments_skipped)
        total.pending_after += rep.pending_after
    return total


class BackfillWorker:
    """One maintenance-plane worker (``run_cycle`` is its poll loop body).

    ``shard_index``/``num_shards`` restrict the worker to its hash shard of
    the segment space (``lease.shard_of``); ``leases`` guards every install
    with a fenced per-segment lease; ``rows_per_pass`` bounds how many rows
    one cycle matches per segment (the rest is checkpointed and resumed).
    ``matcher_cache`` lets a ``MaintenanceWorkerPool`` share compiled delta
    matchers across workers (compiled engines are immutable/thread-safe)."""

    def __init__(self, store: SegmentStore, bus: ControlBus,
                 object_store: ObjectStore, *, worker_id: str = "maint-0",
                 scheduler=None, backend: str = "dfa_ref",
                 block_n: int = 256, interpret: bool = True,
                 shard_index: int = 0, num_shards: int = 1,
                 leases: LeaseManager = None, rows_per_pass: int = None,
                 matcher_cache: dict = None):
        self.store = store
        self.bus = bus
        self.object_store = object_store
        self.worker_id = worker_id
        self.scheduler = scheduler
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret
        if not 0 <= shard_index < max(num_shards, 1):
            raise ValueError(f"shard_index {shard_index} out of range for "
                             f"{num_shards} shards")
        self.shard_index = shard_index
        self.num_shards = max(num_shards, 1)
        self.leases = leases
        self.rows_per_pass = rows_per_pass
        self._target: _Target = None
        # each installed target owes exactly one convergence ack — keyed on
        # installation, not version string, so rolling BACK to a previously
        # acked version still acks once re-converged
        self._ack_pending = False
        self._nacked: set = set()       # offsets already nacked (no spam)
        self._seen_upto = 0             # poll high-water mark (retries are
                                        # not "new" messages to callers)
        self._failed_ids: set = set()   # segments whose last backfill raised
                                        # (deprioritized, retried when idle)
        # incremental pending tracking (single maintenance writer): a full
        # O(segments x rules) ident rescan happens only on target change;
        # steady-state cycles diff just the newly sealed segments
        self._pending_ids: set = None   # None = needs full rescan
        self._scanned_upto = 0          # segment-id high-water mark
        # (version, delta ids, fields) -> dict; shareable across a THREAD
        # pool (compiled engines are immutable/thread-safe).  NOT shared
        # across processes: a ProcessMaintenancePool worker owns a
        # private cache and warms it once per target version
        # (``warm_matchers``) instead of silently recompiling.
        self._matchers: dict = matcher_cache if matcher_cache is not None \
            else {}
        self._warmed_version = None     # target version last warmed for
        self._mem_ckpts: dict = {}      # sid -> (key, hwm, bm) for segments
                                        # without a spill path

    @property
    def worker_ids(self) -> tuple:
        """Worker identities to await acks from (pool-compatible shape)."""
        return (self.worker_id,)

    def owns(self, segment_id: int) -> bool:
        """Shard ownership: this worker backfills (and acks) only its hash
        shard of the segment space."""
        return shard_of(segment_id, self.num_shards) == self.shard_index

    # -- control topology --------------------------------------------------
    def poll_target(self) -> int:
        """Consume engine-update notifications; keep the newest valid target.

        Each notification supersedes the last — backfill converges to the
        latest ruleset, intermediate versions need no historical pass — so
        the backlog is walked newest-first and only the first message whose
        artifact fetches and validates is deserialized; older (superseded)
        messages are committed without touching the object store.  A fresh
        worker group replaying a long topic history therefore does one
        fetch, not one per historical version.

        At-least-once on every candidate that has not been superseded by a
        successful install: offsets are committed only up to the installed
        message, because a message is superseded only once some NEWER
        message actually installs.  In particular, when the newest
        notification is permanently invalid and an older one failed
        transiently, nothing is committed — the older candidate stays
        fetchable and is retried next cycle instead of being silently
        forfeited (duplicate nacks stay suppressed via ``_nacked``).

        Restart recovery: a worker that installed a target, committed its
        offset, and then CRASHED would otherwise never see that
        notification again — its replacement (same worker id, same group)
        polls past the committed offset and finds nothing.  The committed
        offset gates delivery accounting, not target durability: a worker
        with no target re-derives the newest valid one from the raw topic
        history, and owes a convergence ack for it — so a mid-backfill
        crash still ends in exactly the acks the updater awaits once the
        replacement (resuming from checkpoints) converges."""
        group = f"maintenance/{self.worker_id}"
        recovering = False
        msgs = self.bus.poll(SEGMENT_MAINTENANCE, group,
                             max_messages=1_000_000)
        if not msgs and self._target is None:
            msgs = self.bus.messages(SEGMENT_MAINTENANCE, 0)
            recovering = True
            if msgs:
                telemetry.emit("target_recovered", plane="maintenance",
                               worker=self.worker_id,
                               replayed=len(msgs))
        if not msgs:
            return 0
        installed_offset = None
        for msg in reversed(msgs):
            try:
                ref = ObjectRef.from_dict(msg.value["object_ref"])
                data = self.object_store.get(ref, verify=True)
                bundle = EngineBundle.deserialize(data, verify=True)
                if bundle.version != msg.value["engine_version"]:
                    raise ValueError("version mismatch")
                if bundle.checksum() != msg.value["checksum"]:
                    raise ValueError("bundle checksum != notification checksum")
                ruleset = bundle.ruleset()
                self._target = _Target(version=bundle.version, ruleset=ruleset,
                                       idents=ruleset_idents(ruleset))
                self._evict_matchers(bundle.version)
                self._ack_pending = True
                self._pending_ids = None    # target moved: full rescan
                installed_offset = msg.offset
                break
            except Exception as e:  # noqa: BLE001 — nack, try the next-newest
                if msg.offset not in self._nacked:
                    self._nacked.add(msg.offset)
                    self.bus.publish(MAINTENANCE_ACKS, {
                        "worker": self.worker_id,
                        "engine_version": msg.value.get("engine_version"),
                        "ok": False, "error": str(e),
                        "object_ref": msg.value.get("object_ref"),
                    })
        newest = msgs[-1].offset
        if installed_offset is not None:
            # everything at/below the install is superseded; failed NEWER
            # candidates stay uncommitted and are retried next cycle
            # (idempotent under recovery: commit never rewinds offsets)
            self.bus.commit(SEGMENT_MAINTENANCE, group, installed_offset)
        seen = sum(1 for m in msgs if m.offset >= self._seen_upto)
        self._seen_upto = max(self._seen_upto, newest + 1)
        return 0 if recovering else seen    # replay is not new delivery

    def set_target(self, ruleset: RuleSet) -> None:
        """Direct (bus-less) targeting, for embedded/offline use."""
        self._target = _Target(version=ruleset.version_hash(), ruleset=ruleset,
                               idents=ruleset_idents(ruleset))
        self._evict_matchers(self._target.version)
        self._ack_pending = True
        self._pending_ids = None

    def _evict_matchers(self, current_version: str) -> None:
        """Bound the compiled-matcher cache on target change WITHOUT
        wiping it: keys are version-scoped, so stale-version engines are
        merely unreachable, not wrong.  Evicting eagerly would defeat the
        pool-shared cache (worker B's install must not discard engines
        worker A just compiled for the SAME version) — so stale versions
        are dropped only once the cache actually grows."""
        if len(self._matchers) <= 32:
            return
        for k in [k for k in list(self._matchers)
                  if k[0] != current_version]:
            self._matchers.pop(k, None)

    # -- delta computation -------------------------------------------------
    def segment_delta(self, seg) -> tuple:
        """-> (delta_ids, removed_ids): rules to (re-)match vs rules whose
        bits/idents must be cleared.  Empty + empty == segment converged."""
        t = self._target
        seg_idents = seg.meta.get("rule_idents") or {}
        delta = [int(rid) for rid, ident in t.idents.items()
                 if seg_idents.get(rid) != ident]
        removed = [int(rid) for rid in seg_idents if rid not in t.idents]
        return sorted(delta), sorted(removed)

    def pending_segments(self) -> list:
        """Sealed, enrichment-bearing segments OF THIS WORKER'S SHARD not
        yet at the target (exact, full rescan)."""
        if self._target is None:
            return []
        return [seg for seg in list(self.store.segments)
                if self._segment_pending(seg)]

    def _segment_pending(self, seg) -> bool:
        if not self.owns(seg.segment_id):
            return False    # another shard's worker converges (and acks) it
        if ENRICH_COLUMN not in seg.meta["columns"]:
            return False
        delta, removed = self.segment_delta(seg)
        return bool(delta or removed)

    def _refresh_pending(self) -> list:
        """Incrementally maintained pending list: exact under the single
        maintenance-writer assumption, O(new segments) per steady-state
        cycle instead of O(all segments)."""
        segs = list(self.store.segments)
        ids = {s.segment_id for s in segs}
        if self._pending_ids is None:
            self._pending_ids = {s.segment_id for s in segs
                                 if self._segment_pending(s)}
        else:
            for s in segs:
                if (s.segment_id >= self._scanned_upto
                        and self._segment_pending(s)):
                    self._pending_ids.add(s.segment_id)
            self._pending_ids &= ids       # compacted-away segments
        self._scanned_upto = max((i + 1 for i in ids), default=0)
        return [s for s in segs if s.segment_id in self._pending_ids]

    # -- data plane --------------------------------------------------------
    def run_cycle(self, *, max_segments: int = None) -> BackfillReport:
        """One maintenance cycle: poll control topic, backfill up to the
        scheduler budget (hottest segments first), ack when converged."""
        with telemetry.span("maintenance/backfill_cycle", cat="maintenance",
                            worker=self.worker_id):
            rep = self._run_cycle(max_segments=max_segments)
        _BF_SEGMENTS.inc(rep.segments_backfilled)
        _BF_ROWS.inc(rep.rows_matched)
        _BF_ROWS_RESUMED.inc(rep.rows_resumed)
        _BF_BYTES.inc(rep.bytes_rewritten)
        return rep

    def _run_cycle(self, *, max_segments: int = None) -> BackfillReport:
        rep = BackfillReport()
        t0 = time.perf_counter()
        rep.messages = self.poll_target()
        if self._target is None:
            rep.seconds = time.perf_counter() - t0
            return rep
        rep.version = self._target.version
        candidates = self._refresh_pending()
        if self._warmed_version != self._target.version:
            # warm the compiled-matcher cache ONCE per installed target:
            # every (delta, fields) engine this worker's shard will need is
            # compiled up front, so per-cycle passes only ever hit the
            # cache.  In the process model each worker owns its cache, so
            # without an explicit warm the compile cost would repeat
            # per-segment-shape per worker silently inside the timed pass.
            self.warm_matchers(candidates)
        # a permanently failing segment must not starve healthy ones under a
        # tight budget: previously-failed segments only get budget once
        # everything else has converged
        fresh = [s for s in candidates
                 if s.segment_id not in self._failed_ids]
        todo = fresh or candidates
        if self.scheduler is not None:
            todo = self.scheduler.plan_cycle(todo)
        if max_segments is not None:
            todo = todo[:max_segments]
        healed = []
        for seg in todo:
            # lease the segment before touching it: sharding makes overlap
            # unlikely, the lease makes it impossible — and the fencing
            # token below makes even a lease we LOST mid-write harmless
            lease = None
            if self.leases is not None:
                lease = self.leases.acquire(seg.segment_id, self.worker_id)
                if lease is None:
                    rep.segments_contended += 1
                    continue        # held elsewhere; stays pending, retried
            fence = self.leases.fence(lease) if lease is not None else None
            # per-segment isolation: one bad segment (corrupt spill file,
            # truncated column) must not crash the worker or stall the rest.
            # A failed segment stays in the pending set — so no ack happens
            # while it lags — and is retried next cycle; a half-applied
            # phase-1 withdraw is safe (queries fall back to scanning).
            try:
                state = self.backfill_segment(
                    seg, max_rows=self._rows_budget(), fence=fence,
                    report=rep)
            except FencedWriteError:
                # lost the lease race mid-write: the successor owns the
                # segment now; nothing was mutated (the fence fires before
                # the first byte), so just leave it to the new holder
                rep.segments_contended += 1
                continue
            except Exception as e:  # noqa: BLE001
                rep.segments_failed += 1
                self._failed_ids.add(seg.segment_id)
                if len(rep.errors) < 8:
                    rep.errors.append((seg.segment_id, str(e)))
                continue
            finally:
                if lease is not None:
                    self.leases.release(lease)
            if state == "partial":
                rep.segments_partial += 1   # checkpointed; resumes next cycle
            elif state == "done":
                rep.segments_backfilled += 1
                rep.records += seg.num_records
                rep.bytes_rewritten += seg.nbytes([ENRICH_COLUMN])
                self._failed_ids.discard(seg.segment_id)
                self._pending_ids.discard(seg.segment_id)
                healed.append(seg.segment_id)
        if healed and self.scheduler is not None:
            # backfill-aware pruning stats: installed segments no longer
            # serve fallback scans — drop their stale heat so the next
            # cycle prioritizes segments still burning query time
            self.scheduler.notify_backfilled(healed)
        # sealed segments with no enrichment column can never converge —
        # surface them instead of silently treating them as done
        rep.segments_skipped = sum(
            1 for seg in list(self.store.segments)
            if ENRICH_COLUMN not in seg.meta["columns"])
        rep.pending_after = len(self._pending_ids)
        if rep.pending_after == 0 and self._ack_pending:
            self.bus.publish(MAINTENANCE_ACKS, {
                "worker": self.worker_id,
                "engine_version": self._target.version,
                "ok": True,
                "segments": len(self.store.segments),
            })
            self._ack_pending = False
            rep.acked = True
            telemetry.emit("convergence_ack", plane="maintenance",
                           worker=self.worker_id,
                           version=self._target.version)
        rep.seconds = time.perf_counter() - t0
        return rep

    def _rows_budget(self):
        """Per-segment row budget for one pass: the worker's own
        ``rows_per_pass`` or the scheduler policy's
        ``max_rows_per_segment_pass`` (whichever is set; worker wins)."""
        if self.rows_per_pass is not None:
            return self.rows_per_pass
        if self.scheduler is not None:
            return getattr(self.scheduler.policy,
                           "max_rows_per_segment_pass", None)
        return None

    def run_until_converged(self, *, max_cycles: int = 1000) -> BackfillReport:
        """Drain: cycle until no sealed segment in this worker's shard lags
        the target.  Returns the totals across all cycles run."""
        total = BackfillReport()
        for _ in range(max_cycles):
            rep = self.run_cycle()
            merge_reports(total, rep)
            if rep.messages == 0 and (
                    rep.pending_after == 0
                    or (rep.segments_backfilled == 0
                        and rep.segments_partial == 0)):
                # converged — or stuck (every remaining segment failing or
                # contended); don't spin max_cycles on a permanently bad
                # segment.  Partial passes ARE progress: keep cycling.
                break
        return total

    def backfill_segment(self, seg, *, max_rows: int = None, fence=None,
                         report: BackfillReport = None) -> str:
        """Re-enrich one sealed segment to the target ruleset.  Matches only
        the delta rules, then atomically swaps bitmap + zone maps + counts +
        postings + coverage metadata.  Returns ``"skip"`` when the segment
        has no enrichment column to rewrite, ``"partial"`` when ``max_rows``
        cut the pass short (progress checkpointed, resumed next pass), and
        ``"done"`` on install.

        Two-phase when a previously-claimed rule's bits are REINTERPRETED
        (pattern changed or rule removed): first a meta-only update
        withdraws those coverage claims — concurrent readers fall back to
        scanning for them — and only then is the new data installed and
        claimed.  A reader therefore never pairs an old claim with new bits
        (or vice versa); pure additions skip the extra phase because no old
        plan can reference a rule the old metadata never claimed.

        Incremental checkpointing: rows are matched in ``[start, stop)``
        passes; an incomplete pass persists ``(target key, row high-water
        mark, partial bitmap)`` next to the spill files and the next pass —
        by this worker or a restarted replacement — resumes from the
        watermark.  Checkpoints stage work OUTSIDE the segment's visible
        artifacts; readers never observe a partially backfilled bitmap.
        ``fence`` threads the lease's fencing token into every
        ``apply_update`` (withdraw and install)."""
        t = self._target
        if ENRICH_COLUMN not in seg.meta["columns"]:
            return "skip"
        delta_ids, removed_ids = self.segment_delta(seg)
        seg_idents = seg.meta.get("rule_idents") or {}
        reinterpreted = ([r for r in delta_ids if str(r) in seg_idents]
                         + removed_ids)
        if reinterpreted and seg.meta.get("rules_known") is not None:
            drop = {str(r) for r in reinterpreted}
            kept = {rid: ident for rid, ident in seg_idents.items()
                    if rid not in drop}
            seg.apply_update(meta_updates={
                "rule_idents": kept,
                "rules_known": pack_known_bitmap(
                    kept, seg.meta["columns"][ENRICH_COLUMN][1][1]),
            }, fence=fence)
            # the withdraw changed coverage; re-derive the delta so the
            # checkpoint key (and resume) see the post-withdraw world
            seg_idents = seg.meta.get("rule_idents") or {}
            delta_ids, removed_ids = self.segment_delta(seg)
        num_rules = t.ruleset.num_rules
        W = max(words_for_rules(max(num_rules, 1)),
                seg.meta["columns"][ENRICH_COLUMN][1][1])
        N = seg.num_records
        ckpt_key = f"{t.version}:{','.join(map(str, delta_ids))}"
        start, done_bm = self._load_checkpoint(seg, ckpt_key)
        if report is not None and start:
            report.rows_resumed += start
        stop = N if max_rows is None else min(N, start + max(int(max_rows), 1))

        def read_rows(name):
            # cache=False: a maintenance pass streams each column range
            # once — it must not pin the whole spilled dataset in RAM.
            # Whole-segment passes (the common case) read the column
            # directly; partial passes page in just the row range.
            if start == 0 and stop == N:
                return np.asarray(seg.column(name, cache=False))
            return np.asarray(seg.column_rows(
                name, np.arange(start, stop), cache=False))

        old = read_rows(ENRICH_COLUMN)
        part = np.zeros((stop - start, W), np.uint32)
        part[:, :old.shape[1]] = old
        # keep exactly the bits whose rule identity already matches the
        # target; everything else (delta, removed, never-claimed strays) is
        # cleared and — for the delta — recomputed below.  Idempotent
        # across the withdraw above and across checkpoint resumes.
        keep = [int(rid) for rid, ident in t.idents.items()
                if seg_idents.get(rid) == ident and int(rid) < W * 32]
        part &= rule_mask(keep, W * 32) if keep else np.uint32(0)
        if delta_ids:
            delta_rules = tuple(r for r in t.ruleset.rules
                                if r.rule_id in set(delta_ids))
            matchers = self._matchers_for(delta_rules, seg)
            for fieldname, engine in matchers.items():
                if fieldname not in seg.meta["columns"]:
                    continue
                sub = np.asarray(engine.match(read_rows(fieldname)))
                part[:, :sub.shape[1]] |= sub
        if report is not None:
            report.rows_matched += stop - start
        bm = part if done_bm is None else np.concatenate([done_bm, part])
        if stop < N:
            self._save_checkpoint(seg, ckpt_key, stop, bm)
            return "partial"
        enrich_meta, postings = derive_enrichment_meta(bm)
        meta_updates = {
            **enrich_meta,
            "rule_idents": dict(t.idents),
            "rules_known": pack_known_bitmap(t.idents, W),
        }
        seg.apply_update(columns={ENRICH_COLUMN: bm},
                         meta_updates=meta_updates, rule_postings=postings,
                         fence=fence)
        self._clear_checkpoint(seg)
        return "done"

    # -- checkpoint plane --------------------------------------------------
    def _save_checkpoint(self, seg, key: str, hwm: int,
                         bm: np.ndarray) -> None:
        """Persist partial progress atomically (tmp + ``os.replace``), next
        to the spill files.  Memory-only segments checkpoint in the worker
        (survives budget cuts within a process, not a restart — but neither
        does the segment)."""
        _BF_CHECKPOINTS.inc()
        telemetry.emit("backfill_checkpoint", plane="maintenance",
                       segment=seg.segment_id, rows_done=int(hwm))
        if seg.path is None:
            self._mem_ckpts[seg.segment_id] = (key, hwm, bm)
            return
        faults.fire("maintenance.checkpoint", segment=seg.segment_id)
        path = seg.path / CKPT_NAME
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as f:
            np.savez_compressed(f, key=np.asarray([key]),
                                hwm=np.asarray([hwm], np.int64), bm=bm)
        os.replace(tmp, path)

    def _load_checkpoint(self, seg, key: str) -> tuple:
        """-> (resume row, completed-prefix bitmap) — ``(0, None)`` when no
        checkpoint matches the current target key (a moved target, or a
        torn/corrupt file, restarts the segment from row 0)."""
        if seg.path is None:
            mem = self._mem_ckpts.get(seg.segment_id)
            if mem is not None and mem[0] == key:
                return mem[1], mem[2]
            return 0, None
        path = seg.path / CKPT_NAME
        if not path.exists():
            return 0, None
        try:
            with np.load(path, allow_pickle=False) as z:
                if str(z["key"][0]) == key:
                    return int(z["hwm"][0]), np.asarray(z["bm"])
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile) as e:   # torn checkpoint == no checkpoint
            telemetry.suppressed("maintenance.load_checkpoint", e)
        return 0, None

    def _clear_checkpoint(self, seg) -> None:
        self._mem_ckpts.pop(seg.segment_id, None)
        if seg.path is not None:
            try:
                (seg.path / CKPT_NAME).unlink()
            except OSError as e:
                telemetry.suppressed("maintenance.clear_checkpoint", e)

    def warm_matchers(self, candidates: list = None) -> int:
        """Precompile the delta matchers the current target needs over this
        worker's pending segments.  Returns how many engines were compiled
        (0 when the cache was already warm — the idempotent steady state).
        Called automatically once per installed target version at the top
        of the first cycle; safe to call explicitly (a process-pool worker
        warms right after opening the store, before its first timed
        cycle)."""
        if self._target is None:
            return 0
        t = self._target
        if candidates is None:
            candidates = self._refresh_pending()
        compiled = 0
        for seg in candidates:
            delta_ids, _removed = self.segment_delta(seg)
            if not delta_ids:
                continue
            delta_rules = tuple(r for r in t.ruleset.rules
                                if r.rule_id in set(delta_ids))
            if self._matcher_key(delta_rules, seg) not in self._matchers:
                self._matchers_for(delta_rules, seg)
                compiled += 1
        self._warmed_version = t.version
        if compiled:
            telemetry.emit("matcher_cache_warmed", plane="maintenance",
                           worker=self.worker_id, version=t.version,
                           compiled=compiled)
        return compiled

    def _matcher_key(self, delta_rules: tuple, seg) -> tuple:
        fields = tuple(sorted(
            name for name, (dtype, shape) in seg.meta["columns"].items()
            if dtype == "uint8" and len(shape) == 2))
        return (self._target.version,
                tuple(r.rule_id for r in delta_rules), fields)

    def _matchers_for(self, delta_rules: tuple, seg) -> dict:
        """Compile (and cache) matchers for a delta sub-ruleset, keeping the
        ORIGINAL rule ids so emitted bitmaps OR straight into the segment's
        bitmap words."""
        key = self._matcher_key(delta_rules, seg)
        if key not in self._matchers:
            bundle = compile_bundle(RuleSet(delta_rules),
                                    key[2])     # the matchable fields
            self._matchers[key] = build_matchers(
                bundle, backend=self.backend, block_n=self.block_n,
                interpret=self.interpret)
        return self._matchers[key]
