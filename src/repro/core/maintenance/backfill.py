"""BackfillWorker — retroactive re-enrichment of sealed segments.

FluxSieve's consistency rule (paper §3.4 step 4) makes enrichment safe but
pessimistic: a segment sealed before a rule activated serves that rule via
full scan forever.  The backfill worker closes the gap off the ingest path
(Shared Arrangements' shared index maintenance / Fluid ETL's incremental
backfill, applied to the enrichment column):

  1. it consumes engine-update notifications on its OWN control-bus topic
     (``SEGMENT_MAINTENANCE``) with its own consumer-group offsets, fetching
     and validating the compiled artifact exactly like a stream processor;
  2. per sealed segment it diffs the activated ruleset against the segment's
     ``rule_idents`` (rule *content* identities, so changed patterns are
     re-matched, not trusted) and matches only the **delta** rules against
     the segment's text columns, reusing the compiled-matcher stack;
  3. it atomically rewrites the segment's ``rule_bitmap`` column plus every
     derived artifact — ``rule_bitmap_any`` zone map, ``rule_counts``, rule
     postings, ``rules_known`` — via ``Segment.apply_update``, so concurrent
     queries see either the fully-old or fully-new enrichment;
  4. once no sealed segment lags the active version it publishes an ack on
     ``MAINTENANCE_ACKS`` (the updater's ``await_maintenance`` watches it).

Invariant: a query result is byte-identical whether a segment is served via
backfilled bitmap, postings, metadata counts, or full-scan fallback.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.automaton import words_for_rules
from repro.core.control_plane import (ControlBus, MAINTENANCE_ACKS,
                                      SEGMENT_MAINTENANCE)
from repro.core.enrichment import rule_mask
from repro.core.matcher import EngineBundle, build_matchers, compile_bundle
from repro.core.object_store import ObjectRef, ObjectStore
from repro.core.patterns import RuleSet, ruleset_idents
from repro.core.query.store import (SegmentStore, derive_enrichment_meta,
                                    pack_known_bitmap)
from repro.core.stream_processor import ENRICH_COLUMN


@dataclass(frozen=True)
class _Target:
    """Latest activated ruleset the store should converge to."""
    version: str
    ruleset: RuleSet
    idents: dict            # str(rule_id) -> content identity


@dataclass
class BackfillReport:
    version: str = ""
    messages: int = 0
    segments_backfilled: int = 0
    segments_skipped: int = 0   # sealed w/o enrichment column (gauge): can
                                # never converge, served by scan paths only
    segments_failed: int = 0    # raised during backfill; retried next cycle
    errors: list = field(default_factory=list)   # (segment_id, error) pairs
    records: int = 0
    bytes_rewritten: int = 0
    seconds: float = 0.0
    pending_after: int = 0
    acked: bool = False


class BackfillWorker:
    """One maintenance-plane worker (``run_cycle`` is its poll loop body)."""

    def __init__(self, store: SegmentStore, bus: ControlBus,
                 object_store: ObjectStore, *, worker_id: str = "maint-0",
                 scheduler=None, backend: str = "dfa_ref",
                 block_n: int = 256, interpret: bool = True):
        self.store = store
        self.bus = bus
        self.object_store = object_store
        self.worker_id = worker_id
        self.scheduler = scheduler
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret
        self._target: _Target = None
        # each installed target owes exactly one convergence ack — keyed on
        # installation, not version string, so rolling BACK to a previously
        # acked version still acks once re-converged
        self._ack_pending = False
        self._nacked: set = set()       # offsets already nacked (no spam)
        self._seen_upto = 0             # poll high-water mark (retries are
                                        # not "new" messages to callers)
        self._failed_ids: set = set()   # segments whose last backfill raised
                                        # (deprioritized, retried when idle)
        # incremental pending tracking (single maintenance writer): a full
        # O(segments x rules) ident rescan happens only on target change;
        # steady-state cycles diff just the newly sealed segments
        self._pending_ids: set = None   # None = needs full rescan
        self._scanned_upto = 0          # segment-id high-water mark
        self._matchers: dict = {}       # (version, delta ids, fields) -> dict

    # -- control topology --------------------------------------------------
    def poll_target(self) -> int:
        """Consume engine-update notifications; keep the newest valid target.

        Each notification supersedes the last — backfill converges to the
        latest ruleset, intermediate versions need no historical pass — so
        the backlog is walked newest-first and only the first message whose
        artifact fetches and validates is deserialized; older (superseded)
        messages are committed without touching the object store.  A fresh
        worker group replaying a long topic history therefore does one
        fetch, not one per historical version.

        At-least-once on every candidate that has not been superseded by a
        successful install: offsets are committed only up to the installed
        message, because a message is superseded only once some NEWER
        message actually installs.  In particular, when the newest
        notification is permanently invalid and an older one failed
        transiently, nothing is committed — the older candidate stays
        fetchable and is retried next cycle instead of being silently
        forfeited (duplicate nacks stay suppressed via ``_nacked``)."""
        group = f"maintenance/{self.worker_id}"
        msgs = self.bus.poll(SEGMENT_MAINTENANCE, group,
                             max_messages=1_000_000)
        if not msgs:
            return 0
        installed_offset = None
        for msg in reversed(msgs):
            try:
                ref = ObjectRef.from_dict(msg.value["object_ref"])
                data = self.object_store.get(ref, verify=True)
                bundle = EngineBundle.deserialize(data, verify=True)
                if bundle.version != msg.value["engine_version"]:
                    raise ValueError("version mismatch")
                if bundle.checksum() != msg.value["checksum"]:
                    raise ValueError("bundle checksum != notification checksum")
                ruleset = bundle.ruleset()
                self._target = _Target(version=bundle.version, ruleset=ruleset,
                                       idents=ruleset_idents(ruleset))
                self._matchers.clear()
                self._ack_pending = True
                self._pending_ids = None    # target moved: full rescan
                installed_offset = msg.offset
                break
            except Exception as e:  # noqa: BLE001 — nack, try the next-newest
                if msg.offset not in self._nacked:
                    self._nacked.add(msg.offset)
                    self.bus.publish(MAINTENANCE_ACKS, {
                        "worker": self.worker_id,
                        "engine_version": msg.value.get("engine_version"),
                        "ok": False, "error": str(e),
                        "object_ref": msg.value.get("object_ref"),
                    })
        newest = msgs[-1].offset
        if installed_offset is not None:
            # everything at/below the install is superseded; failed NEWER
            # candidates stay uncommitted and are retried next cycle
            self.bus.commit(SEGMENT_MAINTENANCE, group, installed_offset)
        seen = sum(1 for m in msgs if m.offset >= self._seen_upto)
        self._seen_upto = newest + 1
        return seen

    def set_target(self, ruleset: RuleSet) -> None:
        """Direct (bus-less) targeting, for embedded/offline use."""
        self._target = _Target(version=ruleset.version_hash(), ruleset=ruleset,
                               idents=ruleset_idents(ruleset))
        self._matchers.clear()
        self._ack_pending = True
        self._pending_ids = None

    # -- delta computation -------------------------------------------------
    def segment_delta(self, seg) -> tuple:
        """-> (delta_ids, removed_ids): rules to (re-)match vs rules whose
        bits/idents must be cleared.  Empty + empty == segment converged."""
        t = self._target
        seg_idents = seg.meta.get("rule_idents") or {}
        delta = [int(rid) for rid, ident in t.idents.items()
                 if seg_idents.get(rid) != ident]
        removed = [int(rid) for rid in seg_idents if rid not in t.idents]
        return sorted(delta), sorted(removed)

    def pending_segments(self) -> list:
        """Sealed, enrichment-bearing segments not yet at the target
        (exact, full rescan)."""
        if self._target is None:
            return []
        return [seg for seg in list(self.store.segments)
                if self._segment_pending(seg)]

    def _segment_pending(self, seg) -> bool:
        if ENRICH_COLUMN not in seg.meta["columns"]:
            return False
        delta, removed = self.segment_delta(seg)
        return bool(delta or removed)

    def _refresh_pending(self) -> list:
        """Incrementally maintained pending list: exact under the single
        maintenance-writer assumption, O(new segments) per steady-state
        cycle instead of O(all segments)."""
        segs = list(self.store.segments)
        ids = {s.segment_id for s in segs}
        if self._pending_ids is None:
            self._pending_ids = {s.segment_id for s in segs
                                 if self._segment_pending(s)}
        else:
            for s in segs:
                if (s.segment_id >= self._scanned_upto
                        and self._segment_pending(s)):
                    self._pending_ids.add(s.segment_id)
            self._pending_ids &= ids       # compacted-away segments
        self._scanned_upto = max((i + 1 for i in ids), default=0)
        return [s for s in segs if s.segment_id in self._pending_ids]

    # -- data plane --------------------------------------------------------
    def run_cycle(self, *, max_segments: int = None) -> BackfillReport:
        """One maintenance cycle: poll control topic, backfill up to the
        scheduler budget (hottest segments first), ack when converged."""
        rep = BackfillReport()
        t0 = time.perf_counter()
        rep.messages = self.poll_target()
        if self._target is None:
            rep.seconds = time.perf_counter() - t0
            return rep
        rep.version = self._target.version
        candidates = self._refresh_pending()
        # a permanently failing segment must not starve healthy ones under a
        # tight budget: previously-failed segments only get budget once
        # everything else has converged
        fresh = [s for s in candidates
                 if s.segment_id not in self._failed_ids]
        todo = fresh or candidates
        if self.scheduler is not None:
            todo = self.scheduler.plan_cycle(todo)
        if max_segments is not None:
            todo = todo[:max_segments]
        healed = []
        for seg in todo:
            # per-segment isolation: one bad segment (corrupt spill file,
            # truncated column) must not crash the worker or stall the rest.
            # A failed segment stays in the pending set — so no ack happens
            # while it lags — and is retried next cycle; a half-applied
            # phase-1 withdraw is safe (queries fall back to scanning).
            try:
                done = self.backfill_segment(seg)
            except Exception as e:  # noqa: BLE001
                rep.segments_failed += 1
                self._failed_ids.add(seg.segment_id)
                if len(rep.errors) < 8:
                    rep.errors.append((seg.segment_id, str(e)))
                continue
            if done:
                rep.segments_backfilled += 1
                rep.records += seg.num_records
                rep.bytes_rewritten += seg.nbytes([ENRICH_COLUMN])
                self._failed_ids.discard(seg.segment_id)
                self._pending_ids.discard(seg.segment_id)
                healed.append(seg.segment_id)
        if healed and self.scheduler is not None:
            # backfill-aware pruning stats: installed segments no longer
            # serve fallback scans — drop their stale heat so the next
            # cycle prioritizes segments still burning query time
            self.scheduler.notify_backfilled(healed)
        # sealed segments with no enrichment column can never converge —
        # surface them instead of silently treating them as done
        rep.segments_skipped = sum(
            1 for seg in list(self.store.segments)
            if ENRICH_COLUMN not in seg.meta["columns"])
        rep.pending_after = len(self._pending_ids)
        if rep.pending_after == 0 and self._ack_pending:
            self.bus.publish(MAINTENANCE_ACKS, {
                "worker": self.worker_id,
                "engine_version": self._target.version,
                "ok": True,
                "segments": len(self.store.segments),
            })
            self._ack_pending = False
            rep.acked = True
        rep.seconds = time.perf_counter() - t0
        return rep

    def run_until_converged(self, *, max_cycles: int = 1000) -> BackfillReport:
        """Drain: cycle until no sealed segment lags the target.  Returns
        the totals across all cycles run."""
        total = BackfillReport()
        for _ in range(max_cycles):
            rep = self.run_cycle()
            total.version = rep.version
            total.messages += rep.messages
            total.segments_backfilled += rep.segments_backfilled
            total.segments_skipped = rep.segments_skipped
            total.segments_failed += rep.segments_failed
            total.errors.extend(rep.errors[:8 - len(total.errors)])
            total.records += rep.records
            total.bytes_rewritten += rep.bytes_rewritten
            total.seconds += rep.seconds
            total.pending_after = rep.pending_after
            total.acked = total.acked or rep.acked
            if rep.messages == 0 and (rep.pending_after == 0
                                      or rep.segments_backfilled == 0):
                # converged — or stuck (every remaining segment failing);
                # don't spin max_cycles on a permanently bad segment
                break
        return total

    def backfill_segment(self, seg) -> bool:
        """Re-enrich one sealed segment to the target ruleset.  Matches only
        the delta rules, then atomically swaps bitmap + zone maps + counts +
        postings + coverage metadata.  Returns False when the segment has no
        enrichment column to rewrite.

        Two-phase when a previously-claimed rule's bits are REINTERPRETED
        (pattern changed or rule removed): first a meta-only update
        withdraws those coverage claims — concurrent readers fall back to
        scanning for them — and only then is the new data installed and
        claimed.  A reader therefore never pairs an old claim with new bits
        (or vice versa); pure additions skip the extra phase because no old
        plan can reference a rule the old metadata never claimed."""
        t = self._target
        if ENRICH_COLUMN not in seg.meta["columns"]:
            return False
        delta_ids, removed_ids = self.segment_delta(seg)
        seg_idents = seg.meta.get("rule_idents") or {}
        reinterpreted = ([r for r in delta_ids if str(r) in seg_idents]
                         + removed_ids)
        if reinterpreted and seg.meta.get("rules_known") is not None:
            drop = {str(r) for r in reinterpreted}
            kept = {rid: ident for rid, ident in seg_idents.items()
                    if rid not in drop}
            seg.apply_update(meta_updates={
                "rule_idents": kept,
                "rules_known": pack_known_bitmap(
                    kept, seg.meta["columns"][ENRICH_COLUMN][1][1]),
            })
        num_rules = t.ruleset.num_rules
        W = max(words_for_rules(max(num_rules, 1)),
                seg.meta["columns"][ENRICH_COLUMN][1][1])
        # cache=False: a maintenance pass streams each column once — it must
        # not pin the whole spilled dataset in RAM
        old = np.asarray(seg.column(ENRICH_COLUMN, cache=False))
        bm = np.zeros((seg.num_records, W), np.uint32)
        bm[:, :old.shape[1]] = old
        # clear every bit we are about to recompute or retire
        stale = [r for r in delta_ids + removed_ids if r < W * 32]
        if stale:
            bm &= ~rule_mask(stale, W * 32)
        if delta_ids:
            delta_rules = tuple(r for r in t.ruleset.rules
                                if r.rule_id in set(delta_ids))
            matchers = self._matchers_for(delta_rules, seg)
            for fieldname, engine in matchers.items():
                if fieldname not in seg.meta["columns"]:
                    continue
                sub = np.asarray(engine.match(
                    seg.column(fieldname, cache=False)))
                bm[:, :sub.shape[1]] |= sub
        enrich_meta, postings = derive_enrichment_meta(bm)
        meta_updates = {
            **enrich_meta,
            "rule_idents": dict(t.idents),
            "rules_known": pack_known_bitmap(t.idents, W),
        }
        seg.apply_update(columns={ENRICH_COLUMN: bm},
                         meta_updates=meta_updates, rule_postings=postings)
        return True

    def _matchers_for(self, delta_rules: tuple, seg) -> dict:
        """Compile (and cache) matchers for a delta sub-ruleset, keeping the
        ORIGINAL rule ids so emitted bitmaps OR straight into the segment's
        bitmap words."""
        fields = tuple(sorted(
            name for name, (dtype, shape) in seg.meta["columns"].items()
            if dtype == "uint8" and len(shape) == 2))
        key = (self._target.version,
               tuple(r.rule_id for r in delta_rules), fields)
        if key not in self._matchers:
            bundle = compile_bundle(RuleSet(delta_rules), fields)
            self._matchers[key] = build_matchers(
                bundle, backend=self.backend, block_n=self.block_n,
                interpret=self.interpret)
        return self._matchers[key]
