"""MaintenanceWorkerPool — N leased, sharded backfill workers over one store.

The distributed maintenance plane: ``SEGMENT_MAINTENANCE`` consumption is
sharded by segment-id hash (``lease.shard_of``) across ``num_workers``
:class:`~repro.core.maintenance.backfill.BackfillWorker` instances.  Each
worker keeps its OWN consumer-group offsets on the control bus (the
consumer-group plumbing the bus already provides), so delivery stays
at-least-once *per worker*: a crashed worker's replacement re-reads from
its own committed offset and cannot lose a target, and no worker's
progress gates another's.

Exclusion is layered, not assumed:

  * the shard map is the fast path — disjoint shards never contend;
  * a shared :class:`~repro.core.maintenance.lease.LeaseManager` is the
    correctness path — every install runs under a per-segment lease whose
    epoch is the fencing token ``Segment.apply_update`` checks, so even a
    misconfigured (overlapping) pool or a resurrected zombie worker cannot
    interleave writes.  A crashed worker's lease expires; its segments
    become acquirable instead of wedging the shard.

Convergence acks are per worker (one ``MAINTENANCE_ACKS`` message per
worker id once ITS shard is drained); the updater awaits the full
``pool.worker_ids`` set, so "maintenance rollout complete" still means
every sealed segment in the store is at the target.

``run_cycle`` fans the workers out on threads.  The heavy per-segment work
— DFA matching through the jitted XLA backends, numpy bitmap derivation —
releases the GIL, so co-located workers overlap on cores; in a real
deployment each worker is its own process/host and only the bus, store,
and lease manager are shared infrastructure.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor

from repro.core import telemetry
from repro.core.maintenance.backfill import (BackfillReport, BackfillWorker,
                                             merge_reports)
from repro.core.maintenance.lease import LeaseManager


class MaintenanceWorkerPool:
    """N sharded, leased backfill workers sharing one store/bus/object
    store.  Mirrors the single worker's ``run_cycle`` /
    ``run_until_converged`` / ``set_target`` surface so callers (and the
    test matrix's ``FLUXSIEVE_MAINT_WORKERS`` leg) swap it in unchanged;
    reports merge across workers (counters sum, ``pending_after`` is the
    store-wide pending count).

    One ``matcher_cache`` is shared by all workers: compiled delta matchers
    are immutable once built, so N workers pay one compile per
    (version, delta, fields) instead of N.  This sharing is a THREAD-model
    property only — the cache holds jitted engines that cannot cross a
    process boundary, so ``ProcessMaintenancePool`` gives each worker
    process a private cache and warms it once per target version
    (``BackfillWorker.warm_matchers``) instead."""

    def __init__(self, store, bus, object_store, *, num_workers: int = 2,
                 scheduler=None, leases: LeaseManager = None,
                 backend: str = "dfa_ref", block_n: int = 256,
                 interpret: bool = True, rows_per_pass: int = None,
                 worker_prefix: str = "maint", lease_ttl: float = 30.0,
                 matcher_cache: dict = None):
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        self.store = store
        self.leases = leases if leases is not None else LeaseManager(
            ttl=lease_ttl, manifest=getattr(store, "manifest", None))
        self._matcher_cache: dict = (matcher_cache if matcher_cache
                                     is not None else {})
        self.workers = [
            BackfillWorker(store, bus, object_store,
                           worker_id=f"{worker_prefix}-{i}",
                           scheduler=scheduler, backend=backend,
                           block_n=block_n, interpret=interpret,
                           shard_index=i, num_shards=num_workers,
                           leases=self.leases, rows_per_pass=rows_per_pass,
                           matcher_cache=self._matcher_cache)
            for i in range(num_workers)]
        # one persistent executor for the pool's lifetime: convergence
        # under tight row budgets runs MANY cycles, and paying thread
        # spawn/join per cycle is overhead on the path this class speeds
        # up (same discipline as ShardedQueryExecutor's shard pool)
        self._pool = (ThreadPoolExecutor(num_workers,
                                         thread_name_prefix=worker_prefix)
                      if num_workers > 1 else None)

    def close(self) -> None:
        """Shut the cycle executor down (idle threads exit); called at
        finalization too, so churning pools does not accumulate
        process-lifetime threads."""
        if self._pool is not None:
            self._pool.shutdown(wait=False)

    def __del__(self):
        self.close()

    @property
    def worker_ids(self) -> tuple:
        """Identities acking on ``MAINTENANCE_ACKS`` — pass to
        ``MatcherUpdater.await_maintenance``."""
        return tuple(w.worker_id for w in self.workers)

    def set_target(self, ruleset) -> None:
        """Direct (bus-less) targeting of every worker."""
        for w in self.workers:
            w.set_target(ruleset)

    def pending_segments(self) -> list:
        """Union of every shard's pending set (store-wide lag)."""
        out = []
        for w in self.workers:
            out.extend(w.pending_segments())
        return out

    def run_cycle(self, *, max_segments: int = None) -> BackfillReport:
        """One pool cycle: every worker polls its offsets and backfills its
        shard, concurrently.  ``max_segments`` bounds each WORKER's pass
        (the per-cycle budget knob stays per-worker, like the scheduler's)."""
        if len(self.workers) == 1:
            rep = self.workers[0].run_cycle(max_segments=max_segments)
            rep.acked = self._all_acked()
            return rep
        with telemetry.span("maintenance/pool_cycle", cat="maintenance",
                            workers=len(self.workers)):
            reps = list(self._pool.map(
                lambda w: w.run_cycle(max_segments=max_segments),
                self.workers))
        total = BackfillReport()
        for rep in reps:
            merge_reports(total, rep, sequential=False)
        total.acked = self._all_acked()
        return total

    def run_until_converged(self, *, max_cycles: int = 1000) -> BackfillReport:
        """Cycle the pool until every shard converged (or no shard can make
        progress).  Totals merge across cycles."""
        total = BackfillReport()
        for _ in range(max_cycles):
            rep = self.run_cycle()
            merge_reports(total, rep)
            if rep.messages == 0 and (
                    rep.pending_after == 0
                    or (rep.segments_backfilled == 0
                        and rep.segments_partial == 0)):
                break
        total.acked = self._all_acked()
        return total

    def _all_acked(self) -> bool:
        """Pool-level ack state: every worker has a target and owes no ack
        (its shard converged and the ack was published)."""
        return all(w._target is not None and not w._ack_pending
                   for w in self.workers)
