"""Segment maintenance plane — background upkeep of the analytical plane.

Cooperating pieces, all off the ingest path:

  * :class:`BackfillWorker` — retroactive re-enrichment: matches newly
    activated rules against historical (sealed) segments so the fluxsieve
    fast path stops falling back to full scans on pre-rule data; resumes
    from per-segment row-watermark checkpoints after a restart or budget
    cut;
  * :class:`MaintenanceWorkerPool` — N backfill workers sharding the
    segment space by id hash, each with its own consumer-group offsets and
    per-shard convergence ack;
  * :class:`ProcessMaintenancePool` — the same sharded pool as real OS
    processes over a durable control plane (``DurableControlBus`` +
    :class:`DurableLeaseManager`), surviving SIGKILL and escaping the GIL;
  * :class:`LeaseManager` — per-segment leases + epoch fencing: two
    maintenance writers can never interleave on one segment, and a crashed
    worker's lease expires instead of wedging its shard
    (:class:`FencedWriteError` is the write barrier's rejection);
    :class:`DurableLeaseManager` persists the same table + epochs on disk
    so the guarantee spans processes;
  * :class:`Compactor` — merges small sealed segments into right-sized
    ones, re-deriving zone maps and indexes, and physically drops
    retention-tombstoned rows during rewrites;
  * :class:`RetentionWorker` — event-time TTL: retires fully expired
    segments, stamps straddlers with a ``retention_cutoff``;
  * :class:`SpillGC` — deletes RETIRED spill dirs once the manifest, the
    arrangement plane's pin signal, and a grace window all agree no reader
    remains;
  * :class:`MaintenanceScheduler` — orders work by profiler-observed query
    heat and enforces a bytes/records/rows budget per cycle.

Delivery contract: engine updates reach the plane on the
``SEGMENT_MAINTENANCE`` topic with per-worker consumer groups —
**at-least-once per worker**; every install is idempotent (re-backfilling
a converged segment is a no-op) so duplicate delivery is always safe.
"""
from repro.core.maintenance.backfill import (BackfillReport, BackfillWorker,
                                             merge_reports)
from repro.core.maintenance.compactor import CompactionReport, Compactor
from repro.core.maintenance.lease import (DurableLeaseManager,
                                          FencedWriteError, Lease,
                                          LeaseManager, shard_of)
from repro.core.maintenance.process_pool import ProcessMaintenancePool
from repro.core.maintenance.retention import (GCReport, RetentionPolicy,
                                              RetentionReport,
                                              RetentionWorker, SpillGC)
from repro.core.maintenance.scheduler import (MaintenancePolicy,
                                              MaintenanceScheduler)
from repro.core.maintenance.workers import MaintenanceWorkerPool

__all__ = [
    "BackfillReport", "BackfillWorker", "CompactionReport", "Compactor",
    "DurableLeaseManager", "FencedWriteError", "GCReport", "Lease",
    "LeaseManager", "MaintenancePolicy", "MaintenanceScheduler",
    "MaintenanceWorkerPool", "ProcessMaintenancePool", "RetentionPolicy",
    "RetentionReport", "RetentionWorker", "SpillGC",
    "merge_reports", "shard_of",
]
