"""Segment maintenance plane — background upkeep of the analytical plane.

Three cooperating pieces, all off the ingest path:

  * :class:`BackfillWorker` — retroactive re-enrichment: matches newly
    activated rules against historical (sealed) segments so the fluxsieve
    fast path stops falling back to full scans on pre-rule data;
  * :class:`Compactor` — merges small sealed segments into right-sized
    ones, re-deriving zone maps and indexes;
  * :class:`MaintenanceScheduler` — orders work by profiler-observed query
    heat and enforces a bytes/records budget per cycle.
"""
from repro.core.maintenance.backfill import BackfillReport, BackfillWorker
from repro.core.maintenance.compactor import CompactionReport, Compactor
from repro.core.maintenance.scheduler import (MaintenancePolicy,
                                              MaintenanceScheduler)

__all__ = [
    "BackfillReport", "BackfillWorker", "CompactionReport", "Compactor",
    "MaintenancePolicy", "MaintenanceScheduler",
]
