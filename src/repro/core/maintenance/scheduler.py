"""MaintenanceScheduler — which sealed segments to touch, in what order,
under what budget.

The maintenance plane runs off the ingest path but shares the machine with
it, so every cycle is bounded by a bytes/records budget (the analogue of
compaction throttles in LSM stores).  Prioritization is *heat-aware*: the
QueryProfiler tracks how much query time each segment burns on the
consistency-fallback scan path (``segment_heat``), and the scheduler
re-enriches the most queried historical segments first — closing the
profiler -> updater -> backfill loop for historical data the same way the
profiler -> updater -> stream-processor loop closes it for fresh data.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class MaintenancePolicy:
    """Per-cycle budget.  ``None`` disables that bound.

    ``max_rows_per_segment_pass`` bounds how many ROWS one cycle matches
    within a single segment: a segment bigger than the budget is processed
    incrementally, each pass persisting a row-watermark checkpoint (see
    ``BackfillWorker.backfill_segment``), so even one oversized segment
    cannot blow the cycle's latency envelope — the mid-segment analogue of
    the admit-at-least-one rule below."""
    max_bytes_per_cycle: int = None
    max_records_per_cycle: int = None
    max_segments_per_cycle: int = None
    max_rows_per_segment_pass: int = None


class MaintenanceScheduler:
    def __init__(self, profiler=None, policy: MaintenancePolicy = None):
        self.profiler = profiler
        self.policy = policy or MaintenancePolicy()

    def notify_backfilled(self, segment_ids) -> None:
        """Re-run the heat accounting after a backfill install: freshly
        covered segments stop looking hot (their fallback seconds predate
        the coverage), so the next cycle's ordering reflects segments that
        are STILL burning query time, not ones already healed."""
        if self.profiler is not None:
            self.profiler.clear_segment_heat(tuple(segment_ids))

    def order(self, segments: list) -> list:
        """Hottest (most fallback-scanned) first; ties oldest-id first so
        cold historical segments still drain deterministically."""
        heat = (self.profiler.segment_heat()
                if self.profiler is not None else {})
        return sorted(segments,
                      key=lambda s: (-heat.get(s.segment_id, 0.0),
                                     s.segment_id))

    def plan_cycle(self, segments: list, *, cost_bytes=None) -> list:
        """Order candidates and cut at the cycle budget.  At least one
        segment is always admitted so a single oversized segment cannot
        starve the plane forever."""
        cost_bytes = cost_bytes or (lambda s: s.nbytes())
        take, used_b, used_r = [], 0, 0
        p = self.policy
        for seg in self.order(segments):
            b, r = cost_bytes(seg), seg.num_records
            if take:
                if p.max_segments_per_cycle is not None and \
                        len(take) >= p.max_segments_per_cycle:
                    break
                if p.max_bytes_per_cycle is not None and \
                        used_b + b > p.max_bytes_per_cycle:
                    break
                if p.max_records_per_cycle is not None and \
                        used_r + r > p.max_records_per_cycle:
                    break
            take.append(seg)
            used_b += b
            used_r += r
        return take
