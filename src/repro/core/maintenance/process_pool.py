"""ProcessMaintenancePool — the maintenance plane as real OS processes.

``MaintenanceWorkerPool`` fans workers out on *threads*: correct, but every
worker shares one GIL, so the committed backfill scaling is capped by the
single-process CPU ceiling its own bench calibrates.  This pool escapes
that ceiling: each worker is a ``multiprocessing`` (spawn) child that

  * opens the store itself via ``SegmentStore.load`` (the on-disk
    manifest / fence / checkpoint machinery is already process-safe),
  * coordinates purely through the **durable** control plane — the
    ``DurableControlBus`` topic logs for targets/acks and the
    ``DurableLeaseManager`` for per-segment leases + fencing epochs, both
    living under ``<root>/control-bus/`` — never through Python object
    sharing, and
  * survives SIGKILL: a killed worker's lease expires, its replacement
    (respawned under the SAME worker id, hence the same consumer group)
    re-derives the target from the topic history, resumes from the
    row-watermark checkpoints, and the fencing epoch granted to any
    successor rejects the zombie's late writes.

What is shared vs per-process:

  * shared (via the filesystem): segment spill dirs + manifest, bus topic
    logs + committed offsets, the lease/epoch table, object-store blobs;
  * per-process: the ``SegmentStore`` object and its column caches, the
    compiled-matcher cache (jitted engines cannot cross a process
    boundary — each worker warms its own once per target version, see
    ``BackfillWorker.warm_matchers``), telemetry registries (merged after
    the fact via per-process ``write_dump`` prefixes).

The parent keeps the thread pool's surface — ``run_cycle`` /
``run_until_converged`` / ``worker_ids`` / ``pending_segments`` /
``set_target`` / ``leases`` — so launchers and tests swap worker models
with one flag.  Between cycles the parent calls ``store.refresh()`` on its
own store object (when given) so its post-convergence assertions see the
children's installs.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import signal
import time
from pathlib import Path

from repro.core import telemetry
from repro.core.control_plane import CONTROL_DIRNAME, DurableControlBus
from repro.core.maintenance.backfill import BackfillReport, merge_reports
from repro.core.maintenance.lease import DurableLeaseManager

_DEATHS = telemetry.counter(
    "fluxsieve_maintenance_worker_deaths_total",
    help="Maintenance worker processes that died mid-cycle (killed, "
         "crashed, or stalled past the command timeout).")
_RESPAWNS = telemetry.counter(
    "fluxsieve_maintenance_worker_respawns_total",
    help="Maintenance worker processes respawned under their old identity.")


def _worker_main(cfg: dict, conn) -> None:
    """Child entry point (spawn target — module level, import-safe).

    Builds the whole maintenance stack from the durable world: store from
    the manifest, bus + leases from ``<root>/control-bus/``, artifacts
    from the shared object store.  Then serves pipe commands until EOF.

    An ``InjectedCrash`` escaping the worker is honored as a REAL hard
    kill (``SIGKILL`` to self): the PR 7 kill-point machinery extends to
    processes — no Python cleanup, no atexit, exactly what a crashed or
    OOM-killed worker leaves behind.
    """
    from repro.core import faults
    from repro.core.maintenance.backfill import BackfillWorker
    from repro.core.maintenance.scheduler import (MaintenancePolicy,
                                                  MaintenanceScheduler)
    from repro.core.object_store import ObjectStore
    from repro.core.query.store import SegmentStore

    root = Path(cfg["root"])
    store = SegmentStore.load(root, segment_size=cfg["segment_size"],
                              index_fields=tuple(cfg["index_fields"]))
    bus = DurableControlBus(root / CONTROL_DIRNAME)
    leases = DurableLeaseManager(root / CONTROL_DIRNAME,
                                 ttl=cfg["lease_ttl"])
    ostore = ObjectStore(root=cfg["objects_root"])
    scheduler = None
    if cfg["policy"] is not None:
        scheduler = MaintenanceScheduler(
            None, MaintenancePolicy(**cfg["policy"]))
    worker = BackfillWorker(
        store, bus, ostore, worker_id=cfg["worker_id"],
        scheduler=scheduler, backend=cfg["backend"],
        block_n=cfg["block_n"], interpret=cfg["interpret"],
        shard_index=cfg["shard_index"], num_shards=cfg["num_shards"],
        leases=leases, rows_per_pass=cfg["rows_per_pass"])

    while True:
        try:
            cmd = conn.recv()
        except (EOFError, OSError, KeyboardInterrupt):
            break
        try:
            op = cmd[0]
            if op == "stop":
                conn.send(("bye", None))
                break
            elif op == "cycle":
                store.refresh()     # see the parent's newest seals/compactions
                rep = worker.run_cycle(max_segments=cmd[1])
                acked = (worker._target is not None
                         and not worker._ack_pending)
                reply = ("report", rep, acked)
            elif op == "pending":
                store.refresh()
                worker.poll_target()
                reply = ("pending",
                         [s.segment_id for s in worker.pending_segments()])
            elif op == "set_target":
                worker.set_target(cmd[1])
                reply = ("ok", None)
            elif op == "warm":
                store.refresh()
                worker.poll_target()
                reply = ("ok", worker.warm_matchers())
            elif op == "dump":
                paths = telemetry.write_dump(
                    cmd[1], prefix=f"{cfg['worker_id']}.")
                reply = ("ok", [str(p) for p in paths.values()])
            else:
                reply = ("error", f"unknown command {op!r}")
        except faults.InjectedCrash:
            # a REAL hard kill, not an exception unwind: the parent sees
            # EOF, the lease table sees an expiry, the checkpoint files
            # see nothing at all
            os.kill(os.getpid(), signal.SIGKILL)
        except BaseException as e:  # noqa: BLE001 — isolate, report, serve on
            reply = ("error", f"{type(e).__name__}: {e}")
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            break


class ProcessMaintenancePool:
    """N sharded, leased backfill workers as spawn *processes* over one
    durable root.  Same calling surface as ``MaintenanceWorkerPool``.

    ``root`` must be a spilled store root (the children reopen it via
    ``SegmentStore.load``); ``objects_root`` the shared ``ObjectStore``
    root holding the compiled engine artifacts.  ``store`` may pass the
    parent's own ``SegmentStore`` object — it is refreshed after every
    cycle so the parent observes the children's installs.

    No ``matcher_cache`` parameter exists by design: compiled matchers
    are jitted closures that cannot cross a process boundary, so the
    cache is strictly per-process (each worker warms its own once per
    target version).  ``scheduler`` degrades gracefully: only its
    *policy* (a plain dataclass) ships to the children — profiler heat
    lives in the parent and cannot steer child-side ordering.
    """

    def __init__(self, root, *, num_workers: int = 2, store=None,
                 objects_root=None, scheduler=None, policy=None,
                 backend: str = "dfa_ref", block_n: int = 256,
                 interpret: bool = True, rows_per_pass: int = None,
                 worker_prefix: str = "maint", lease_ttl: float = 30.0,
                 segment_size: int = 100_000, index_fields: tuple = (),
                 recv_timeout: float = 120.0, respawn: bool = True):
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        self.root = Path(root)
        self.store = store
        if objects_root is None:
            raise ValueError(
                "ProcessMaintenancePool needs objects_root: worker "
                "processes fetch compiled artifacts from a shared "
                "file-backed ObjectStore, not from parent memory")
        self.objects_root = str(objects_root)
        if policy is None and scheduler is not None:
            policy = scheduler.policy
        self._policy_dict = (dataclasses.asdict(policy)
                             if policy is not None else None)
        self.num_workers = num_workers
        self.recv_timeout = float(recv_timeout)
        self.respawn = respawn
        self.leases = DurableLeaseManager(self.root / CONTROL_DIRNAME,
                                          ttl=lease_ttl)
        self.bus = DurableControlBus(self.root / CONTROL_DIRNAME)
        self._ctx = mp.get_context("spawn")
        self._cfg_base = {
            "root": str(self.root), "objects_root": self.objects_root,
            "backend": backend, "block_n": block_n, "interpret": interpret,
            "rows_per_pass": rows_per_pass, "lease_ttl": float(lease_ttl),
            "segment_size": int(segment_size),
            "index_fields": tuple(index_fields),
            "num_shards": num_workers, "policy": self._policy_dict,
        }
        self._prefix = worker_prefix
        self._workers = [self._spawn(i) for i in range(num_workers)]
        self._deaths_last_cycle = 0

    # -- process lifecycle -------------------------------------------------
    def _spawn(self, index: int) -> dict:
        worker_id = f"{self._prefix}-{index}"
        parent_conn, child_conn = self._ctx.Pipe()
        cfg = {**self._cfg_base, "worker_id": worker_id,
               "shard_index": index}
        proc = self._ctx.Process(target=_worker_main,
                                 args=(cfg, child_conn),
                                 name=worker_id, daemon=True)
        proc.start()
        child_conn.close()
        return {"index": index, "worker_id": worker_id, "proc": proc,
                "conn": parent_conn, "alive": True}

    def _ensure_workers(self) -> None:
        """Respawn any dead worker under its OLD identity: same worker id
        means same consumer group, so the replacement resumes from the
        committed offsets (or re-derives the target from topic history)
        and from the on-disk row-watermark checkpoints."""
        for i, w in enumerate(self._workers):
            if w["alive"] and w["proc"].is_alive():
                continue
            self._mark_dead(w)
            self._workers[i] = self._spawn(w["index"])
            _RESPAWNS.inc()
            telemetry.emit("worker_respawn", plane="maintenance",
                           worker=w["worker_id"])

    def _mark_dead(self, w: dict) -> None:
        if not w["alive"]:
            return
        w["alive"] = False
        try:
            w["conn"].close()
        except OSError:
            pass
        if w["proc"].is_alive():
            w["proc"].kill()
        w["proc"].join(timeout=5.0)

    def _request(self, w: dict, cmd: tuple):
        """Send + receive with a liveness deadline.  Returns the reply or
        None when the worker died (killed mid-command, crashed, or stalled
        past ``recv_timeout`` — stalls are treated as deaths, the
        replacement takes over from durable state)."""
        if not w["alive"]:
            return None
        try:
            w["conn"].send(cmd)
            deadline = time.monotonic() + self.recv_timeout
            while True:
                if w["conn"].poll(0.05):
                    return w["conn"].recv()
                if not w["proc"].is_alive() and not w["conn"].poll(0.05):
                    raise EOFError("worker process died")
                if time.monotonic() > deadline:
                    raise TimeoutError("worker command timed out")
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError,
                TimeoutError):
            self._mark_dead(w)
            self._deaths_last_cycle += 1
            _DEATHS.inc()
            telemetry.emit("worker_death", plane="maintenance",
                           worker=w["worker_id"], command=cmd[0])
            return None

    def close(self) -> None:
        """Stop every child (graceful, then forceful)."""
        for w in self._workers:
            if w["alive"]:
                try:
                    w["conn"].send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
        for w in self._workers:
            if w["alive"]:
                w["proc"].join(timeout=5.0)
            self._mark_dead(w)

    def __del__(self):
        try:
            self.close()
        except Exception:   # noqa: BLE001 — interpreter teardown
            pass

    # -- pool surface (MaintenanceWorkerPool-compatible) -------------------
    @property
    def worker_ids(self) -> tuple:
        """Identities acking on ``MAINTENANCE_ACKS`` — pass to
        ``MatcherUpdater.await_maintenance``.  Stable across respawns."""
        return tuple(w["worker_id"] for w in self._workers)

    def set_target(self, ruleset) -> None:
        """Direct (bus-less) targeting of every worker."""
        self._ensure_workers()
        for w in self._workers:
            self._request(w, ("set_target", ruleset))

    def warm_matchers(self) -> int:
        """Ask every worker to poll its target and precompile its delta
        matchers (``BackfillWorker.warm_matchers``) — benches call this so
        compile cost stays out of the timed lanes, exactly like the thread
        pool's shared-cache warmup."""
        self._ensure_workers()
        total = 0
        for w in self._workers:
            reply = self._request(w, ("warm",))
            if reply is not None and reply[0] == "ok":
                total += int(reply[1])
        return total

    def pending_segments(self) -> list:
        """Union of every shard's pending set.  Returns the PARENT store's
        segment objects when a store was attached, else bare segment ids."""
        self._ensure_workers()
        ids = []
        for w in self._workers:
            reply = self._request(w, ("pending",))
            if reply is not None and reply[0] == "pending":
                ids.extend(reply[1])
        if self.store is None:
            return ids
        self.store.refresh()
        wanted = set(ids)
        return [s for s in self.store.segments if s.segment_id in wanted]

    def run_cycle(self, *, max_segments: int = None) -> BackfillReport:
        """One pool cycle: every live worker refreshes its store view,
        polls its offsets, and backfills its shard — concurrently, in its
        own process.  A worker that dies mid-cycle (SIGKILL, injected
        crash, stall) contributes nothing this cycle and is respawned at
        the start of the next one."""
        self._ensure_workers()
        self._deaths_last_cycle = 0
        with telemetry.span("maintenance/process_pool_cycle",
                            cat="maintenance", workers=self.num_workers):
            for w in self._workers:
                if w["alive"]:
                    try:
                        w["conn"].send(("cycle", max_segments))
                        w["_inflight"] = True
                    except (BrokenPipeError, OSError):
                        self._mark_dead(w)
                        self._deaths_last_cycle += 1
                        _DEATHS.inc()
                        w["_inflight"] = False
                else:
                    w["_inflight"] = False
            total = BackfillReport()
            acked_all = True
            for w in self._workers:
                if not w.get("_inflight"):
                    acked_all = False
                    continue
                reply = self._collect(w)
                if reply is None or reply[0] != "report":
                    acked_all = False
                    continue
                merge_reports(total, reply[1], sequential=False)
                acked_all = acked_all and reply[2]
        total.acked = acked_all and self._deaths_last_cycle == 0
        if self.store is not None:
            self.store.refresh()
        return total

    def _collect(self, w: dict):
        """Receive a cycle reply (same liveness discipline as _request,
        but the command was already sent)."""
        try:
            deadline = time.monotonic() + self.recv_timeout
            while True:
                if w["conn"].poll(0.05):
                    reply = w["conn"].recv()
                    if reply[0] == "error":
                        telemetry.emit("worker_cycle_error",
                                       plane="maintenance",
                                       worker=w["worker_id"],
                                       error=reply[1])
                        return None
                    return reply
                if not w["proc"].is_alive() and not w["conn"].poll(0.05):
                    raise EOFError("worker process died mid-cycle")
                if time.monotonic() > deadline:
                    raise TimeoutError("worker cycle timed out")
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError,
                TimeoutError):
            self._mark_dead(w)
            self._deaths_last_cycle += 1
            _DEATHS.inc()
            telemetry.emit("worker_death", plane="maintenance",
                           worker=w["worker_id"], command="cycle")
            return None

    def run_until_converged(self, *, max_cycles: int = 1000
                            ) -> BackfillReport:
        """Cycle the pool until every shard converged (or no live shard can
        make progress).  A cycle that lost a worker never terminates the
        loop — the replacement must first report its shard's true pending
        count."""
        total = BackfillReport()
        last = None
        for _ in range(max_cycles):
            rep = self.run_cycle()
            merge_reports(total, rep)
            last = rep
            if self._deaths_last_cycle:
                continue    # a dead shard's pending count is unknown
            if rep.messages == 0 and (
                    rep.pending_after == 0
                    or (rep.segments_backfilled == 0
                        and rep.segments_partial == 0)):
                break
        total.acked = bool(last is not None and last.acked)
        return total

    # -- telemetry ---------------------------------------------------------
    def write_dumps(self, directory) -> list:
        """Per-process telemetry dumps: every worker writes
        ``<worker_id>.metrics.prom`` / ``.snapshot.json`` / ``.trace.json``
        into ``directory``.  Pair with ``telemetry.export.merge_dumps`` to
        fold them (plus the parent's own dump) into one snapshot."""
        self._ensure_workers()
        paths = []
        for w in self._workers:
            reply = self._request(w, ("dump", str(directory)))
            if reply is not None and reply[0] == "ok":
                paths.extend(reply[1])
        return paths
