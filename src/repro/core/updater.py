"""MatcherUpdater — central orchestrator of pattern-engine rollout
(paper §3.4.1-§3.4.2).

Update flow, implemented verbatim against the ObjectStore/ControlBus
stand-ins:

  1. ``submit(ruleset)``     — delta computation vs the current set;
  2. async **compilation**   — off the data path, in a worker thread;
  3. artifact **upload**     — versioned + checksummed into the object store;
  4. **notification**        — lightweight message (ObjectRef, version,
                               checksum) on the matcher-updates topic;
  5. processors fetch/validate/swap (stream_processor.poll_updates);
  6. **acknowledgments**     — tracked per instance with a rollout timeout;
     ``await_rollout`` reports completed/failed/missing instances and
     ``rollback`` re-publishes a previous version.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.core import telemetry
from repro.core.automaton import STATE_BUCKETS
from repro.core.control_plane import (ControlBus, MAINTENANCE_ACKS,
                                      MATCHER_ACKS, MATCHER_UPDATES,
                                      SEGMENT_MAINTENANCE)
from repro.core.matcher import EngineBundle, compile_bundle
from repro.core.object_store import ObjectRef, ObjectStore
from repro.core.patterns import RuleSet

ENGINE_KEY = "engines/matcher"

_COMPILE_HIST = telemetry.histogram(
    "fluxsieve_updater_compile_seconds",
    help="Engine compilation latency (off the data path).")
_PUBLISH_HIST = telemetry.histogram(
    "fluxsieve_updater_publish_seconds",
    help="Artifact upload + control-bus notification latency.")
_RULES_REJECTED = telemetry.counter(
    "fluxsieve_updater_rules_rejected_total",
    help="Rules nacked by submit-time validation (rest of the set sails).")


@dataclass
class UpdateHandle:
    version: str
    delta: dict
    ref: ObjectRef = None
    checksum: str = ""
    error: str = ""
    rejected: dict = field(default_factory=dict)  # rule name -> nack reason
    _done: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: float = None) -> bool:
        return self._done.wait(timeout)

    @property
    def published(self) -> bool:
        return self._done.is_set() and not self.error


@dataclass
class RolloutStatus:
    version: str
    acked: tuple
    failed: dict            # instance -> error
    missing: tuple
    complete: bool


class MatcherUpdater:
    def __init__(self, store: ObjectStore, bus: ControlBus, fields,
                 *, initial: RuleSet = None):
        self.store = store
        self.bus = bus
        self.fields = tuple(fields)
        self._lock = threading.RLock()
        self._current: RuleSet = initial if initial is not None else RuleSet(())
        # history entries: (version, ObjectRef|None, checksum, RuleSet)
        # the initial (out-of-band deployed) version has no stored artifact
        self._history: list = [(self._current.version_hash(), None, "",
                                self._current)]
        self._ack_cursor = 0
        self._maint_cursor = 0

    @property
    def current_ruleset(self) -> RuleSet:
        with self._lock:
            return self._current

    @property
    def current_version(self) -> str:
        with self._lock:
            return self._current.version_hash()

    # -- steps 1-4 -------------------------------------------------------
    @staticmethod
    def _validate_rule(rule) -> str:
        """Submit-time sanity check for ONE rule; -> nack reason or None.
        A rule can pass construction (<=4096 literals, each <=256 bytes)
        yet blow past the largest DFA state bucket at compile time — the
        trie upper bound (sum of literal lengths) catches it here, before
        it can fail the compile for every OTHER rule in the set."""
        try:
            lits = rule.literals()
        except Exception as e:  # noqa: BLE001 — any expand failure is a nack
            return f"{type(e).__name__}: {e}"
        states = 1 + sum(len(lit) for lit in lits)
        if states > STATE_BUCKETS[-1]:
            return (f"state estimate {states} exceeds the largest DFA "
                    f"bucket ({STATE_BUCKETS[-1]})")
        return None

    def submit(self, ruleset: RuleSet, *, asynchronous: bool = True) -> UpdateHandle:
        """Compute delta, validate, compile, upload, notify.  Compilation
        runs in a worker thread by default — 'performed asynchronously and
        does not block ongoing stream processing' (paper §3.4 step 2).

        Validation nacks *individual* bad rules (``handle.rejected``, one
        ``rule_rejected`` event each) and compiles the rest: one
        un-compilable rule must not take down an otherwise-good rollout."""
        rejected = {}
        with self._lock:
            known = {r.rule_id: r for r in self._current.rules}
        for rule in ruleset.rules:
            if known.get(rule.rule_id) == rule:
                continue                # unchanged: compiled in a past rollout
            err = self._validate_rule(rule)
            if err is not None:
                rejected[rule.name] = err
                _RULES_REJECTED.inc()
                telemetry.emit("rule_rejected", plane="control",
                               rule=rule.name, rule_id=rule.rule_id,
                               error=err)
        if rejected:
            bad_names = set(rejected)
            ruleset = ruleset.without_ids(
                r.rule_id for r in ruleset.rules if r.name in bad_names)
        with self._lock:
            delta = self._current.diff(ruleset)
        handle = UpdateHandle(version=ruleset.version_hash(), delta=delta,
                              rejected=rejected)
        if not (delta["added"] or delta["removed"] or delta["changed"]):
            handle.error = ("no-op: every submitted change was rejected"
                            if rejected else
                            "no-op: target equals current rule set")
            handle._done.set()
            return handle

        def work():
            try:
                t0 = time.perf_counter()
                with telemetry.span("updater/compile", cat="control",
                                    version=handle.version,
                                    rules=ruleset.num_rules):
                    bundle = compile_bundle(ruleset, self.fields)
                _COMPILE_HIST.observe(time.perf_counter() - t0)
                t1 = time.perf_counter()
                with telemetry.span("updater/publish", cat="control",
                                    version=bundle.version):
                    ref = self.store.put(ENGINE_KEY, bundle.serialize())
                    checksum = bundle.checksum()
                    notification = {
                        "engine_version": bundle.version,
                        "object_ref": ref.to_dict(),
                        "checksum": checksum,
                        "num_rules": bundle.num_rules,
                        "delta": {k: [r.name for r in v]
                                  for k, v in delta.items()},
                    }
                    self.bus.publish(MATCHER_UPDATES, notification)
                    # fan out to the maintenance plane: backfill workers
                    # re-enrich historical (sealed) segments off the ingest
                    # path
                    self.bus.publish(SEGMENT_MAINTENANCE, notification)
                _PUBLISH_HIST.observe(time.perf_counter() - t1)
                with self._lock:
                    self._current = ruleset
                    self._history.append((bundle.version, ref, checksum,
                                          ruleset))
                handle.ref = ref
                handle.checksum = checksum
            except Exception as e:  # noqa: BLE001
                handle.error = f"{type(e).__name__}: {e}"
            finally:
                handle._done.set()

        if asynchronous:
            threading.Thread(target=work, daemon=True).start()
        else:
            work()
        return handle

    # -- step 6 ----------------------------------------------------------
    def await_rollout(self, version: str, instances, *, timeout: float = 10.0,
                      poll_interval: float = 0.02) -> RolloutStatus:
        """Watch the ack topic until every instance confirms `version` (or
        the timeout elapses — the paper's failure-detection window)."""
        return self._watch_acks(MATCHER_ACKS, "_ack_cursor", "instance",
                                version, instances, timeout, poll_interval)

    def await_maintenance(self, version: str, workers, *,
                          timeout: float = 30.0,
                          poll_interval: float = 0.02) -> RolloutStatus:
        """Watch the maintenance-ack topic until every backfill worker
        confirms it has re-enriched the sealed segments for ``version``."""
        return self._watch_acks(MAINTENANCE_ACKS, "_maint_cursor", "worker",
                                version, workers, timeout, poll_interval)

    def _watch_acks(self, topic: str, cursor_attr: str, sender_key: str,
                    version: str, senders, timeout: float,
                    poll_interval: float) -> RolloutStatus:
        want = set(senders)
        acked: set = set()
        failed: dict = {}
        deadline = time.time() + timeout
        while time.time() < deadline:
            for msg in self.bus.messages(topic, getattr(self, cursor_attr)):
                setattr(self, cursor_attr, msg.offset + 1)
                if msg.value.get("engine_version") != version:
                    continue
                inst = msg.value[sender_key]
                if msg.value.get("ok"):
                    acked.add(inst)
                    failed.pop(inst, None)
                else:
                    failed[inst] = msg.value.get("error", "unknown")
            if want <= acked:
                break
            time.sleep(poll_interval)
        missing = tuple(sorted(want - acked - set(failed)))
        return RolloutStatus(version=version, acked=tuple(sorted(acked)),
                             failed=failed, missing=missing,
                             complete=want <= acked)

    # -- rollback ----------------------------------------------------------
    def rollback(self) -> UpdateHandle:
        """Re-publish the previous engine version.  Object-store versions are
        immutable, so when an artifact exists this is a pure notification —
        no recompile.  The initial (out-of-band deployed) version has no
        stored artifact; rolling back to it recompiles synchronously."""
        with self._lock:
            if len(self._history) < 2:
                raise RuntimeError("no previous version to roll back to")
            version, ref, checksum, ruleset = self._history[-2]
        if ref is None:
            with self._lock:
                self._history.pop()          # drop the version being undone
            return self.submit(ruleset, asynchronous=False)
        with self._lock:
            self._history.append((version, ref, checksum, ruleset))
            self._current = ruleset
        handle = UpdateHandle(version=version,
                              delta={"added": [], "removed": [], "changed": []})
        notification = {
            "engine_version": version, "object_ref": ref.to_dict(),
            "checksum": checksum, "num_rules": ruleset.num_rules,
            "delta": "rollback",
        }
        self.bus.publish(MATCHER_UPDATES, notification)
        self.bus.publish(SEGMENT_MAINTENANCE, notification)
        handle.ref, handle.checksum = ref, checksum
        handle._done.set()
        return handle
