"""StreamProcessor — the in-stream prefilter/enricher (paper §3.2 module 2,
§3.4.3 "Streaming Application (Matcher)").

Dual-topology design, as in the paper's Kafka Streams implementation:

  * the **data topology** (``process``) runs every incoming RecordBatch
    through the active per-field matchers and attaches the packed rule
    bitmap (enrichment) — and, in ``filter`` mode, drops non-matching
    records entirely;
  * the **control topology** (``poll_updates``) consumes engine-update
    notifications, fetches the compiled artifact from the object store,
    validates version + checksum, and hot-swaps the active matchers.

The active engine lives behind a single reference read once per batch
(`_active`), so in-flight batches finish against the engine they started
with — the paper's no-downtime swap guarantee.  Swap never retraces jit
caches because table shapes are bucketed (automaton.py).

The data topology is split into ``process_async`` (ONE fused device
dispatch for all text fields of a batch — see matcher.FusedMatcher) and
``finalize`` (single D2H transfer + column attach + optional filter), so a
pipelined caller can keep the device matching batch *k* while the host
stores batch *k-1* (data/pipeline.py).  ``process`` is the sequential
composition of the two.
"""
from __future__ import annotations

import functools
import operator
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import enrichment, faults, telemetry
from repro.core.control_plane import (ControlBus, MATCHER_ACKS,
                                      MATCHER_UPDATES)
from repro.core.faults import CircuitBreaker, InjectedCrash
from repro.core.matcher import (FUSED_BACKENDS, EngineBundle, FusedMatcher,
                                MatchResult, build_matchers, match_pairs)
from repro.core.object_store import ObjectRef, ObjectStore
from repro.core.patterns import ruleset_idents
from repro.core.records import RecordBatch

ENRICH_COLUMN = "rule_bitmap"
ENGINE_VERSION_COLUMN = "engine_version_id"

# the oracle lane the breaker degrades to: same compiled tables, jnp
# reference execution — bitmaps identical to the primary by construction
FALLBACK_BACKEND = "dfa_ref"

_DISPATCH_ERRORS = telemetry.counter(
    "fluxsieve_match_dispatch_errors_total",
    help="Failed primary-lane dispatch attempts (each may be retried).")
_FALLBACK_BATCHES = telemetry.counter(
    "fluxsieve_match_fallback_batches_total",
    help="Batches matched on the degraded oracle lane (breaker open or "
         "primary retries exhausted).")
_POLL_HIST = telemetry.histogram(
    "fluxsieve_match_poll_seconds",
    help="Control-topology bus-poll latency (poll_updates, per call).")


class BatchMatchError(RuntimeError):
    """A batch failed on the primary AND the fallback match lanes — it
    cannot be enriched.  The ingest pipeline quarantines such batches to a
    dead-letter spill dir instead of dropping them (or crashing)."""


@dataclass
class _Active:
    bundle: EngineBundle
    matchers: dict          # field -> MatchEngine
    fused: object           # FusedMatcher, or None for host-path backends
    version_id: int         # monotonically increasing local id
    activated_at: float
    fallback: object = None  # lazily built FALLBACK_BACKEND FusedMatcher


@dataclass
class PendingBatch:
    """An in-flight enriched batch: dispatched, result possibly still on
    device.  ``StreamProcessor.finalize`` turns it into a RecordBatch."""
    batch: RecordBatch
    result: MatchResult
    version_id: int
    n: int


@dataclass
class ProcessorStats:
    records_in: int = 0
    records_out: int = 0
    records_matched: int = 0
    batches: int = 0
    swaps: int = 0
    match_seconds: float = 0.0
    versions: dict = field(default_factory=dict)  # version -> activation time


class StreamProcessor:
    """mode: 'enrich' keeps every record and attaches the bitmap (paper's
    deployment — analytical plane stays the complete source of truth);
    'filter' additionally drops records that match no rule (pre-filtering
    for pipelines that only want query-relevant records)."""

    def __init__(self, bundle: EngineBundle, *, instance_id: str = "proc-0",
                 mode: str = "enrich", backend: str = "dfa_ref",
                 bus: ControlBus = None, store: ObjectStore = None,
                 block_n: int = 256, interpret: bool = True,
                 confirm_backend: str = "ref", retry_limit: int = 2,
                 retry_backoff_s: float = 0.002,
                 breaker: CircuitBreaker = None):
        if mode not in ("enrich", "filter"):
            raise ValueError(mode)
        self.instance_id = instance_id
        self.mode = mode
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret
        self.confirm_backend = confirm_backend   # dfa_selective pass 2
        self.bus = bus
        self.store = store
        # graceful degradation: bounded retry-with-backoff around the
        # primary dispatch, then a circuit breaker that routes whole
        # batches to the FALLBACK_BACKEND oracle lane (see _dispatch)
        self.retry_limit = int(retry_limit)
        self.retry_backoff_s = float(retry_backoff_s)
        self.breaker = breaker or CircuitBreaker(site="match.dispatch")
        self.stats = ProcessorStats()
        self._lock = threading.RLock()
        self._pending: dict = {}          # version -> ObjectRef (fetch queued)
        self._swap_lock = threading.Lock()
        # version_id -> {str(rule_id): ident}: which rules (by content
        # identity) each activated engine knew.  The SegmentStore reads this
        # at seal time to derive the per-segment ``rules_known`` coverage
        # metadata (consistency propagation, paper §3.4 step 4).
        self.version_rules: dict = {}
        self._install(bundle, version_id=0)

    # -- data topology ---------------------------------------------------
    def process(self, batch: RecordBatch) -> RecordBatch:
        """Match + enrich (and maybe filter) one batch, synchronously."""
        return self.finalize(self.process_async(batch))

    def process_async(self, batch: RecordBatch) -> PendingBatch:
        """Dispatch the match for one batch and return without blocking on
        the device: ONE fused dispatch covering every matched text field
        (bitmap OR + any-match mask computed on device)."""
        active = self._active                      # single read: swap-safe
        t0 = time.perf_counter()
        n = len(batch)
        result = self._dispatch(active, batch, n)
        with self._lock:
            self.stats.match_seconds += time.perf_counter() - t0
        return PendingBatch(batch=batch, result=result,
                            version_id=active.version_id, n=n)

    def _dispatch(self, active: _Active, batch: RecordBatch, n: int):
        """Primary-lane dispatch behind the degradation machinery: bounded
        retry-with-backoff, then the circuit breaker routes the batch to
        the oracle lane (same bundle, FALLBACK_BACKEND execution — bitmaps
        identical by construction).  While OPEN, every batch goes straight
        to the fallback and periodic HALF_OPEN probes test the primary.
        A batch that fails on BOTH lanes raises ``BatchMatchError`` — the
        pipeline quarantines it, ingest keeps flowing."""
        def primary():
            faults.fire("match.dispatch", backend=self.backend,
                        instance=self.instance_id)
            if active.fused is not None:
                return active.fused.match_batch(batch.columns,
                                                batch.text_fields, n)
            return self._match_per_field(active, batch)

        if self.breaker.allow_primary():
            err = None
            for attempt in range(self.retry_limit + 1):
                try:
                    result = primary()
                    self.breaker.record_success()
                    return result
                except InjectedCrash:
                    raise               # a simulated kill is not retryable
                except Exception as e:  # noqa: BLE001 — degrade, not drop
                    err = e
                    _DISPATCH_ERRORS.inc()
                    if attempt < self.retry_limit and self.retry_backoff_s:
                        time.sleep(self.retry_backoff_s * (2 ** attempt))
            self.breaker.record_failure(
                error=f"{type(err).__name__}: {err}")
        try:
            faults.fire("match.fallback", backend=FALLBACK_BACKEND,
                        instance=self.instance_id)
            result = self._fallback_for(active).match_batch(
                batch.columns, batch.text_fields, n)
            _FALLBACK_BATCHES.inc()
            return result
        except InjectedCrash:
            raise
        except Exception as e:  # noqa: BLE001 — deterministic failure
            raise BatchMatchError(
                f"batch failed on primary ({self.backend}) and fallback "
                f"({FALLBACK_BACKEND}) lanes: {type(e).__name__}: {e}") from e

    def _fallback_for(self, active: _Active) -> FusedMatcher:
        """The degraded lane's matcher, built lazily per active version
        (off the happy path — most processes never pay for it)."""
        if active.fallback is None:
            with self._swap_lock:
                if active.fallback is None:
                    active.fallback = FusedMatcher(
                        active.bundle, backend=FALLBACK_BACKEND,
                        block_n=self.block_n, interpret=self.interpret)
        return active.fallback

    def finalize(self, pending: PendingBatch) -> RecordBatch:
        """Materialize a pending batch: single D2H transfer, attach the
        enrichment columns, apply filter mode, account stats."""
        t0 = time.perf_counter()
        faults.fire("match.d2h", version=pending.version_id)
        bm, matched = pending.result.to_host()
        out = pending.batch.with_column(ENRICH_COLUMN, bm)
        out = out.with_column(
            ENGINE_VERSION_COLUMN,
            np.full(pending.n, pending.version_id, np.int32))
        if self.mode == "filter":
            out = out.select(matched)
        with self._lock:
            self.stats.records_in += pending.n
            self.stats.records_out += len(out)
            self.stats.records_matched += int(matched.sum())
            self.stats.batches += 1
            self.stats.match_seconds += time.perf_counter() - t0
        return out

    def _match_per_field(self, active: _Active, batch: RecordBatch):
        """Fallback for backends without a fused dispatch (dfa_selective,
        shift_or): per-field engine calls, OR-reduced on device when every
        engine returns device arrays (one D2H at finalize), on host
        otherwise."""
        bms = [active.matchers[f].match(batch.columns[c])
               for f, c in match_pairs(tuple(active.matchers),
                                       batch.text_fields)]
        n, W = len(batch), active.bundle.words
        if not bms:
            return MatchResult(np.zeros((n, W), np.uint32),
                               np.zeros(n, bool))
        if any(isinstance(b, np.ndarray) for b in bms):
            bm = np.zeros((n, W), np.uint32)
            for b in bms:
                bm |= np.asarray(b)
            return MatchResult(bm, enrichment.any_match(bm))
        bm = functools.reduce(operator.or_, bms)
        return MatchResult(bm, (bm != 0).any(axis=1))

    # -- control topology --------------------------------------------------
    def poll_updates(self) -> int:
        """Consume update notifications; fetch+validate+swap.  Returns the
        number of successful swaps performed (paper §3.4.2 steps 4-6)."""
        if self.bus is None or self.store is None:
            return 0
        group = f"matcher/{self.instance_id}"
        swaps = 0
        t0 = time.perf_counter()
        with telemetry.span("match/poll_updates", cat="control",
                            instance=self.instance_id):
            swaps = self._poll_updates(group)
        _POLL_HIST.observe(time.perf_counter() - t0)
        return swaps

    def _poll_updates(self, group: str) -> int:
        swaps = 0
        for msg in self.bus.poll(MATCHER_UPDATES, group):
            ok = False
            err = ""
            try:
                ref = ObjectRef.from_dict(msg.value["object_ref"])
                expect_version = msg.value["engine_version"]
                expect_checksum = msg.value["checksum"]
                data = self.store.get(ref, verify=True)           # sha256
                bundle = EngineBundle.deserialize(data, verify=True)
                if bundle.version != expect_version:
                    raise ValueError(
                        f"version mismatch: got {bundle.version}, "
                        f"expected {expect_version}")
                if bundle.checksum() != expect_checksum:
                    raise ValueError("bundle checksum != notification checksum")
                self.swap(bundle)
                swaps += 1
                ok = True
            except Exception as e:  # noqa: BLE001 — ack failure, keep serving
                err = str(e)
            self.bus.commit(MATCHER_UPDATES, group, msg.offset)
            ack = {"instance": self.instance_id,
                   "engine_version": msg.value.get("engine_version"),
                   "ok": ok}
            if not ok:
                ack["error"] = err
                # echo the artifact reference so operators can tell WHICH
                # object failed fetch/validation from the ack alone
                ack["object_ref"] = msg.value.get("object_ref")
            self.bus.publish(MATCHER_ACKS, ack)
        return swaps

    def swap(self, bundle: EngineBundle) -> None:
        """Hot swap: build matchers off-path, then flip the reference."""
        with self._swap_lock:
            vid = self._active.version_id + 1
            self._install(bundle, version_id=vid)
            with self._lock:
                self.stats.swaps += 1

    # -- introspection -------------------------------------------------------
    @property
    def active_version(self) -> str:
        return self._active.bundle.version

    @property
    def active_version_id(self) -> int:
        return self._active.version_id

    @property
    def num_rules(self) -> int:
        return self._active.bundle.num_rules

    def _install(self, bundle: EngineBundle, version_id: int) -> None:
        matchers = build_matchers(bundle, backend=self.backend,
                                  block_n=self.block_n,
                                  interpret=self.interpret,
                                  confirm_backend=self.confirm_backend)
        fused = None
        if self.backend in FUSED_BACKENDS:
            fused = FusedMatcher(bundle, backend=self.backend,
                                 block_n=self.block_n,
                                 interpret=self.interpret)
        idents = (ruleset_idents(bundle.ruleset()) if bundle.ruleset_json
                  else {})
        self.version_rules[version_id] = idents
        self._active = _Active(bundle=bundle, matchers=matchers, fused=fused,
                               version_id=version_id,
                               activated_at=time.time())
        self.stats.versions[bundle.version] = self._active.activated_at
