"""StreamProcessor — the in-stream prefilter/enricher (paper §3.2 module 2,
§3.4.3 "Streaming Application (Matcher)").

Dual-topology design, as in the paper's Kafka Streams implementation:

  * the **data topology** (``process``) runs every incoming RecordBatch
    through the active per-field matchers and attaches the packed rule
    bitmap (enrichment) — and, in ``filter`` mode, drops non-matching
    records entirely;
  * the **control topology** (``poll_updates``) consumes engine-update
    notifications, fetches the compiled artifact from the object store,
    validates version + checksum, and hot-swaps the active matchers.

The active engine lives behind a single reference read once per batch
(`_active`), so in-flight batches finish against the engine they started
with — the paper's no-downtime swap guarantee.  Swap never retraces jit
caches because table shapes are bucketed (automaton.py).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import enrichment
from repro.core.control_plane import (ControlBus, MATCHER_ACKS,
                                      MATCHER_UPDATES)
from repro.core.matcher import EngineBundle, MatchEngine, build_matchers
from repro.core.object_store import ObjectRef, ObjectStore
from repro.core.patterns import ruleset_idents
from repro.core.records import RecordBatch

ENRICH_COLUMN = "rule_bitmap"
ENGINE_VERSION_COLUMN = "engine_version_id"


@dataclass
class _Active:
    bundle: EngineBundle
    matchers: dict          # field -> MatchEngine
    version_id: int         # monotonically increasing local id
    activated_at: float


@dataclass
class ProcessorStats:
    records_in: int = 0
    records_out: int = 0
    records_matched: int = 0
    batches: int = 0
    swaps: int = 0
    match_seconds: float = 0.0
    versions: dict = field(default_factory=dict)  # version -> activation time


class StreamProcessor:
    """mode: 'enrich' keeps every record and attaches the bitmap (paper's
    deployment — analytical plane stays the complete source of truth);
    'filter' additionally drops records that match no rule (pre-filtering
    for pipelines that only want query-relevant records)."""

    def __init__(self, bundle: EngineBundle, *, instance_id: str = "proc-0",
                 mode: str = "enrich", backend: str = "dfa_ref",
                 bus: ControlBus = None, store: ObjectStore = None,
                 block_n: int = 256, interpret: bool = True):
        if mode not in ("enrich", "filter"):
            raise ValueError(mode)
        self.instance_id = instance_id
        self.mode = mode
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret
        self.bus = bus
        self.store = store
        self.stats = ProcessorStats()
        self._lock = threading.RLock()
        self._pending: dict = {}          # version -> ObjectRef (fetch queued)
        self._swap_lock = threading.Lock()
        # version_id -> {str(rule_id): ident}: which rules (by content
        # identity) each activated engine knew.  The SegmentStore reads this
        # at seal time to derive the per-segment ``rules_known`` coverage
        # metadata (consistency propagation, paper §3.4 step 4).
        self.version_rules: dict = {}
        self._install(bundle, version_id=0)

    # -- data topology ---------------------------------------------------
    def process(self, batch: RecordBatch) -> RecordBatch:
        """Match + enrich (and maybe filter) one batch."""
        active = self._active                      # single read: swap-safe
        t0 = time.perf_counter()
        n = len(batch)
        W = active.bundle.words
        bm = np.zeros((n, W), np.uint32)
        for fieldname, engine in active.matchers.items():
            if fieldname == "*":
                cols = batch.text_fields
            elif fieldname in batch.columns:
                cols = (fieldname,)
            else:
                continue
            for c in cols:
                bm |= np.asarray(engine.match(batch.columns[c]))
        out = batch.with_column(ENRICH_COLUMN, bm)
        out = out.with_column(
            ENGINE_VERSION_COLUMN,
            np.full(n, active.version_id, np.int32))
        matched = enrichment.any_match(bm)
        if self.mode == "filter":
            out = out.select(matched)
        with self._lock:
            self.stats.records_in += n
            self.stats.records_out += len(out)
            self.stats.records_matched += int(matched.sum())
            self.stats.batches += 1
            self.stats.match_seconds += time.perf_counter() - t0
        return out

    # -- control topology --------------------------------------------------
    def poll_updates(self) -> int:
        """Consume update notifications; fetch+validate+swap.  Returns the
        number of successful swaps performed (paper §3.4.2 steps 4-6)."""
        if self.bus is None or self.store is None:
            return 0
        group = f"matcher/{self.instance_id}"
        swaps = 0
        for msg in self.bus.poll(MATCHER_UPDATES, group):
            ok = False
            err = ""
            try:
                ref = ObjectRef.from_dict(msg.value["object_ref"])
                expect_version = msg.value["engine_version"]
                expect_checksum = msg.value["checksum"]
                data = self.store.get(ref, verify=True)           # sha256
                bundle = EngineBundle.deserialize(data, verify=True)
                if bundle.version != expect_version:
                    raise ValueError(
                        f"version mismatch: got {bundle.version}, "
                        f"expected {expect_version}")
                if bundle.checksum() != expect_checksum:
                    raise ValueError("bundle checksum != notification checksum")
                self.swap(bundle)
                swaps += 1
                ok = True
            except Exception as e:  # noqa: BLE001 — ack failure, keep serving
                err = str(e)
            self.bus.commit(MATCHER_UPDATES, group, msg.offset)
            ack = {"instance": self.instance_id,
                   "engine_version": msg.value.get("engine_version"),
                   "ok": ok}
            if not ok:
                ack["error"] = err
                # echo the artifact reference so operators can tell WHICH
                # object failed fetch/validation from the ack alone
                ack["object_ref"] = msg.value.get("object_ref")
            self.bus.publish(MATCHER_ACKS, ack)
        return swaps

    def swap(self, bundle: EngineBundle) -> None:
        """Hot swap: build matchers off-path, then flip the reference."""
        with self._swap_lock:
            vid = self._active.version_id + 1
            self._install(bundle, version_id=vid)
            with self._lock:
                self.stats.swaps += 1

    # -- introspection -------------------------------------------------------
    @property
    def active_version(self) -> str:
        return self._active.bundle.version

    @property
    def active_version_id(self) -> int:
        return self._active.version_id

    @property
    def num_rules(self) -> int:
        return self._active.bundle.num_rules

    def _install(self, bundle: EngineBundle, version_id: int) -> None:
        matchers = build_matchers(bundle, backend=self.backend,
                                  block_n=self.block_n,
                                  interpret=self.interpret)
        idents = (ruleset_idents(bundle.ruleset()) if bundle.ruleset_json
                  else {})
        self.version_rules[version_id] = idents
        self._active = _Active(bundle=bundle, matchers=matchers,
                               version_id=version_id,
                               activated_at=time.time())
        self.stats.versions[bundle.version] = self._active.activated_at
