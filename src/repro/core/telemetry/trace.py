"""Context-manager spans with parent/child linkage, exportable as Chrome
trace-event JSON (chrome://tracing and https://ui.perfetto.dev both load it).

The point is *timeline visibility*: ingest double-buffering overlap (a
``ingest/generate`` span running while the previous batch's device match is
still in flight), stacked query dispatches across shard threads, and
maintenance backfill cycles all land on ONE timeline, one track per thread.

  * ``span(name, **args)`` — context manager; on exit one complete event
    (``ph: "X"``) is appended to a bounded ring buffer (old spans fall off,
    memory never grows);
  * parent/child linkage rides a thread-local stack: each finished span
    records its parent's id in ``args.parent`` (the Chrome viewer already
    nests same-thread spans by ts/dur; the explicit id survives export);
  * ``export_chrome_trace()`` -> the trace-event JSON object; timestamps
    are microseconds since tracer start, durations microseconds, as the
    format requires.

A span is two ``perf_counter`` reads, two list ops, and one locked deque
append — cheap enough for per-batch (NOT per-record) hot-path use; the
``telemetry_overhead`` bench lane measures exactly this budget.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque

from repro.core.telemetry import metrics


class _Span:
    """One in-flight span (the context manager ``Tracer.span`` returns)."""

    __slots__ = ("tracer", "name", "cat", "args", "span_id", "parent_id",
                 "_t0")

    def __init__(self, tracer, name, cat, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.span_id = 0
        self.parent_id = 0
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        t = self.tracer
        self._t0 = t._clock()
        stack = t._stack()
        self.parent_id = stack[-1] if stack else 0
        self.span_id = t._next_id()
        stack.append(self.span_id)
        return self

    def __exit__(self, *exc) -> None:
        t = self.tracer
        t1 = t._clock()
        stack = t._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        args = dict(self.args) if self.args else {}
        args["id"] = self.span_id
        if self.parent_id:
            args["parent"] = self.parent_id
        t._record({
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": (self._t0 - t._epoch) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": t._pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": args,
        })


class _NullSpan:
    """Returned while telemetry is disabled: costs one attribute check."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL = _NullSpan()


class Tracer:
    """Bounded ring buffer of finished spans.  ``capacity`` bounds memory;
    the newest spans win (a long benchmark keeps its tail, which is what a
    timeline of "what was the system doing" wants)."""

    def __init__(self, *, capacity: int = 16384, clock=time.perf_counter):
        self._events = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._local = threading.local()
        self._clock = clock
        self._epoch = clock()
        self._pid = os.getpid()
        self._id_lock = threading.Lock()
        self._id = 0
        self.dropped = 0            # spans that pushed older ones off

    # -- internals ---------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        with self._id_lock:
            self._id += 1
            return self._id

    def _record(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    # -- public ------------------------------------------------------------
    def span(self, name: str, *, cat: str = "fluxsieve", **args):
        """Context manager timing one region.  ``args`` must be JSON-able
        scalars (they land verbatim in the exported trace)."""
        if not metrics.enabled():
            return _NULL
        return _Span(self, name, cat, args)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def spans(self) -> list:
        with self._lock:
            return list(self._events)

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._epoch = self._clock()

    def export_chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object (load in Perfetto or
        chrome://tracing).  ``displayTimeUnit`` and per-event ``ph``/``ts``/
        ``dur`` follow the trace-event format spec."""
        with self._lock:
            events = [dict(ev) for ev in self._events]
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"source": "fluxsieve.telemetry",
                          "spans_dropped": self.dropped},
        }


# -- the process-wide default tracer -----------------------------------------
TRACER = Tracer()


def current_span_id() -> int:
    """Innermost open span's id on THIS thread (0 when none) — the
    histogram exemplar source: a latency observed inside a span links the
    bucket back to the exact span that produced it."""
    st = TRACER._stack()
    return st[-1] if st else 0


# histograms capture exemplars through this hook (registered here, not in
# metrics.py, to keep metrics import-independent of the tracer)
metrics.set_exemplar_source(current_span_id)


def span(name: str, *, cat: str = "fluxsieve", **args):
    return TRACER.span(name, cat=cat, **args)


def export_chrome_trace() -> dict:
    return TRACER.export_chrome_trace()


def reset() -> None:
    TRACER.reset()
