"""Process-wide metrics registry — counters, gauges, log2 latency histograms.

FluxSieve's headline claim is speedups at *very low computational overhead*
(paper §1, §5); a system built to serve observability data must itself be
observable, and cheaply so.  This module is the single registry every plane
(ingest, match, query, arrangement, maintenance) reports through:

  * **Counter** — monotonic float/int accumulator (``_total`` suffix by
    convention);
  * **Gauge** — settable level (device bytes resident, live arrangements),
    with ``track_max`` for high-water marks;
  * **Histogram** — fixed-bucket base-2 latency histogram: one bucket per
    binary exponent of the observed value, so p50/p99 come from bucket
    interpolation **without retaining samples** and an ``observe`` is one
    lock + two adds, never an allocation.

Hot-path discipline: call sites cache the metric object at import time
(``_D2H = telemetry.counter(...)``) so the hot path pays one short
per-metric lock, not a registry lookup.  ``reset()`` zeroes values *in
place* — cached handles stay valid across benchmark suites and tests.
``set_enabled(False)`` turns every mutation into an early return; the
``telemetry_overhead`` bench lane A/Bs exactly this switch.

Metric naming scheme (see docs/TELEMETRY.md): ``fluxsieve_<plane>_<what>``
with unit suffixes (``_total``, ``_bytes_total``, ``_seconds``); the plane
token is one of ``ingest | match | query | arrangement | maintenance |
store | events``.
"""
from __future__ import annotations

import math
import threading

# Histogram bucket span: 2^-20 s (~1 us) .. 2^10 s (~17 min).  Values
# outside clamp into the edge buckets; min/max are tracked exactly so
# clamping never distorts the reported extremes.
LOG2_MIN = -20
LOG2_MAX = 10
NUM_BUCKETS = LOG2_MAX - LOG2_MIN + 1   # bucket i covers [2^(MIN+i), 2^(MIN+i+1))

_ENABLED = True

# Exemplar capture (off by default — one extra callable per observe when
# on): each histogram bucket remembers ONE (span_id, value) witness, so a
# slow p99 bucket links straight to the trace span that caused it.  The
# source callable is registered by the tracer (``trace.current_span_id``)
# to avoid a circular import; exports render OpenMetrics exemplar syntax.
_EXEMPLARS = False
_EXEMPLAR_SOURCE = None


def set_exemplars(flag: bool) -> None:
    """Enable/disable histogram exemplar capture (and rendering)."""
    global _EXEMPLARS
    _EXEMPLARS = bool(flag)


def exemplars_enabled() -> bool:
    return _EXEMPLARS


def set_exemplar_source(fn) -> None:
    """Register the zero-arg span-id source (the tracer installs its
    ``current_span_id`` at import; 0/None means "no span open")."""
    global _EXEMPLAR_SOURCE
    _EXEMPLAR_SOURCE = fn


def set_enabled(flag: bool) -> None:
    """Globally enable/disable telemetry mutation (spans and events consult
    this too).  Reads (snapshots, exports) always work."""
    global _ENABLED
    _ENABLED = bool(flag)


def enabled() -> bool:
    return _ENABLED


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in (labels or {}).items()))


class Counter:
    """Monotonic accumulator.  ``inc`` returns the new value (callers that
    maintain a paired high-water gauge use it)."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels or {})
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n=1):
        if not _ENABLED:
            return self._value
        with self._lock:
            self._value += n
            return self._value

    @property
    def value(self):
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0

    def _snapshot(self) -> dict:
        return {"labels": self.labels, "value": self._value}


class Gauge:
    """Settable level.  ``inc``/``dec`` adjust (process-wide aggregation
    across several owners of one resource); ``track_max`` ratchets — the
    peak-gauge idiom (``g_peak.track_max(g.inc(n))``)."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels or {})
        self._value = 0
        self._lock = threading.Lock()

    def set(self, v) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self._value = v

    def inc(self, n=1):
        if not _ENABLED:
            return self._value
        with self._lock:
            self._value += n
            return self._value

    def dec(self, n=1):
        return self.inc(-n)

    def track_max(self, v) -> None:
        if not _ENABLED:
            return
        with self._lock:
            if v > self._value:
                self._value = v

    @property
    def value(self):
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0

    def _snapshot(self) -> dict:
        return {"labels": self.labels, "value": self._value}


class Histogram:
    """Fixed-bucket base-2 histogram: percentiles without sample retention.

    ``observe(v)`` buckets ``v`` (seconds) by binary exponent — O(1), no
    allocation, one short lock.  ``quantile(q)`` walks the cumulative
    counts and interpolates *geometrically* inside the target bucket
    (buckets are exponential, so the geometric mean is the unbiased
    midpoint); the result is exact to within one octave and clamped to the
    exact observed [min, max]."""

    __slots__ = ("name", "labels", "_counts", "_count", "_sum",
                 "_min", "_max", "_exemplars", "_lock")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels or {})
        self._counts = [0] * NUM_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        # one (span_id, value) witness per bucket, kept only while exemplar
        # capture is on (None entries otherwise — zero steady-state cost)
        self._exemplars = [None] * NUM_BUCKETS
        self._lock = threading.Lock()

    @staticmethod
    def bucket_index(v: float) -> int:
        if v <= 0.0:
            return 0
        e = math.frexp(v)[1] - 1        # floor(log2 v)
        return min(max(e - LOG2_MIN, 0), NUM_BUCKETS - 1)

    @staticmethod
    def bucket_bounds(i: int) -> tuple:
        """(lo, hi) of bucket ``i`` in seconds."""
        return (2.0 ** (LOG2_MIN + i), 2.0 ** (LOG2_MIN + i + 1))

    def observe(self, v: float) -> None:
        if not _ENABLED:
            return
        v = float(v)
        i = self.bucket_index(v)
        exemplar = None
        if _EXEMPLARS and _EXEMPLAR_SOURCE is not None:
            sid = _EXEMPLAR_SOURCE()
            if sid:
                exemplar = (int(sid), v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if exemplar is not None:
                self._exemplars[i] = exemplar

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (0..1); NaN when empty."""
        with self._lock:
            if self._count == 0:
                return math.nan
            target = q * self._count
            cum = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                if cum + c >= target:
                    lo, _ = self.bucket_bounds(i)
                    frac = (target - cum) / c
                    est = lo * (2.0 ** frac)
                    return min(max(est, self._min), self._max)
                cum += c
            return self._max

    def _reset(self) -> None:
        with self._lock:
            self._counts = [0] * NUM_BUCKETS
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf
            self._exemplars = [None] * NUM_BUCKETS

    def _snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            count, total = self._count, self._sum
            mn = self._min if count else None
            mx = self._max if count else None
            exemplars = list(self._exemplars)
        out = {"labels": self.labels, "count": count, "sum": total,
               "min": mn, "max": mx}
        if count:
            out["p50"] = self.quantile(0.50)
            out["p90"] = self.quantile(0.90)
            out["p99"] = self.quantile(0.99)
            out["buckets"] = {f"{self.bucket_bounds(i)[1]:.9g}": c
                              for i, c in enumerate(counts) if c}
            ex = {f"{self.bucket_bounds(i)[1]:.9g}":
                  {"span_id": e[0], "value": e[1]}
                  for i, e in enumerate(exemplars)
                  if e is not None and counts[i]}
            if ex:
                out["exemplars"] = ex
        return out


class MetricsRegistry:
    """Thread-safe get-or-create registry of labeled metrics.  One
    process-wide default instance (module functions below) is the normal
    interface; private registries exist for tests."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics = {}      # (kind, name, label key) -> metric
        self._help = {}         # name -> help string

    def _get(self, kind: str, name: str, labels: dict, help: str):
        key = (kind, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                for k, n, _ in self._metrics:
                    if n == name and k != kind:
                        raise ValueError(
                            f"metric {name!r} already registered as {k}")
                m = self._KINDS[kind](name, labels)
                self._metrics[key] = m
                if help:
                    self._help.setdefault(name, help)
        return m

    def counter(self, name: str, *, labels: dict = None,
                help: str = "") -> Counter:
        return self._get("counter", name, labels, help)

    def gauge(self, name: str, *, labels: dict = None,
              help: str = "") -> Gauge:
        return self._get("gauge", name, labels, help)

    def histogram(self, name: str, *, labels: dict = None,
                  help: str = "") -> Histogram:
        return self._get("histogram", name, labels, help)

    def reset(self) -> None:
        """Zero every metric IN PLACE — handles cached by call sites stay
        valid (benchmark suites isolate this way)."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m._reset()

    def collect(self) -> list:
        """-> [(kind, name, metric)] sorted by (name, labels)."""
        with self._lock:
            items = sorted(self._metrics.items(),
                           key=lambda kv: (kv[0][1], kv[0][2], kv[0][0]))
        return [(kind, name, m) for (kind, name, _), m in items]

    def help_text(self, name: str) -> str:
        return self._help.get(name, "")

    def snapshot(self) -> dict:
        """JSON-able {"counters": {name: [series...]}, "gauges": ...,
        "histograms": ...} — the per-suite provenance block BENCH_*.json
        embeds and the five-plane assertion in tests reads."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for kind, name, m in self.collect():
            out[kind + "s"].setdefault(name, []).append(m._snapshot())
        return out


# -- the process-wide default registry ---------------------------------------
REGISTRY = MetricsRegistry()


def counter(name: str, *, labels: dict = None, help: str = "") -> Counter:
    return REGISTRY.counter(name, labels=labels, help=help)


def gauge(name: str, *, labels: dict = None, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, labels=labels, help=help)


def histogram(name: str, *, labels: dict = None, help: str = "") -> Histogram:
    return REGISTRY.histogram(name, labels=labels, help=help)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
