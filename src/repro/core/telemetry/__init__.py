"""FluxSieve's unified telemetry plane.

One process-wide registry of counters/gauges/histograms, one span tracer
with Chrome-trace export, one structured event log, and the exporters that
serialize all three.  Every plane (ingest, match, query, arrangement,
maintenance) reports through this package; see docs/TELEMETRY.md for the
naming scheme and snapshot schema.

Typical call-site idiom — cache handles at import time, mutate on the hot
path, never look up:

    from repro.core.telemetry import metrics, trace

    _DISPATCH = metrics.counter("fluxsieve_match_dispatch_total",
                                help="Fused device dispatches.")
    ...
    with trace.span("match/dispatch", batch=n):
        _DISPATCH.inc()
"""
from repro.core.telemetry import events, export, metrics, trace
from repro.core.telemetry.events import emit
from repro.core.telemetry.export import (merge_dumps, merge_snapshots,
                                         prometheus_text, snapshot,
                                         write_dump)
from repro.core.telemetry.metrics import (counter, enabled, gauge, histogram,
                                          set_enabled, set_exemplars)
from repro.core.telemetry.trace import export_chrome_trace, span


def reset() -> None:
    """Zero all metrics in place, clear spans and events.  Cached metric
    handles stay valid (benchmark suites and tests isolate this way)."""
    metrics.reset()
    trace.reset()
    events.reset()


def suppressed(site: str, err: BaseException) -> None:
    """Account an intentionally swallowed error.  Every ``except ...: pass``
    style handler routes through here so suppressed failures stay
    observable: bumps ``fluxsieve_errors_suppressed_total{site}`` and emits
    an ``error_suppressed`` event.  Never raises (safe from ``__del__`` at
    interpreter teardown, when the registry may already be torn down)."""
    try:
        metrics.counter("fluxsieve_errors_suppressed_total",
                        labels={"site": site},
                        help="Errors intentionally swallowed, by site.").inc()
        emit("error_suppressed", plane=site.split(".", 1)[0], site=site,
             error=f"{type(err).__name__}: {err}")
    except Exception:       # noqa: BLE001 — observability must not throw
        pass


__all__ = [
    "counter", "gauge", "histogram", "enabled", "set_enabled",
    "set_exemplars", "span", "export_chrome_trace", "emit", "suppressed",
    "prometheus_text", "snapshot", "write_dump", "merge_dumps",
    "merge_snapshots", "reset", "metrics", "trace", "events", "export",
]
