"""Structured event log for the control-plane moments that matter.

Counters say *how many*; the event log says *what happened, when, with what
identifiers* — epoch publishes, lease acquisitions and fencing rejections,
manifest commits, crash-recovery actions, GC sweeps.  Each event is one
JSON-able dict in a bounded ring buffer:

    {"ts": <unix seconds>, "kind": "fencing_rejection", "plane":
     "maintenance", "worker": "bf-1", "epoch": 3, ...}

Every ``emit`` also bumps ``fluxsieve_events_total{kind=...}`` so the
aggregate rate shows up in the metrics snapshot even after the ring has
wrapped.  The log is capped (default 4096 events) — a stuck retry loop
cannot grow memory; ``dropped`` counts what fell off.
"""
from __future__ import annotations

import threading
import time
from collections import deque

from repro.core.telemetry import metrics


class EventLog:
    def __init__(self, *, capacity: int = 4096):
        self._events = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self.dropped = 0
        self._counter = metrics.REGISTRY

    def emit(self, kind: str, *, plane: str = "", **fields) -> None:
        """Record one structured event.  ``fields`` must be JSON-able."""
        if not metrics.enabled():
            return
        ev = {"ts": time.time(), "kind": kind, "plane": plane}
        ev.update(fields)
        metrics.counter("fluxsieve_events_total",
                        labels={"kind": kind},
                        help="Structured events emitted, by kind.").inc()
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    def events(self, *, kind: str = None) -> list:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        return evs

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0


# -- the process-wide default event log ---------------------------------------
EVENTS = EventLog()


def emit(kind: str, *, plane: str = "", **fields) -> None:
    EVENTS.emit(kind, plane=plane, **fields)


def events(*, kind: str = None) -> list:
    return EVENTS.events(kind=kind)


def reset() -> None:
    EVENTS.reset()
