"""Exporters: Prometheus text exposition format + JSON snapshot + trace dump.

``prometheus_text()`` renders the whole registry in the text format every
Prometheus-compatible scraper understands (`# HELP` / `# TYPE` headers,
``name{label="v"} value`` samples, histograms as cumulative ``_bucket{le=}``
series plus ``_sum``/``_count``).  ``snapshot()`` is the JSON-able dict the
benchmarks embed per suite; ``write_dump(dir)`` writes all three artifacts
(``metrics.prom``, ``snapshot.json``, ``trace.json``) for offline
inspection — the trace loads directly in https://ui.perfetto.dev.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core.telemetry import events as _events
from repro.core.telemetry import metrics
from repro.core.telemetry import trace as _trace


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _labels_text(labels: dict, extra: dict = None) -> str:
    pairs = dict(labels or {})
    if extra:
        pairs.update(extra)
    if not pairs:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(pairs.items()))
    return "{" + body + "}"


def prometheus_text(registry: "metrics.MetricsRegistry" = None) -> str:
    """Render the registry in Prometheus text exposition format."""
    reg = registry if registry is not None else metrics.REGISTRY
    # group series under one HELP/TYPE header per metric name
    by_name = {}
    for kind, name, m in reg.collect():
        by_name.setdefault(name, (kind, []))[1].append(m)
    lines = []
    for name in sorted(by_name):
        kind, series = by_name[name]
        help_text = reg.help_text(name)
        if help_text:
            lines.append(f"# HELP {name} {_escape(help_text)}")
        lines.append(f"# TYPE {name} {kind}")
        for m in series:
            if kind in ("counter", "gauge"):
                lines.append(f"{name}{_labels_text(m.labels)} {m.value}")
            else:
                cum = 0
                with m._lock:
                    counts = list(m._counts)
                    count, total = m._count, m._sum
                for i, c in enumerate(counts):
                    if not c:
                        continue
                    cum += c
                    le = f"{m.bucket_bounds(i)[1]:.9g}"
                    lines.append(f"{name}_bucket"
                                 f"{_labels_text(m.labels, {'le': le})} {cum}")
                lines.append(f"{name}_bucket"
                             f"{_labels_text(m.labels, {'le': '+Inf'})} "
                             f"{count}")
                lines.append(f"{name}_sum{_labels_text(m.labels)} {total}")
                lines.append(f"{name}_count{_labels_text(m.labels)} {count}")
    return "\n".join(lines) + "\n"


def snapshot() -> dict:
    """Full JSON-able telemetry snapshot: metrics + recent events."""
    out = metrics.snapshot()
    out["events"] = _events.events()
    out["generated_at"] = time.time()
    return out


def write_dump(directory, *, prefix: str = "") -> dict:
    """Write metrics.prom, snapshot.json, and trace.json into ``directory``.
    Returns {artifact name: path} for logging."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    paths = {}
    prom = d / f"{prefix}metrics.prom"
    prom.write_text(prometheus_text())
    paths["metrics"] = str(prom)
    snap = d / f"{prefix}snapshot.json"
    snap.write_text(json.dumps(snapshot(), indent=2, default=str))
    paths["snapshot"] = str(snap)
    tr = d / f"{prefix}trace.json"
    tr.write_text(json.dumps(_trace.export_chrome_trace()))
    paths["trace"] = str(tr)
    return paths
