"""Exporters: Prometheus text exposition format + JSON snapshot + trace dump.

``prometheus_text()`` renders the whole registry in the text format every
Prometheus-compatible scraper understands (`# HELP` / `# TYPE` headers,
``name{label="v"} value`` samples, histograms as cumulative ``_bucket{le=}``
series plus ``_sum``/``_count``).  When exemplar capture is on
(``metrics.set_exemplars(True)``) bucket lines carry OpenMetrics exemplar
suffixes — ``... 42 # {span_id="1234"} 0.0371`` — linking a bucket to one
trace span that landed in it.  ``snapshot()`` is the JSON-able dict the
benchmarks embed per suite; ``write_dump(dir, prefix=...)`` writes all
three artifacts (``metrics.prom``, ``snapshot.json``, ``trace.json``) for
offline inspection — the trace loads directly in https://ui.perfetto.dev.

Multi-process telemetry: registries are per-process, so the process worker
model dumps with per-worker prefixes (``maint-0.metrics.prom`` ...) and
``merge_dumps(dir)`` folds every per-process snapshot/trace in a directory
into ONE ``merged.*`` artifact set: counters and histogram buckets sum,
gauges sum (per-process levels of one fleet add), min/max merge exactly,
quantiles re-interpolate from the merged buckets, and traces concatenate —
distinct pids give each process its own Perfetto track.
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path

from repro.core.telemetry import events as _events
from repro.core.telemetry import metrics
from repro.core.telemetry import trace as _trace


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _labels_text(labels: dict, extra: dict = None) -> str:
    pairs = dict(labels or {})
    if extra:
        pairs.update(extra)
    if not pairs:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(pairs.items()))
    return "{" + body + "}"


def _exemplar_text(exemplar) -> str:
    """OpenMetrics exemplar suffix for a bucket line ('' when absent)."""
    if not exemplar:
        return ""
    sid, value = exemplar
    return f' # {{span_id="{int(sid)}"}} {float(value):.9g}'


def prometheus_text(registry: "metrics.MetricsRegistry" = None, *,
                    exemplars: bool = None) -> str:
    """Render the registry in Prometheus text exposition format.
    ``exemplars`` defaults to the global capture flag
    (``metrics.exemplars_enabled()``)."""
    reg = registry if registry is not None else metrics.REGISTRY
    if exemplars is None:
        exemplars = metrics.exemplars_enabled()
    # group series under one HELP/TYPE header per metric name
    by_name = {}
    for kind, name, m in reg.collect():
        by_name.setdefault(name, (kind, []))[1].append(m)
    lines = []
    for name in sorted(by_name):
        kind, series = by_name[name]
        help_text = reg.help_text(name)
        if help_text:
            lines.append(f"# HELP {name} {_escape(help_text)}")
        lines.append(f"# TYPE {name} {kind}")
        for m in series:
            if kind in ("counter", "gauge"):
                lines.append(f"{name}{_labels_text(m.labels)} {m.value}")
            else:
                cum = 0
                with m._lock:
                    counts = list(m._counts)
                    count, total = m._count, m._sum
                    witnesses = list(m._exemplars)
                for i, c in enumerate(counts):
                    if not c:
                        continue
                    cum += c
                    le = f"{m.bucket_bounds(i)[1]:.9g}"
                    ex = (_exemplar_text(witnesses[i]) if exemplars else "")
                    lines.append(f"{name}_bucket"
                                 f"{_labels_text(m.labels, {'le': le})} "
                                 f"{cum}{ex}")
                lines.append(f"{name}_bucket"
                             f"{_labels_text(m.labels, {'le': '+Inf'})} "
                             f"{count}")
                lines.append(f"{name}_sum{_labels_text(m.labels)} {total}")
                lines.append(f"{name}_count{_labels_text(m.labels)} {count}")
    return "\n".join(lines) + "\n"


def snapshot() -> dict:
    """Full JSON-able telemetry snapshot: metrics + recent events."""
    out = metrics.snapshot()
    out["events"] = _events.events()
    out["generated_at"] = time.time()
    return out


def write_dump(directory, *, prefix: str = "") -> dict:
    """Write metrics.prom, snapshot.json, and trace.json into ``directory``.
    ``prefix`` namespaces one process's artifacts (``maint-0.metrics.prom``)
    so N processes can dump into one directory for ``merge_dumps``.
    Returns {artifact name: path} for logging."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    paths = {}
    prom = d / f"{prefix}metrics.prom"
    prom.write_text(prometheus_text())
    paths["metrics"] = str(prom)
    snap = d / f"{prefix}snapshot.json"
    snap.write_text(json.dumps(snapshot(), indent=2, default=str))
    paths["snapshot"] = str(snap)
    tr = d / f"{prefix}trace.json"
    tr.write_text(json.dumps(_trace.export_chrome_trace()))
    paths["trace"] = str(tr)
    return paths


# -- multi-process merge ------------------------------------------------------

def _merge_series(kind: str, into: list, series: list) -> None:
    """Merge one snapshot's series list into the accumulator, matching on
    label sets."""
    def key(s):
        return tuple(sorted((str(k), str(v))
                            for k, v in (s.get("labels") or {}).items()))

    index = {key(s): s for s in into}
    for s in series:
        acc = index.get(key(s))
        if acc is None:
            into.append(json.loads(json.dumps(s)))   # deep copy
            index[key(s)] = into[-1]
            continue
        if kind in ("counters", "gauges"):
            acc["value"] = acc.get("value", 0) + s.get("value", 0)
            continue
        acc["count"] = acc.get("count", 0) + s.get("count", 0)
        acc["sum"] = acc.get("sum", 0.0) + s.get("sum", 0.0)
        for bound in ("min", "max"):
            vals = [v for v in (acc.get(bound), s.get(bound))
                    if v is not None]
            acc[bound] = ((min(vals) if bound == "min" else max(vals))
                          if vals else None)
        buckets = dict(acc.get("buckets") or {})
        for le, c in (s.get("buckets") or {}).items():
            buckets[le] = buckets.get(le, 0) + c
        if buckets:
            acc["buckets"] = buckets
        exemplars = dict(acc.get("exemplars") or {})
        for le, e in (s.get("exemplars") or {}).items():
            exemplars.setdefault(le, e)     # first witness per bucket wins
        if exemplars:
            acc["exemplars"] = exemplars


def _requantile(acc: dict) -> None:
    """Recompute p50/p90/p99 of a merged histogram series by geometric
    interpolation over the merged buckets (the same estimator the live
    Histogram uses), clamped to the merged exact [min, max]."""
    count = acc.get("count", 0)
    buckets = acc.get("buckets") or {}
    if not count or not buckets:
        for q in ("p50", "p90", "p99"):
            acc.pop(q, None)
        return
    ordered = sorted(((float(le), c) for le, c in buckets.items()))
    for q, label in ((0.50, "p50"), (0.90, "p90"), (0.99, "p99")):
        target = q * count
        cum = 0
        est = ordered[-1][0]
        for hi, c in ordered:
            if cum + c >= target:
                lo = hi / 2.0
                frac = (target - cum) / c
                est = lo * (2.0 ** frac)
                break
            cum += c
        mn = acc.get("min")
        mx = acc.get("max")
        if mn is not None:
            est = max(est, mn)
        if mx is not None:
            est = min(est, mx)
        acc[label] = est


def merge_snapshots(snaps: list) -> dict:
    """Fold per-process snapshots into one: counters/histogram buckets sum,
    gauges sum (each process's level of one shared fleet), min/max merge
    exactly, quantiles re-interpolate, events concatenate."""
    merged = {"counters": {}, "gauges": {}, "histograms": {},
              "events": [], "generated_at": 0.0}
    for snap in snaps:
        for kind in ("counters", "gauges", "histograms"):
            for name, series in (snap.get(kind) or {}).items():
                _merge_series(kind, merged[kind].setdefault(name, []),
                              series)
        merged["events"].extend(snap.get("events") or [])
        merged["generated_at"] = max(merged["generated_at"],
                                     float(snap.get("generated_at") or 0.0))
    for series in merged["histograms"].values():
        for acc in series:
            _requantile(acc)
    return merged


def prometheus_from_snapshot(snap: dict, *, exemplars: bool = True) -> str:
    """Render a (possibly merged) snapshot dict in Prometheus text format —
    same grammar ``scripts/check_prom_format.py`` validates for the live
    registry rendering."""
    lines = []
    kinds = (("counters", "counter"), ("gauges", "gauge"),
             ("histograms", "histogram"))
    names = sorted({name for key, _ in kinds
                    for name in (snap.get(key) or {})})
    by_name = {}
    for key, kind in kinds:
        for name, series in (snap.get(key) or {}).items():
            by_name[name] = (kind, series)
    for name in names:
        kind, series = by_name[name]
        lines.append(f"# TYPE {name} {kind}")
        for s in series:
            labels = s.get("labels") or {}
            if kind in ("counter", "gauge"):
                lines.append(f"{name}{_labels_text(labels)} "
                             f"{s.get('value', 0)}")
                continue
            count = s.get("count", 0)
            buckets = sorted(((float(le), le, c) for le, c
                              in (s.get("buckets") or {}).items()))
            witnesses = s.get("exemplars") or {}
            cum = 0
            for _, le, c in buckets:
                cum += c
                ex = ""
                if exemplars and le in witnesses:
                    w = witnesses[le]
                    ex = _exemplar_text((w["span_id"], w["value"]))
                lines.append(f"{name}_bucket"
                             f"{_labels_text(labels, {'le': le})} {cum}{ex}")
            lines.append(f"{name}_bucket"
                         f"{_labels_text(labels, {'le': '+Inf'})} {count}")
            lines.append(f"{name}_sum{_labels_text(labels)} "
                         f"{s.get('sum', 0.0)}")
            lines.append(f"{name}_count{_labels_text(labels)} {count}")
    return "\n".join(lines) + "\n"


def merge_dumps(directory, *, out_prefix: str = "merged.") -> dict:
    """Fold every per-process dump in ``directory`` (all ``*snapshot.json``
    / ``*trace.json``, prefixed or not, except previous merge outputs)
    into ``merged.metrics.prom`` / ``merged.snapshot.json`` /
    ``merged.trace.json``.  One snapshot then covers every plane across
    every worker process; the merged trace shows one Perfetto track group
    per pid.  Returns {artifact name: path}."""
    d = Path(directory)
    snaps = []
    for p in sorted(d.glob("*snapshot.json")):
        if p.name.startswith(out_prefix):
            continue
        try:
            snaps.append(json.loads(p.read_text()))
        except ValueError:
            continue
    merged = merge_snapshots(snaps)
    trace_events = []
    dropped = 0
    for p in sorted(d.glob("*trace.json")):
        if p.name.startswith(out_prefix):
            continue
        try:
            tr = json.loads(p.read_text())
        except ValueError:
            continue
        trace_events.extend(tr.get("traceEvents") or [])
        dropped += int((tr.get("otherData") or {}).get("spans_dropped", 0))
    paths = {}
    prom = d / f"{out_prefix}metrics.prom"
    prom.write_text(prometheus_from_snapshot(merged))
    paths["metrics"] = str(prom)
    snap = d / f"{out_prefix}snapshot.json"
    snap.write_text(json.dumps(merged, indent=2, default=str))
    paths["snapshot"] = str(snap)
    tr_path = d / f"{out_prefix}trace.json"
    tr_path.write_text(json.dumps({
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        "otherData": {"source": "fluxsieve.telemetry.merged",
                      "spans_dropped": dropped,
                      "processes": len(snaps)},
    }))
    paths["trace"] = str(tr_path)
    return paths
