"""Standing queries — O(delta) incremental view maintenance over the
epoch feed.

FluxSieve precomputes *filters* at ingest time; this module keeps *query
results* incrementally maintained (McSherry et al., *Shared Arrangements*;
Elghandour et al., *Incremental Techniques for Large-Scale Dynamic Query
Processing*).  A client registers a query once; the system materializes
the initial result through the normal planner/executor, then subscribes to
``SegmentStore.subscribe_epochs`` and folds each :class:`EpochDelta` —
new seals, backfill installs, compaction replaces, retention retires —
into a maintained per-segment partial-result map.  ``refresh()`` then
answers a dashboard-style repeated query in **O(changed segments)**
instead of O(all segments): unchanged segments contribute their folded
count (and row ids, for copy mode) without touching the planner, the
executor, or any column.

Invariants, each asserted in tests:

  * **bit-identical to the pull path** — after every epoch a refresh
    returns exactly the count (and records) a cold ``engine.execute``
    (numpy-oracle lane included) would compute, across interleaved
    seal / backfill / compaction / retention histories;
  * **O(changed segments) per epoch** — a fold classifies and executes
    only the delta's segments (plus any previously failed ones),
    re-using the planner's per-segment path classes and the shared
    ``ArrangementStore`` leases, so an incremental re-evaluation of a
    swapped segment is one small stacked dispatch, not a re-plan of the
    store; token comparison (``Segment.meta_token`` vs the folded
    partial's token) makes duplicated deliveries and already-folded
    epochs free;
  * **honest degradation** — a fold that faults (``standing.fold``
    injection site) marks exactly its segments failed: ``refresh()``
    reports ``partial=True`` with per-segment coverage, and the next
    epoch (or the refresh itself) heals the failed set by refolding it;
  * **cold-run transparency** — ``drop`` epochs (cache drops) fold
    nothing: they invalidate derived caches, not results, and re-warming
    them would silently undo the cold-run semantics benchmarks rely on.

``QueryEngine.register_standing`` is the entry point; the engine owns one
:class:`StandingRegistry` that fans every delta out to its standing
queries.  Sharded engines fold through their ``ShardedQueryExecutor``, so
a wide delta (compaction rewriting many segments) re-evaluates across the
shard pool with the same partial/coverage semantics as pull queries.
"""
from __future__ import annotations

import threading
import time

from repro.core import faults, telemetry
from repro.core.query.engine import QueryResult, filter_expired
from repro.core.query.planner import (FULL_SCAN, PRUNED, TEXT_INDEX,
                                      PhysicalPlan, SegmentTask)

import numpy as np

FOLD_KINDS = ("seal", "update", "replace", "retire", "heal", "initial")

_REGISTERED = telemetry.counter(
    "fluxsieve_standing_registered_total",
    help="Standing queries registered over the lifetime of the process.")
_ACTIVE = telemetry.gauge(
    "fluxsieve_standing_active",
    help="Standing queries currently maintained.")
_FOLDS = {
    k: telemetry.counter("fluxsieve_standing_folds_total",
                         labels={"kind": k},
                         help="Epoch-delta folds applied to standing "
                              "queries, by change kind.")
    for k in FOLD_KINDS
}
_SEGMENTS_FOLDED = telemetry.counter(
    "fluxsieve_standing_segments_folded_total",
    help="Segments (re-)evaluated by standing-query folds — the O(delta) "
         "work actually performed.")
_FOLD_FAILURES = telemetry.counter(
    "fluxsieve_standing_fold_failures_total",
    help="Folds that faulted; their segments degrade to failed/partial "
         "until a later fold heals them.")
_FOLD_SECONDS = telemetry.histogram(
    "fluxsieve_standing_fold_seconds",
    help="Latency of one epoch-delta fold (classify + execute + install).")
_REFRESH_SECONDS = telemetry.histogram(
    "fluxsieve_standing_refresh_seconds",
    help="Latency of a standing-query refresh (assembly; includes heal "
         "work when partials drifted).")


class _Partial:
    """One segment's folded contribution to the maintained result.

    ``token`` is the segment's ``meta_token()`` read before
    classification: a live partial whose token still matches the segment
    is provably current (meta-flips-last ordering on the writer side), so
    folds and refreshes skip it without reading any data."""

    __slots__ = ("token", "path_class", "count", "ids",
                 "scanned", "pruned", "fallback")

    def __init__(self, token, path_class, count, ids,
                 scanned, pruned, fallback):
        self.token = token
        self.path_class = path_class
        self.count = count
        self.ids = ids              # int32 row ids (copy mode / straddlers)
        self.scanned = scanned
        self.pruned = pruned
        self.fallback = fallback


class StandingQuery:
    """A maintained query result.  Obtain via
    ``engine.register_standing(query)``; call :meth:`refresh` for the
    current result; :meth:`close` stops maintenance.  Thread-safe —
    maintenance threads fold deltas while readers refresh."""

    def __init__(self, engine, query, *, path: str = "auto",
                 name: str = "", registry=None):
        self.engine = engine
        self.query = query
        self.name = name or (query.name or "standing")
        self._path_req = path
        self._registry = registry
        self._lock = threading.RLock()
        self._closed = False
        self._partials = {}         # segment_id -> _Partial
        self._failed = set()        # segment_ids whose last fold faulted
        self._pending_bytes = 0     # spill bytes folds read since last refresh
        self._sig = None            # (logical path, flux signature)
        self._chosen = None         # current logical path
        self.folds = 0              # applied folds (tests/benches)
        self.segments_folded = 0    # segments re-evaluated across all folds

    # -- epoch feed ----------------------------------------------------------
    def on_delta(self, delta) -> None:
        """Fold one :class:`EpochDelta` into the maintained result.
        ``drop`` deltas fold nothing (cache residency changed, results did
        not); every other kind re-evaluates exactly the affected segments
        plus any previously failed ones."""
        if self._closed or delta.kind == "drop":
            return
        with self._lock:
            if delta.kind in ("replace", "retire"):
                for sid in delta.segment_ids:
                    self._partials.pop(sid, None)
                    self._failed.discard(sid)
                dirty = list(delta.added)
            elif delta.kind == "seal":
                dirty = list(delta.added)
            else:               # update: resolve ids to live segments
                ids = set(delta.segment_ids)
                dirty = [s for s in self.engine.store.segments
                         if s.segment_id in ids]
            self._fold_locked(dirty, kind=delta.kind)

    # -- readers -------------------------------------------------------------
    def refresh(self) -> QueryResult:
        """The maintained result, assembled from folded partials in
        segment order.  O(changed segments): when every partial's token
        matches its segment (the steady state — folds ran on publish)
        assembly touches no planner, executor, or column; drifted or
        failed partials heal here first.  ``partial``/``coverage`` are
        honest: a segment whose fold faulted counts as unserved."""
        if self._closed:
            raise RuntimeError(f"standing query {self.name!r} is closed")
        t0 = time.perf_counter()
        with telemetry.span("standing/refresh", cat="standing",
                            query=self.name):
            with self._lock:
                segments = list(self.engine.store.segments)
                stale = [s for s in segments if self._needs_fold(s)]
                # always enters the fold (cheaply, when nothing is stale):
                # a rule rollout changes the plan signature WITHOUT any
                # segment epoch, and only the fold's signature check
                # catches that — refresh must never serve partials folded
                # under a superseded plan
                self._fold_locked(stale, kind="heal")
                res = self._assemble_locked(segments)
        res.latency_s = time.perf_counter() - t0
        _REFRESH_SECONDS.observe(res.latency_s)
        if res.segments_failed:
            telemetry.emit("standing_partial", plane="standing",
                           query=self.name, failed=res.segments_failed,
                           total=res.segments_total)
        return res

    def close(self) -> None:
        """Stop maintenance; later deltas are ignored."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._partials.clear()
            self._failed.clear()
        if self._registry is not None:
            self._registry.deregister(self.name)
        _ACTIVE.dec()

    # -- internals -----------------------------------------------------------
    def _needs_fold(self, seg) -> bool:
        if seg.segment_id in self._failed:
            return True
        p = self._partials.get(seg.segment_id)
        return p is None or p.token != seg.meta_token()

    def _plan_state(self):
        """(chosen logical path, flux plan, signature).  The signature
        captures everything that invalidates EVERY partial at once: the
        logical path flipping, or the mapper resolving the query onto a
        different rule set (updater activated a new engine version)."""
        engine = self.engine
        flux = None
        if self._path_req in ("auto", "fluxsieve") \
                and engine.mapper is not None:
            flux = engine.mapper.map(self.query)
        if self._path_req == "fluxsieve" and flux is None:
            raise ValueError("query not covered by registered rules; "
                             "no fluxsieve plan")
        chosen = engine.planner.logical_path(
            self.query, list(engine.store.segments),
            path=self._path_req, flux=flux)
        sig = (chosen, None if flux is None else
               (flux.rule_ids, flux.rule_idents, flux.min_version_id,
                tuple(len(m) for m in flux.masks)))
        return chosen, flux, sig

    def _fold_locked(self, dirty: list, kind: str) -> None:
        """Re-evaluate ``dirty`` segments (plus the failed set) against
        the current plan state and install their partials.  A fault here
        marks exactly this fold's segments failed — the maintained view
        degrades to honest partial coverage, never to a stale answer."""
        t0 = time.perf_counter()
        try:
            chosen, flux, sig = self._plan_state()
        except Exception as e:  # noqa: BLE001 — e.g. rules withdrawn
            # without a plan we cannot even tell which partials are still
            # valid: degrade the whole view, not just the delta
            self._mark_failed(list(self.engine.store.segments), kind, e)
            return
        if sig != self._sig:
            # the logical plan itself moved: every partial is stale
            self._sig, self._chosen = sig, chosen
            self._partials.clear()
            dirty = list(self.engine.store.segments)
        else:
            seen = {s.segment_id for s in dirty}
            dirty = list(dirty) + [
                s for s in self.engine.store.segments
                if s.segment_id in self._failed and s.segment_id not in seen]
        # token dedupe: an already-folded (or duplicated) delta is free
        work = [s for s in dirty if self._needs_fold(s)]
        if not work:
            return
        planner = self.engine.planner
        tokens = [s.meta_token() for s in work]   # read BEFORE classify
        try:
            faults.fire("standing.fold", query=self.name, change=kind,
                        segments=len(work))
            tasks = []
            for seg in work:
                if chosen == "fluxsieve":
                    tasks.append(planner.classify(seg, self.query, flux,
                                                  cache=True))
                else:
                    meta = seg.meta
                    expired, cutoff = planner._expiry(meta)
                    cls = (PRUNED if expired
                           else TEXT_INDEX if chosen == "text_index"
                           else FULL_SCAN)
                    tasks.append(SegmentTask(seg=seg, meta=meta,
                                             path_class=cls, cutoff=cutoff))
            plan = PhysicalPlan(
                query=self.query, path=chosen,
                flux=flux if chosen == "fluxsieve" else None, tasks=tasks)
            with telemetry.span("standing/fold", cat="standing",
                                query=self.name, kind=kind,
                                segments=len(work)):
                per_seg = self.engine.executor.execute(
                    plan, planner, cache=True,
                    owner=f"standing/{self.name}")
        except Exception as e:  # noqa: BLE001 — InjectedCrash passes through
            self._mark_failed(work, kind, e)
            return
        for seg, tok, task, (ids, stats) in zip(work, tokens, tasks,
                                                per_seg):
            sid = seg.segment_id
            if stats.failed:    # sharded fold: this shard faulted/overran
                self._partials.pop(sid, None)
                self._failed.add(sid)
                continue
            self._pending_bytes += stats.bytes_read
            if ids is None:                     # pruned: contributes zero
                count, row_ids = 0, None
            elif isinstance(ids, (int, np.integer)):
                count, row_ids = int(ids), None
            else:
                ids, extra = filter_expired(task, ids, cache=True)
                self._pending_bytes += extra
                count, row_ids = len(ids), ids
            self._partials[sid] = _Partial(
                tok, stats.path_class, count, row_ids,
                stats.scanned, stats.pruned, stats.fallback)
            self._failed.discard(sid)
            self.segments_folded += 1
            _SEGMENTS_FOLDED.inc()
        self.folds += 1
        _FOLDS.get(kind, _FOLDS["heal"]).inc()
        _FOLD_SECONDS.observe(time.perf_counter() - t0)

    def _mark_failed(self, segs: list, kind: str, err: Exception) -> None:
        for seg in segs:
            self._partials.pop(seg.segment_id, None)
            self._failed.add(seg.segment_id)
        _FOLD_FAILURES.inc()
        telemetry.emit("standing_fold_failed", plane="standing",
                       query=self.name, change=kind, segments=len(segs),
                       error=f"{type(err).__name__}: {err}")

    def _assemble_locked(self, segments: list) -> QueryResult:
        res = QueryResult(count=0, segments_total=len(segments),
                          path=self._chosen or "")
        matches = []
        for seg in segments:
            sid = seg.segment_id
            p = self._partials.get(sid)
            if p is None or sid in self._failed:
                res.segments_failed += 1
                res.failed_segment_ids += (sid,)
                continue
            res.count += p.count
            res.segments_scanned += p.scanned
            res.segments_pruned += p.pruned
            res.segments_fallback += p.fallback
            if p.fallback:
                res.fallback_ids += (sid,)
            if p.path_class:
                res.path_classes[p.path_class] = \
                    res.path_classes.get(p.path_class, 0) + 1
            if self.query.mode == "copy" and p.ids is not None \
                    and len(p.ids):
                matches.append((seg, p.ids))
        res.bytes_read += self._pending_bytes
        self._pending_bytes = 0
        if self.query.mode == "copy":
            res.records = self.engine._materialize(matches, True, res)
        return res


class StandingRegistry:
    """The engine's fan-out point: one subscription on the store's epoch
    feed, every delta delivered to every registered standing query.  Built
    lazily by ``QueryEngine.register_standing`` (the engine holds the
    strong reference — the store's listener list holds this registry's
    bound method weakly, same as every other epoch subscriber)."""

    def __init__(self, engine):
        self.engine = engine
        self._lock = threading.Lock()
        self._queries = {}          # name -> StandingQuery
        self._seq = 0

    def on_epoch(self, delta) -> None:
        for sq in self.active():
            sq.on_delta(delta)

    def active(self) -> list:
        with self._lock:
            return list(self._queries.values())

    def get(self, name: str):
        with self._lock:
            return self._queries.get(name)

    def register(self, query, *, path: str = "auto",
                 name: str = None) -> StandingQuery:
        with self._lock:
            self._seq += 1
            name = name or query.name or f"standing-{self._seq}"
            if name in self._queries:
                raise ValueError(f"standing query {name!r} already "
                                 "registered")
            sq = StandingQuery(self.engine, query, path=path, name=name,
                               registry=self)
            self._queries[name] = sq
        _REGISTERED.inc()
        _ACTIVE.inc()
        telemetry.emit("standing_registered", plane="standing",
                       query=name, path=path)
        # initial materialization: no partials and no signature yet, so
        # this first fold evaluates the full store once
        with sq._lock:
            sq._fold_locked([], kind="initial")
        return sq

    def deregister(self, name: str) -> None:
        with self._lock:
            self._queries.pop(name, None)

    def close(self) -> None:
        for sq in self.active():
            sq.close()
