from repro.core.query.store import Segment, SegmentStore  # noqa: F401
from repro.core.query.engine import Query, QueryEngine, QueryResult  # noqa: F401
from repro.core.query.mapper import QueryMapper  # noqa: F401
from repro.core.query.profiler import QueryProfiler  # noqa: F401
