from repro.core.query.store import Segment, SegmentStore  # noqa: F401
from repro.core.query.arrangement import (ArrangementLease,  # noqa: F401
                                          ArrangementStore)
from repro.core.query.engine import Query, QueryEngine, QueryResult  # noqa: F401
from repro.core.query.planner import (PATH_CLASSES, PhysicalPlan,  # noqa: F401
                                      QueryPlanner, SegmentTask)
from repro.core.query.executor import (PlanExecutor,  # noqa: F401
                                       ShardedQueryExecutor)
from repro.core.query.mapper import QueryMapper  # noqa: F401
from repro.core.query.profiler import QueryProfiler  # noqa: F401
from repro.core.query.standing import (StandingQuery,  # noqa: F401
                                       StandingRegistry)
