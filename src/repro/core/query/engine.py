"""Query execution over the columnar plane — three physical paths.

  full_scan   vectorized substring scan over raw content bytes
              (the DuckDB optimized-full-scan baseline, paper §5.1);
  text_index  token -> posting-list lookup on the per-segment inverted
              index (the Pinot FTS baseline, paper §6.1);
  fluxsieve   bitmap test on the enrichment column + segment zone-map
              pruning (the paper's fast path, via the Query Mapper).

A query is a conjunction of (field contains term) predicates with a
``copy`` (materialize matching records) or ``count`` (aggregate only) mode —
exactly the paper's Q1-Q4 and their "with count" variants.  ``cold=True``
drops all segment caches first and reads without retaining, modelling the
paper's cold runs; bytes read from disk are accounted per query.

Consistency (paper §3.4 step 4): the fluxsieve path consults the mapper per
segment — records ingested under an engine version that did not know a rule
fall back to full scan for that segment (hybrid execution), so enrichment
never changes results.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.records import RecordBatch
from repro.core.stream_processor import ENRICH_COLUMN
from repro.core.query.store import Segment, SegmentStore

PATHS = ("full_scan", "text_index", "fluxsieve")


@dataclass(frozen=True)
class Query:
    """terms: ((field, term), ...) AND-combined; mode: 'copy' | 'count'."""
    terms: tuple
    mode: str = "count"
    name: str = ""

    def __post_init__(self):
        if self.mode not in ("copy", "count"):
            raise ValueError(self.mode)
        if not self.terms:
            raise ValueError("empty query")

    def key(self) -> tuple:
        return tuple(sorted(self.terms))


@dataclass
class QueryResult:
    count: int
    records: RecordBatch = None
    latency_s: float = 0.0
    path: str = ""
    segments_scanned: int = 0
    segments_pruned: int = 0
    segments_fallback: int = 0
    bytes_read: int = 0
    fallback_ids: tuple = ()    # segment ids served via consistency fallback


def substring_scan(data: np.ndarray, term: str) -> np.ndarray:
    """(N, L) uint8 contains `term` as a byte substring -> (N,) bool."""
    t = term.encode()
    N, L = data.shape
    m = len(t)
    if m == 0 or m > L:
        return np.zeros(N, bool)
    # vectorized first-byte prefilter, then confirm remaining bytes
    acc = data[:, :L - m + 1] == t[0]
    for i in range(1, m):
        acc &= data[:, i:L - m + 1 + i] == t[i]
    return acc.any(axis=1)


class QueryEngine:
    """``workers`` > 1 scans segments concurrently (numpy releases the GIL
    in the vectorized kernels) — the intra-query parallelism axis of the
    paper's Figs 6-9."""

    def __init__(self, store: SegmentStore, *, mapper=None, profiler=None,
                 workers: int = 1):
        self.store = store
        self.mapper = mapper          # QueryMapper (None -> no fluxsieve path)
        self.profiler = profiler
        self.workers = workers

    # -- public ------------------------------------------------------------
    def execute(self, query: Query, *, path: str = "auto",
                cold: bool = False) -> QueryResult:
        if cold:
            self.store.drop_caches()
        chosen = path
        plan = None
        if path in ("auto", "fluxsieve") and self.mapper is not None:
            plan = self.mapper.map(query)
        if path == "auto":
            chosen = "fluxsieve" if plan is not None else self._fallback_path(query)
        if chosen == "fluxsieve" and plan is None:
            raise ValueError("query not covered by registered rules; "
                             "no fluxsieve plan")
        t0 = time.perf_counter()
        res = self._run(query, chosen, plan, cache=not cold)
        res.latency_s = time.perf_counter() - t0
        res.path = chosen
        if self.profiler is not None:
            self.profiler.record(query, res)
        return res

    def _fallback_path(self, query: Query) -> str:
        segs = self.store.segments
        if segs and all(s.has_text_index(f) for f, _ in query.terms
                        for s in segs):
            return "text_index"
        return "full_scan"

    # -- execution ---------------------------------------------------------
    def _run(self, query: Query, path: str, plan, cache: bool) -> QueryResult:
        res = QueryResult(count=0)
        segs = self.store.segments

        def one(seg):
            # thread-local counters; merged below (no racy increments)
            local = QueryResult(count=0)
            if path == "fluxsieve":
                ids = self._seg_fluxsieve(seg, query, plan, cache, local)
            elif path == "text_index":
                ids = self._seg_text_index(seg, query, cache, local)
            else:
                ids = self._seg_full_scan(seg, query, cache, local)
            return ids, local

        if self.workers > 1 and len(segs) > 1:
            from concurrent.futures import ThreadPoolExecutor
            with ThreadPoolExecutor(self.workers) as pool:
                per_seg = list(pool.map(one, segs))
        else:
            per_seg = [one(seg) for seg in segs]

        for _, local in per_seg:
            res.segments_scanned += local.segments_scanned
            res.segments_pruned += local.segments_pruned
            res.segments_fallback += local.segments_fallback
            res.bytes_read += local.bytes_read
            res.fallback_ids += local.fallback_ids

        matches = []   # (segment, ids) for copy mode
        for seg, (ids, _) in zip(segs, per_seg):
            if ids is None:
                continue
            if isinstance(ids, int):           # metadata-only count
                res.count += ids
                continue
            res.count += len(ids)
            if query.mode == "copy" and len(ids):
                matches.append((seg, ids))
        if query.mode == "copy":
            res.records = self._materialize(matches, cache, res)
        return res

    def _seg_full_scan(self, seg: Segment, query: Query, cache, res):
        res.segments_scanned += 1
        mask = None
        for fieldname, term in query.terms:
            col = self._read(seg, fieldname, cache, res)
            m = substring_scan(col, term)
            mask = m if mask is None else (mask & m)
        return np.flatnonzero(mask)

    def _seg_text_index(self, seg: Segment, query: Query, cache, res):
        res.segments_scanned += 1
        ids = None
        for fieldname, term in query.terms:
            idx = seg.text_index(fieldname, cache=cache)
            posting = idx.get(term, np.zeros(0, np.int32))
            ids = posting if ids is None else np.intersect1d(ids, posting,
                                                             assume_unique=True)
            if not len(ids):
                break
        return ids

    def _seg_fluxsieve(self, seg: Segment, query: Query, plan, cache, res):
        # snapshot-validate-retry: the maintenance plane can swap a sealed
        # segment's enrichment (bitmap/postings + meta) between our coverage
        # check and our data read.  Evaluate everything against ONE meta
        # snapshot, then confirm the segment still carries that snapshot;
        # if not, retry against the new state, and after repeated swaps fall
        # back to the full scan, which never depends on enrichment.
        for _ in range(3):
            meta = seg.meta
            attempt = QueryResult(count=0)
            ids = self._seg_fluxsieve_snap(seg, meta, query, plan, cache,
                                           attempt)
            if seg.meta is meta:
                res.segments_scanned += attempt.segments_scanned
                res.segments_pruned += attempt.segments_pruned
                res.segments_fallback += attempt.segments_fallback
                res.bytes_read += attempt.bytes_read
                res.fallback_ids += attempt.fallback_ids
                return ids
        res.segments_fallback += 1
        res.fallback_ids += (seg.segment_id,)
        return self._seg_full_scan(seg, query, cache, res)

    def _seg_fluxsieve_snap(self, seg: Segment, meta: dict, query: Query,
                            plan, cache, res):
        # consistency: records ingested before a rule existed -> fallback scan
        if not plan.covers_segment(seg, meta):
            res.segments_fallback += 1
            res.fallback_ids += (seg.segment_id,)   # maintenance-plane heat
            return self._seg_full_scan(seg, query, cache, res)
        # zone-map pruning: segment-level OR of bitmaps lacks a needed bit
        zone = meta.get("rule_bitmap_any")
        if zone is not None:
            zone = np.asarray(zone, np.uint32)
            for mask in plan.masks:
                # widths may differ across engine generations; a bit beyond
                # the segment's bitmap width cannot be set in any record
                k = min(len(zone), len(mask))
                if not (zone[:k] & mask[:k]).any():
                    res.segments_pruned += 1
                    return None
        # single-rule count: answered from per-segment metadata, zero I/O
        if query.mode == "count" and len(plan.rule_ids) == 1:
            c = seg.rule_count(plan.rule_ids[0], meta)
            if c is not None:
                res.segments_scanned += 1
                return int(c)
        res.segments_scanned += 1
        # seal-time rule postings (sparse inverted index): ids directly,
        # intersected for multi-term AND — no bitmap-column scan
        postings = [seg.rule_postings(rid, cache=cache)
                    for rid in plan.rule_ids]
        if all(p is not None for p in postings):
            ids = postings[0]
            for p in postings[1:]:
                ids = np.intersect1d(ids, p, assume_unique=True)
                if not len(ids):
                    break
            return ids
        bm = self._read(seg, ENRICH_COLUMN, cache, res)
        keep = None
        for rid in plan.rule_ids:
            # test ONE word column + bit, not the full (N, W) mask product
            m = (bm[:, rid // 32] >> np.uint32(rid % 32)) & np.uint32(1)
            keep = m.astype(bool) if keep is None else (keep & m.astype(bool))
        return np.flatnonzero(keep)

    def _materialize(self, matches, cache, res) -> RecordBatch:
        parts = []
        for seg, ids in matches:
            cols = {}
            for name in seg.column_names:
                in_mem = name in seg._columns
                rows = seg.column_rows(name, ids, cache=cache)
                if not in_mem:
                    res.bytes_read += rows.nbytes
                cols[name] = rows
            parts.append(RecordBatch(cols))
        if not parts:
            return RecordBatch({})
        return RecordBatch.concat(parts)

    def _read(self, seg: Segment, name: str, cache: bool, res: QueryResult):
        in_mem = name in seg._columns
        col = seg.column(name, cache=cache)
        if not in_mem:
            res.bytes_read += col.nbytes
        return col
