"""Query execution over the columnar plane — planner/executor split.

Three logical paths (paper §5.1/§6.1 baselines + the paper's fast path):

  full_scan   vectorized substring scan over raw content bytes
              (the DuckDB optimized-full-scan baseline, paper §5.1);
  text_index  token -> posting-list lookup on the per-segment inverted
              index (the Pinot FTS baseline, paper §6.1);
  fluxsieve   enrichment-bitmap evaluation + segment zone-map pruning
              (the paper's fast path, via the Query Mapper).

A query is a conjunction of (field contains term) predicates with a
``copy`` (materialize matching records) or ``count`` (aggregate only) mode —
exactly the paper's Q1-Q4 and their "with count" variants.  ``cold=True``
drops all segment caches (host AND device) first, modelling the paper's
cold runs; bytes read from disk are accounted per query.

Execution is split into a logical **planner** (``query.planner``) that
consults the mapper/zone-maps/metadata once and classifies every segment
into a physical path class, and a batched **executor** (``query.executor``)
that runs all bitmap-scan segments as ONE stacked device dispatch with one
D2H transfer per query, leases hot device state from the SHARED
refcounted arrangement plane (``query.arrangement`` — one upload per word
column per maintenance epoch across ALL concurrent queries and shards),
and re-plans segments the maintenance plane swapped mid-query.
Consistency (paper §3.4 step 4) is preserved: records ingested under an
engine version that did not know a rule fall back to full scan for that
segment (hybrid execution), so enrichment never changes results.

The plane's invariants, each asserted in tests:

  * results are byte-identical across ``full_scan`` / ``text_index`` /
    ``fluxsieve`` and across every fluxsieve path class — before, during,
    and after any maintenance action;
  * ONE counted D2H transfer per query on the stacked bitmap path
    (``executor.transfer_count``, under ``jax.transfer_guard``), ONE fused
    matcher D2H for all fallback/full-scan segments of a query;
  * ONE H2D upload per enrichment word column per maintenance epoch,
    shared by all concurrent clients and shards
    (``ArrangementStore.upload_counts`` — every value == 1);
  * enriched-path results re-validate the meta snapshot their
    classification used (meta-flips-last on the writer side makes the
    check sufficient); swapped segments re-plan individually, full scans
    return directly because they never read enrichment state.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import telemetry
from repro.core.records import RecordBatch
from repro.core.query.arrangement import ArrangementStore
from repro.core.query.executor import (PlanExecutor, ShardedQueryExecutor,
                                       substring_scan)  # noqa: F401 — substring_scan re-exported
from repro.core.query.planner import PhysicalPlan, QueryPlanner
from repro.core.query.store import Segment, SegmentStore  # noqa: F401

PATHS = ("full_scan", "text_index", "fluxsieve")

# per-path latency histograms: the paper's Fig-6/7 axis in snapshot form
_QUERY_LATENCY = {
    p: telemetry.histogram("fluxsieve_query_latency_seconds",
                           labels={"path": p},
                           help="End-to-end query latency by logical path.")
    for p in PATHS
}
_QUERY_TOTAL = telemetry.counter(
    "fluxsieve_query_total", help="Queries executed.")
_QUERY_BYTES = telemetry.counter(
    "fluxsieve_query_bytes_read_total",
    help="Bytes read from spill by queries (cold-path I/O).")
_QUERY_PARTIAL = telemetry.counter(
    "fluxsieve_query_partial_total",
    help="Queries answered partially (>=1 shard failed or timed out).")


def filter_expired(task, ids: np.ndarray, cache: bool) -> tuple:
    """Retention-straddler filter: expired rows are plan-time invisible
    long before compaction physically drops them.  ONE central filter —
    every physical class (and the standing-query fold path) funnels its
    row ids through here, so no per-path filter can tear.  Returns
    ``(kept_ids, bytes_read)`` (bytes only when the timestamp column came
    off disk)."""
    if task.cutoff is None or not len(ids):
        return ids, 0
    seg = task.seg
    in_mem = "timestamp" in seg._columns
    ts = np.asarray(seg.column_rows("timestamp", ids, cache=cache))
    return ids[ts >= task.cutoff], (0 if in_mem else ts.nbytes)


@dataclass(frozen=True)
class Query:
    """terms: ((field, term), ...) AND-combined; mode: 'copy' | 'count'."""
    terms: tuple
    mode: str = "count"
    name: str = ""

    def __post_init__(self):
        if self.mode not in ("copy", "count"):
            raise ValueError(self.mode)
        if not self.terms:
            raise ValueError("empty query")

    def key(self) -> tuple:
        return tuple(sorted(self.terms))


@dataclass
class QueryResult:
    count: int
    records: RecordBatch = None
    latency_s: float = 0.0
    path: str = ""
    segments_scanned: int = 0
    segments_pruned: int = 0
    segments_fallback: int = 0
    segments_failed: int = 0    # shard faulted/deadline overrun: unserved
    segments_total: int = 0
    bytes_read: int = 0
    fallback_ids: tuple = ()    # segment ids served via consistency fallback
    failed_segment_ids: tuple = ()  # segment ids a degraded query skipped
    path_classes: dict = field(default_factory=dict)  # class -> num segments

    @property
    def partial(self) -> bool:
        """True when >=1 segment went unserved: ``count``/``records`` are a
        lower bound over ``coverage`` of the store, not the full answer."""
        return self.segments_failed > 0

    @property
    def coverage(self) -> float:
        """Fraction of planned segments actually served (1.0 = complete)."""
        if not self.segments_total:
            return 1.0
        return 1.0 - self.segments_failed / self.segments_total


class QueryEngine:
    """``backend`` selects the bitmap-class executor: ``"ref"`` (stacked jnp
    dispatch, default), ``"pallas"`` (stacked Pallas kernel), ``"numpy"``
    (pre-refactor per-segment word tests — the equivalence oracle).
    ``scan_backend`` (e.g. ``"dfa_ref"``) routes full-scan fallbacks through
    throwaway compiled DFA engines (fused backends batch ALL scan segments
    of a query into one dispatch).  ``workers`` > 1 scans host-path
    segments concurrently (numpy releases the GIL in the vectorized
    kernels) — the intra-query parallelism axis of the paper's Figs 6-9.

    Device state is the SHARED arrangement plane: pass one
    ``arrangements=ArrangementStore()`` to every engine over a store (or
    share one engine) and concurrent queries lease a single refcounted
    device copy per (segment set, word subset) — uploaded once per
    maintenance epoch.  The engine subscribes the arrangement store to the
    segment store's maintenance feed, so ``apply_update`` / compaction /
    cold-run drops publish epochs instead of invalidating under readers.
    ``shards`` > 1 turns on the sharded query workers: ``plan.tasks``
    partition by segment across a pool (identities
    ``{worker_id}/shard-{i}``), each shard dispatching and re-planning
    independently against the shared arrangements."""

    def __init__(self, store: SegmentStore, *, mapper=None, profiler=None,
                 workers: int = 1, backend: str = "ref",
                 scan_backend: str = None, block_n: int = 1024,
                 interpret: bool = True, arrangements: ArrangementStore = None,
                 device_counts="auto", shards: int = 1,
                 worker_id: str = "query-0", shard_deadline_s: float = None,
                 shard_affinity: str = "weighted", prefetch: bool = True):
        self.store = store
        self.mapper = mapper          # QueryMapper (None -> no fluxsieve path)
        self.profiler = profiler
        self.workers = workers
        self.planner = QueryPlanner(mapper)
        self.arrangements = arrangements or ArrangementStore()
        # maintenance swaps publish kind-aware epoch deltas to the shared
        # device plane (on_epoch retires + optionally prefetches; seals
        # pass through without bumping the arrangement epoch)
        store.subscribe_epochs(self.arrangements.on_epoch)
        if prefetch:
            self.arrangements.set_prefetch_source(self._prefetch_item)
        self.plan_executor = PlanExecutor(
            backend=backend, scan_backend=scan_backend, block_n=block_n,
            interpret=interpret, workers=workers,
            arrangements=self.arrangements, device_counts=device_counts)
        self.executor = (ShardedQueryExecutor(self.plan_executor,
                                              shards=shards,
                                              worker_id=worker_id,
                                              deadline_s=shard_deadline_s,
                                              affinity=shard_affinity)
                         if shards > 1 else self.plan_executor)
        self._standing = None         # StandingRegistry, built on demand

    def close(self) -> None:
        """Release standing queries and the shard worker pool (both no-ops
        when unused)."""
        if self._standing is not None:
            self._standing.close()
        if isinstance(self.executor, ShardedQueryExecutor):
            self.executor.close()

    def _prefetch_item(self, segment_id: int):
        """Arrangement-prefetch source: the segment's CURRENT-token
        ``ArrangementItem`` (hot bitmap read), or None once it left the
        store."""
        from repro.core.stream_processor import ENRICH_COLUMN
        from repro.core.query.arrangement import ArrangementItem
        for seg in self.store.segments:
            if seg.segment_id == segment_id:
                return ArrangementItem(
                    token=seg.meta_token(),
                    num_records=int(seg.num_records),
                    load=lambda s=seg: np.asarray(s.column(ENRICH_COLUMN)))
        return None

    # -- standing queries ----------------------------------------------------
    def register_standing(self, query: Query, *, path: str = "auto",
                          name: str = None):
        """Register ``query`` for incremental view maintenance: the result
        materializes once through the normal executor, then per-segment
        deltas from the store's epoch feed fold into it — ``refresh()``
        answers in O(changed segments) instead of O(all segments).
        Returns the :class:`repro.core.query.standing.StandingQuery`."""
        from repro.core.query.standing import StandingRegistry
        if self._standing is None:
            self._standing = StandingRegistry(self)
            self.store.subscribe_epochs(self._standing.on_epoch)
        return self._standing.register(query, path=path, name=name)

    # -- public ------------------------------------------------------------
    def plan(self, query: Query, *, path: str = "auto",
             cache: bool = True) -> PhysicalPlan:
        """EXPLAIN: the physical plan ``execute`` would run (fresh per call;
        classifications snapshot live segment metadata)."""
        flux = None
        if path in ("auto", "fluxsieve") and self.mapper is not None:
            flux = self.mapper.map(query)
        return self.planner.plan(query, list(self.store.segments),
                                 path=path, flux=flux, cache=cache)

    def execute(self, query: Query, *, path: str = "auto",
                cold: bool = False) -> QueryResult:
        if cold:
            self.store.drop_caches()    # token bump also invalidates device
        flux = None
        if path in ("auto", "fluxsieve") and self.mapper is not None:
            flux = self.mapper.map(query)
        if path == "fluxsieve" and flux is None:
            raise ValueError("query not covered by registered rules; "
                             "no fluxsieve plan")
        t0 = time.perf_counter()
        with telemetry.span("query/execute", cat="query",
                            mode=query.mode, query=query.name):
            plan = self.planner.plan(query, list(self.store.segments),
                                     path=path, flux=flux, cache=not cold)
            res = self._run(plan, cache=not cold)
        res.latency_s = time.perf_counter() - t0
        res.path = plan.path
        _QUERY_TOTAL.inc()
        _QUERY_BYTES.inc(res.bytes_read)
        hist = _QUERY_LATENCY.get(res.path)
        if hist is not None:
            hist.observe(res.latency_s)
        if self.profiler is not None:
            self.profiler.record(query, res)
        return res

    # -- execution ---------------------------------------------------------
    def _run(self, plan: PhysicalPlan, cache: bool) -> QueryResult:
        res = QueryResult(count=0, segments_total=len(plan.tasks))
        per_seg = self.executor.execute(plan, self.planner, cache=cache)
        matches = []   # (segment, ids) for copy mode
        for task, (ids, stats) in zip(plan.tasks, per_seg):
            res.segments_scanned += stats.scanned
            res.segments_pruned += stats.pruned
            res.segments_fallback += stats.fallback
            res.segments_failed += stats.failed
            res.bytes_read += stats.bytes_read
            res.fallback_ids += stats.fallback_ids
            res.failed_segment_ids += stats.failed_ids
            if stats.path_class:
                res.path_classes[stats.path_class] = \
                    res.path_classes.get(stats.path_class, 0) + 1
            if ids is None:
                continue
            if isinstance(ids, (int, np.integer)):   # metadata-only count
                res.count += int(ids)
                continue
            ids, extra_bytes = filter_expired(task, ids, cache)
            res.bytes_read += extra_bytes
            res.count += len(ids)
            if plan.query.mode == "copy" and len(ids):
                matches.append((task.seg, ids))
        if plan.query.mode == "copy":
            res.records = self._materialize(matches, cache, res)
        if res.segments_failed:
            _QUERY_PARTIAL.inc()
            telemetry.emit("query_partial", plane="query",
                           failed=res.segments_failed,
                           total=res.segments_total,
                           segments=[int(s) for s in res.failed_segment_ids])
        return res

    def _materialize(self, matches, cache, res) -> RecordBatch:
        parts = []
        for seg, ids in matches:
            cols = {}
            for name in seg.column_names:
                in_mem = name in seg._columns
                rows = seg.column_rows(name, ids, cache=cache)
                if not in_mem:
                    res.bytes_read += rows.nbytes
                cols[name] = rows
            parts.append(RecordBatch(cols))
        if not parts:
            return RecordBatch({})
        return RecordBatch.concat(parts)
