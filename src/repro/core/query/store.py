"""Columnar analytical plane — segments, seal, spill, zone maps, FTS index.

The in-framework analogue of Pinot REALTIME segments / a Parquet data lake:
record batches append into an active (mutable) segment; at ``segment_size``
records the segment **seals** — columns freeze, per-segment metadata (zone
maps) is derived, and an optional **text index** (token -> posting list, the
Pinot FTS analogue) is built.  Sealed segments can **spill** to disk as one
file per column, so queries read only the columns they touch (columnar I/O),
and caches can be dropped per column to measure genuine cold-run behaviour
(paper §4.2).

Zone maps kept per segment:
  * min/max ``timestamp``;
  * OR of all enrichment bitmaps (``rule_bitmap_any``) — a segment whose
    combined bitmap lacks a query's rule bits is **pruned without any I/O**,
    the mechanism behind the paper's cold-run wins ("data pruning possible
    with our approach that avoids I/O bottlenecks", §6.3.1);
  * min/max ``engine_version_id`` — consistency propagation (§3.4 step 4):
    the mapper only uses the enriched path on segments whose records were all
    ingested with an engine that knew the rule.

Durability invariants (maintenance plane v2):
  * **meta-flips-last** — ``Segment.apply_update`` installs data before
    flipping ``meta`` and bumps the cache token after, so no stale derived
    state can ever be cached under a live token;
  * **manifest is the commit point** — segment-set membership (seal
    registration, compaction swap, retention retire) changes as ONE atomic
    :class:`Manifest` write; ``SegmentStore.load`` trusts it, closing the
    crash window where a merged segment and its un-retired inputs coexist
    on disk (RETIRED tombstones are advisory: legacy loads + GC keys);
  * **fenced writes** — ``apply_update(fence=...)`` runs the maintenance
    plane's epoch-fencing barrier inside the write lock, before the first
    mutation (see ``repro.core.maintenance.lease``).
"""
from __future__ import annotations

import json
import os
import re
import threading
import warnings
import weakref
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core import faults, telemetry
from repro.core.records import RecordBatch, decode_texts
from repro.core.stream_processor import ENGINE_VERSION_COLUMN, ENRICH_COLUMN

_SEALED = telemetry.counter(
    "fluxsieve_store_segments_sealed_total",
    help="Segments sealed out of the active append buffer.")
_COMMITS = telemetry.counter(
    "fluxsieve_store_manifest_commits_total",
    help="Atomic root-manifest commits.")
_EPOCH_PUBLISHES = telemetry.counter(
    "fluxsieve_store_epoch_publishes_total",
    help="Maintenance epochs published to subscribers.")
_SEGMENTS_MISSING = telemetry.counter(
    "fluxsieve_store_segments_missing_total",
    help="Manifest-listed spill dirs found missing at load (data loss).")

_TOKEN_RE = re.compile(r"[A-Za-z0-9_\-./:]+")

# fraction of a segment above which a rule is "dense" and gets no posting list
POSTING_DENSITY_CUT = 0.1

# tombstone file marking a spill dir replaced by compaction/retention: load()
# skips it (pre-manifest stores), SpillGC deletes it once no reader remains
RETIRED_MARKER = "RETIRED"

# root manifest: the authoritative valid-segment set + fencing-epoch registry
MANIFEST_NAME = "manifest.json"

# the ingest WAL's home under the store root (owned by data/pipeline, named
# here so load() can recognize a WAL-born store without a circular import)
INGEST_WAL_DIRNAME = "ingest-wal"

# meta key stamped by the retention plane (maintenance.retention) on
# segments straddling the TTL horizon: rows with timestamp < this value are
# logically expired.  The planner filters them at plan time (immediate
# query invisibility); the Compactor's next rewrite drops them physically.
RETENTION_CUTOFF = "retention_cutoff"

# epoch change kinds published to subscribe_epochs listeners
EPOCH_KINDS = ("seal", "update", "drop", "replace", "retire")


@dataclass(frozen=True)
class EpochDelta:
    """One maintenance epoch's change record — the payload of the
    ``subscribe_epochs`` feed (the richer sibling of the legacy
    ``subscribe_maintenance`` segment-id feed).

    ``kind`` names the change class:

      ``seal``     a new segment entered the store off the append path;
      ``update``   ``Segment.apply_update`` swapped enrichment artifacts
                   (backfill install, retention-cutoff stamp);
      ``drop``     a cold-run cache drop bumped tokens (data unchanged —
                   derived device/host caches are invalid, results are not);
      ``replace``  compaction swapped ``segment_ids`` out for ``added``;
      ``retire``   retention removed ``segment_ids`` with no replacement.

    ``segment_ids`` are the ids whose previous state this epoch
    invalidates (for ``seal`` the new segment's own id); ``added`` carries
    the Segment objects entering the store (seal/replace); ``tokens`` maps
    every affected id still in the store to its post-change
    ``meta_token()`` — the affected-version detail incremental consumers
    (standing queries) compare against their folded state, so a duplicated
    delivery or an already-folded epoch is recognized without re-reading
    any data."""
    seq: int
    kind: str
    segment_ids: tuple
    added: tuple = ()
    tokens: dict = field(default_factory=dict)


def tokenize(text: str) -> list:
    return _TOKEN_RE.findall(text)


class Manifest:
    """Crash-safe root manifest for a spilled ``SegmentStore``.

    A hard kill between a compactor spilling its merged segment and
    tombstoning the inputs used to leave BOTH on disk, so a later
    ``SegmentStore.load`` would double-count every merged record.  The
    manifest closes that window by making segment-set membership a single
    atomic commit: the valid segment set (plus the id allocator's
    high-water mark and the maintenance plane's fencing epochs) lives in
    one small JSON document, rewritten via tmp + ``os.replace`` — a reload
    sees either the pre-swap or the post-swap world, never a mix.

    Commit protocol (writers):
      * a sealed segment spills FIRST, then registers — a crash in between
        leaves an unregistered dir that ``load`` ignores;
      * compaction materializes its merged segment *unregistered*
        (``make_segment_from_batch``), and ``replace_segments`` commits
        "new in, old out" as ONE manifest write — the commit point; the
        RETIRED tombstones written afterwards are advisory (for
        pre-manifest readers and the GC), not load-bearing;
      * lease epochs persist here too (``fences``), so a restarted process
        can never re-issue a fencing token an earlier holder already wrote
        under (see ``maintenance.lease.LeaseManager``).

    Thread-safe; state is held in memory and every ``commit`` rewrites the
    full (small) document atomically.
    """

    def __init__(self, root):
        self.root = Path(root)
        self.path = self.root / MANIFEST_NAME
        self._lock = threading.Lock()
        self._state = {"segments": {}, "next_id": 0, "fences": {},
                       "sealed_rows": 0}

    @staticmethod
    def read(root) -> dict:
        """The on-disk manifest state, or None when no manifest exists
        (pre-manifest store — ``load`` falls back to directory scanning)."""
        path = Path(root) / MANIFEST_NAME
        if not path.exists():
            return None
        return json.loads(path.read_text())

    def adopt(self, state: dict) -> None:
        """Install previously persisted state (``SegmentStore.load``)."""
        with self._lock:
            self._state = {"segments": dict(state.get("segments", {})),
                           "next_id": int(state.get("next_id", 0)),
                           "fences": dict(state.get("fences", {})),
                           "sealed_rows": int(state.get("sealed_rows", 0))}

    def commit(self, *, add: dict = None, remove=None, next_id: int = None,
               fences: dict = None, sealed_rows: int = None) -> None:
        """Atomically apply a membership/epoch delta and persist.

        ``add``: {segment_id: dirname}; ``remove``: segment ids;
        ``next_id``: id-allocator high-water mark (monotonic);
        ``fences``: {segment_id: epoch} (monotonic per segment);
        ``sealed_rows``: ingest durability watermark — total rows the
        ingest path has sealed into registered segments (monotonic; the
        WAL truncates, and crash recovery dedups, against it)."""
        faults.fire("store.manifest_commit", root=str(self.root))
        with self._lock:
            seg = self._state["segments"]
            if add:
                for sid, name in add.items():
                    seg[str(int(sid))] = str(name)
            for sid in (remove or ()):
                seg.pop(str(int(sid)), None)
            if next_id is not None:
                self._state["next_id"] = max(self._state["next_id"],
                                             int(next_id))
            if fences:
                f = self._state["fences"]
                for sid, epoch in fences.items():
                    key = str(int(sid))
                    f[key] = max(int(f.get(key, 0)), int(epoch))
            if sealed_rows is not None:
                self._state["sealed_rows"] = max(
                    self._state.get("sealed_rows", 0), int(sealed_rows))
            _atomic_write_text(self.path,
                               json.dumps(self._state, sort_keys=True))
        _COMMITS.inc()
        telemetry.emit("manifest_commit", plane="store",
                       added=len(add or ()), removed=len(tuple(remove or ())),
                       fenced=len(fences or ()))

    # -- readers -----------------------------------------------------------
    def segment_dirs(self) -> list:
        """Valid spill dirs in segment-id order (the load set)."""
        with self._lock:
            items = sorted(self._state["segments"].items(),
                           key=lambda kv: int(kv[0]))
            return [self.root / name for _, name in items]

    def segment_ids(self) -> set:
        with self._lock:
            return {int(s) for s in self._state["segments"]}

    def next_id(self) -> int:
        with self._lock:
            return self._state["next_id"]

    def fences(self) -> dict:
        with self._lock:
            return {int(s): int(e)
                    for s, e in self._state["fences"].items()}

    def sealed_rows(self) -> int:
        """Ingest durability watermark: rows sealed into registered
        segments (crash recovery replays WAL entries past it)."""
        with self._lock:
            return int(self._state.get("sealed_rows", 0))


def build_text_index(data: np.ndarray) -> dict:
    """(N, L) uint8 -> token -> sorted int32 record ids (inverted index)."""
    postings: dict = {}
    for rid, text in enumerate(decode_texts(data)):
        for tok in set(tokenize(text)):
            postings.setdefault(tok, []).append(rid)
    return {t: np.asarray(ids, np.int32) for t, ids in postings.items()}


def derive_enrichment_meta(bm: np.ndarray) -> tuple:
    """(N, W) uint32 rule bitmap -> (meta_updates, rule_postings).

    Shared by seal, backfill, and compaction so every producer of an
    enrichment column derives identical zone maps / counts / postings:
      * ``rule_bitmap_any``  — OR of all bitmaps (zone-map pruning);
      * ``rule_counts``      — per-rule match counts (metadata-only counts);
      * posting lists for selective rules (the bitmap's inverted index).
    """
    bm = np.asarray(bm)
    bm_any = np.bitwise_or.reduce(bm, axis=0) if len(bm) else \
        np.zeros(bm.shape[1], np.uint32)
    meta = {"rule_bitmap_any": bm_any.tolist()}
    bits = np.unpackbits(bm.view(np.uint8), axis=1, bitorder="little")
    counts = bits.sum(axis=0)
    meta["rule_counts"] = [[int(r), int(c)]
                           for r, c in enumerate(counts) if c]
    postings = {}
    dense_cut = max(1, int(POSTING_DENSITY_CUT * len(bm)))
    for r, c in meta["rule_counts"]:
        if c <= dense_cut:
            postings[str(r)] = np.flatnonzero(bits[:, r]).astype(np.int32)
    return meta, postings


def rules_known_for_versions(version_rules: dict, version_ids) -> dict:
    """Intersect the rule-ident maps of every engine version present in a
    segment: str(rule_id) -> ident for rules that ALL versions knew with the
    same content identity.  A version missing from the registry contributes
    nothing (safe: those rules fall back to scanning)."""
    maps = [version_rules.get(int(v)) for v in version_ids]
    if not maps or any(m is None for m in maps):
        return {}
    common = dict(maps[0])
    for m in maps[1:]:
        common = {rid: ident for rid, ident in common.items()
                  if m.get(rid) == ident}
    return common


def pack_known_bitmap(idents: dict, words: int) -> list:
    """{str(rule_id): ident} -> packed uint32 words (list, JSON-able)."""
    known = np.zeros(words, np.uint32)
    for rid in idents:
        r = int(rid)
        if r < words * 32:
            known[r // 32] |= np.uint32(1 << (r % 32))
    return known.tolist()


@dataclass
class Segment:
    segment_id: int
    num_records: int
    meta: dict                      # zone maps + schema
    _columns: dict = field(default_factory=dict)     # name -> array (may be empty when spilled)
    _text_index: dict = field(default_factory=dict)  # field -> {token: ids}
    _rule_postings: dict = None     # str(rule_id) -> int32 ids (None = absent)
    _rule_counts: tuple = None      # (source object, {int id: count}) cache
    _meta_gen: int = 0              # bumped on every enrichment swap / cache
                                    # drop; see meta_token()
    path: Path = None               # spill directory (None = memory only)
    # serializes cold-load cache fills against apply_update: without it a
    # reader could np.load the OLD file, get descheduled across a swap, and
    # install the stale array under the NEW metadata — permanently.  The
    # in-cache fast paths stay lock-free (install happens-before meta flip).
    _io_lock: object = field(default_factory=threading.Lock)
    # maintenance-epoch publication hook (set by the owning SegmentStore):
    # called with (segment_ids, kind, changed_segments) AFTER a swap/
    # cache-drop bumps the token, so shared-arrangement readers retire the
    # old epoch instead of racing a cache invalidation, and standing-query
    # folds learn the change kind + post-change tokens
    _on_swap: object = None

    # -- column access ---------------------------------------------------
    @property
    def column_names(self) -> tuple:
        return tuple(self.meta["columns"])

    def meta_token(self) -> tuple:
        """Identity of this segment's current enrichment state, usable as a
        cache key by holders of derived artifacts (the query executor's
        device-resident column cache keys on it).  ``apply_update`` and
        ``drop_caches`` both bump the generation, so a maintenance swap or a
        cold-run cache drop can never serve a stale derived array: the old
        token simply stops being produced.  Segment ids are monotonic and
        never reused (compaction allocates fresh ids), so tokens are unique
        across segment objects of one store."""
        return (self.segment_id, self._meta_gen)

    def column(self, name: str, *, cache: bool = True) -> np.ndarray:
        """Read one column; ``cache=False`` models a cold read (load from
        disk, do not retain)."""
        if name in self._columns:
            return self._columns[name]
        if self.path is None:
            raise KeyError(f"segment {self.segment_id}: column {name} dropped "
                           "with no spill path")
        with self._io_lock:
            if name in self._columns:
                return self._columns[name]
            arr = np.load(self.path / f"{name}.npy")
            if cache:
                self._columns[name] = arr
        return arr

    def column_rows(self, name: str, ids: np.ndarray,
                    *, cache: bool = True) -> np.ndarray:
        """Read only the given rows of a column.  Cold reads memory-map the
        file and touch just the matching pages (row-group reads) instead of
        loading the whole column."""
        if name in self._columns:
            return self._columns[name][ids]
        if self.path is None:
            raise KeyError(f"segment {self.segment_id}: column {name}")
        with self._io_lock:
            if name in self._columns:
                return self._columns[name][ids]
            arr = np.load(self.path / f"{name}.npy", mmap_mode="r")
            out = np.array(arr[ids])
            if cache:  # hot mode retains the full column for later queries
                self._columns[name] = np.array(arr)
        return out

    def text_index(self, fieldname: str, *, cache: bool = True) -> dict:
        if fieldname in self._text_index:
            return self._text_index[fieldname]
        if self.path is None:
            raise KeyError(f"segment {self.segment_id}: no text index for "
                           f"{fieldname}")
        with self._io_lock:
            if fieldname in self._text_index:
                return self._text_index[fieldname]
            idx = _load_index(self.path / f"{fieldname}.fts.npz")
            if cache:
                self._text_index[fieldname] = idx
        return idx

    def has_text_index(self, fieldname: str) -> bool:
        if fieldname in self._text_index:
            return True
        return (self.path is not None
                and (self.path / f"{fieldname}.fts.npz").exists())

    def rule_postings(self, rule_id: int, *, cache: bool = True):
        """Seal-time inverted index over the enrichment column: int32 ids
        for selective rules.  Returns None when unavailable (dense rule or
        segment without enrichment) — callers fall back to the bitmap."""
        if self._rule_postings is None:
            if self.path is None or not (self.path / "rule_postings.npz").exists():
                return None
            with self._io_lock:
                if self._rule_postings is not None:
                    return self._rule_postings.get(str(rule_id))
                idx = _load_index(self.path / "rule_postings.npz")
                if cache:
                    self._rule_postings = idx
            return idx.get(str(rule_id))
        return self._rule_postings.get(str(rule_id))

    def rule_count(self, rule_id: int, meta: dict = None):
        """Per-segment precomputed match count (None when unavailable).
        ``meta`` reads from a caller-held snapshot of ``self.meta``."""
        rc = (self.meta if meta is None else meta).get("rule_counts")
        if rc is None:
            return None
        # normalized lookup lives OUTSIDE meta (meta must stay JSON-shaped:
        # mutating it in place leaks {int: int} keys into meta.json as
        # strings, which a reload would then silently miss).  Keyed on the
        # source object so an apply_update meta swap invalidates it.
        if self._rule_counts is None or self._rule_counts[0] is not rc:
            pairs = rc.items() if isinstance(rc, dict) else rc
            self._rule_counts = (rc, {int(r): int(c) for r, c in pairs})
        return self._rule_counts[1].get(int(rule_id), 0)

    # -- maintenance -------------------------------------------------------
    def apply_update(self, *, columns: dict = None, meta_updates: dict = None,
                     rule_postings: dict = None,
                     text_index: dict = None, fence=None) -> None:
        """Atomically swap enrichment artifacts of a sealed segment.

        Maintenance-plane entry point (backfill rewrites ``rule_bitmap`` +
        zone maps + postings).  Safe against concurrent readers:

          * spilled files are written to a temp name and ``os.replace``d, so
            a cold read sees either the old or the new file, never a torn
            one;
          * in-memory columns/postings/indexes are installed *before* the
            metadata flips, and ``self.meta`` is replaced by a single
            attribute assignment — a reader that still sees the old meta
            takes the old (fallback/scan) path, which stays byte-identical
            (**meta-flips-last** ordering: install happens-before flip
            happens-before token bump).

        ``fence`` is the maintenance plane's write barrier: a zero-arg
        callable (``LeaseManager.fence(lease)``) invoked inside the write
        lock before the first mutation.  A writer whose lease was
        superseded raises ``FencedWriteError`` here and the segment is
        untouched — two maintenance workers can never interleave writes on
        one segment.

        Safe on its own only when the new data is a pure *extension* (old
        claims still hold over the new bits).  When previously-claimed bits
        are reinterpreted, the caller must first withdraw those claims with
        a meta-only update — see ``BackfillWorker.backfill_segment``.
        """
        columns = columns or {}
        meta_updates = dict(meta_updates or {})
        for name, arr in columns.items():
            meta_updates.setdefault("columns", dict(self.meta["columns"]))
            meta_updates["columns"][name] = (str(arr.dtype), list(arr.shape))
        # the io lock excludes in-flight cold cache fills: without it a
        # reader could have loaded the OLD file and install it as the cache
        # entry AFTER the swap below, poisoning every later query
        with self._io_lock:
            if fence is not None:
                fence()     # raises FencedWriteError on a superseded lease
            if self.path is not None:
                for name, arr in columns.items():
                    _atomic_save_npy(self.path / f"{name}.npy", arr)
                if rule_postings is not None:
                    _save_index(self.path / "rule_postings.npz", rule_postings)
                if text_index is not None:
                    for fieldname, idx in text_index.items():
                        _save_index(self.path / f"{fieldname}.fts.npz", idx)
            # install data before metadata: a concurrent reader either sees
            # the old meta (-> old path, old semantics) or the new meta with
            # the new data already in place
            for name, arr in columns.items():
                if self.path is None or name in self._columns:
                    self._columns[name] = arr
            if rule_postings is not None:
                self._rule_postings = dict(rule_postings)
            if text_index is not None:
                self._text_index.update(text_index)
            self.meta = {**self.meta, **meta_updates}
            # token bump strictly AFTER the meta flip: a racing reader that
            # observes the new generation is guaranteed to also observe the
            # new meta/columns (install happens-before flip happens-before
            # bump), so nothing stale can ever be cached under a live token
            self._meta_gen += 1
            if self.path is not None:
                _atomic_write_text(self.path / "meta.json", json.dumps(
                    {**self.meta, "segment_id": self.segment_id,
                     "num_records": self.num_records},
                    default=_json_np))
        # epoch publication OUTSIDE the io lock (listeners take their own
        # locks; a listener that re-entered column() must not deadlock)
        if self._on_swap is not None:
            self._on_swap((self.segment_id,), "update", (self,))

    # -- lifecycle ---------------------------------------------------------
    def spill(self, root: Path) -> None:
        """Write one .npy per column (+ .fts.npz per indexed field)."""
        faults.fire("store.spill", segment=self.segment_id)
        d = Path(root) / f"segment-{self.segment_id:06d}"
        d.mkdir(parents=True, exist_ok=True)
        for name, arr in self._columns.items():
            np.save(d / f"{name}.npy", arr)
        for fieldname, idx in self._text_index.items():
            _save_index(d / f"{fieldname}.fts.npz", idx)
        if self._rule_postings is not None:
            _save_index(d / "rule_postings.npz", self._rule_postings)
        (d / "meta.json").write_text(json.dumps(
            {**self.meta, "segment_id": self.segment_id,
             "num_records": self.num_records},
            default=_json_np))
        self.path = d

    def drop_caches(self) -> None:
        """Free in-memory columns/indexes (requires a spill path)."""
        if self.path is None:
            raise RuntimeError("cannot drop caches before spill()")
        with self._io_lock:
            self._columns = {}
            self._text_index = {}
            self._rule_postings = None
            # cold-run semantics extend to device residency: bumping the
            # token invalidates any device-cached copy of our columns, so a
            # cold query re-reads from disk (and is accounted as such)
            self._meta_gen += 1
        if self._on_swap is not None:
            self._on_swap((self.segment_id,), "drop", (self,))

    def nbytes(self, names=None) -> int:
        names = names or self.column_names
        total = 0
        for n in names:
            dtype, shape = self.meta["columns"][n]
            total += int(np.prod(shape)) * np.dtype(dtype).itemsize
        return total

    @staticmethod
    def load(d: Path) -> "Segment":
        meta = json.loads((Path(d) / "meta.json").read_text())
        return Segment(segment_id=meta["segment_id"],
                       num_records=meta["num_records"], meta=meta,
                       path=Path(d))


def _json_np(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def _save_index(path: Path, idx: dict) -> None:
    tokens = sorted(idx)
    lengths = np.asarray([len(idx[t]) for t in tokens], np.int64)
    flat = (np.concatenate([idx[t] for t in tokens]) if tokens
            else np.zeros(0, np.int32))
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez_compressed(f, tokens=np.asarray(tokens), lengths=lengths,
                            flat=flat)
    os.replace(tmp, path)


def _atomic_save_npy(path: Path, arr: np.ndarray) -> None:
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.save(f, arr)
    os.replace(tmp, path)


def _atomic_write_text(path: Path, text: str) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)


def _load_index(path: Path) -> dict:
    z = np.load(path, allow_pickle=False)
    tokens = [str(t) for t in z["tokens"]]
    offsets = np.concatenate([[0], np.cumsum(z["lengths"])])
    flat = z["flat"]
    return {t: flat[offsets[i]:offsets[i + 1]] for i, t in enumerate(tokens)}


class SegmentStore:
    """Append-only columnar store with sealing + spilling."""

    def __init__(self, *, segment_size: int = 100_000, root=None,
                 index_fields: tuple = (), version_rules: dict = None):
        self.segment_size = segment_size
        self.root = Path(root) if root is not None else None
        self.index_fields = tuple(index_fields)
        # engine version_id -> {str(rule_id): ident} — normally the live
        # ``StreamProcessor.version_rules`` dict (IngestPipeline wires it).
        # Lets seal derive the per-segment ``rules_known`` coverage bitmap;
        # without it segments carry no rules_known and the mapper falls back
        # to the coarser version-min check.
        self.version_rules = version_rules
        self.segments: list = []
        self._active: list = []     # pending RecordBatches
        self._active_count = 0
        self._next_id = 0           # monotonic (compaction retires ids)
        self._sealed_rows = 0       # ingest durability watermark (see WAL)
        self._lock = threading.RLock()
        # crash-safe root manifest (spilled stores only): authoritative
        # valid-segment set + durable fencing epochs.  A FRESH store over a
        # root starts with an empty manifest (first commit overwrites any
        # stale file); SegmentStore.load adopts the persisted one instead.
        self.manifest = Manifest(self.root) if self.root is not None else None
        # maintenance-epoch listeners (shared-arrangement stores): every
        # apply_update / drop_caches / replace_segments publishes the
        # affected segment ids here instead of invalidating caches in place
        self._maintenance_listeners: list = []
        # kind-aware delta listeners (standing queries, prefetching
        # arrangement stores): receive an EpochDelta for EVERY epoch,
        # including seals — the legacy segment-id feed above never saw
        # seals, because a new segment invalidates nothing
        self._epoch_listeners: list = []
        self._epoch_seq = 0

    # -- epoch publication ---------------------------------------------------
    def subscribe_maintenance(self, fn) -> None:
        """Register ``fn(segment_ids)`` to be called after every
        maintenance swap (``Segment.apply_update``), cold-run cache drop,
        or compaction retire — the shared-arrangement plane's epoch feed
        (``store.subscribe_maintenance(arrangements.publish)``).

        Idempotent per callable (N engines sharing one ArrangementStore
        publish ONE epoch per swap, not N), and bound methods are held
        weakly: a discarded engine's arrangement store is collectable — a
        store outliving its engines must not pin their device memory.

        Seals are NOT delivered here (a new segment invalidates no derived
        state); subscribe to the kind-aware ``subscribe_epochs`` feed for
        the full change stream."""
        with self._lock:
            self._subscribe_locked(self._maintenance_listeners, fn)

    def subscribe_epochs(self, fn) -> None:
        """Register ``fn(delta: EpochDelta)`` on the kind-aware epoch feed:
        every seal, enrichment swap, cache drop, compaction replace, and
        retention retire publishes one delta carrying the change kind, the
        affected segment ids, the Segment objects entering the store, and
        the post-change ``meta_token()`` of every surviving affected
        segment.  Same subscription discipline as ``subscribe_maintenance``
        (idempotent per callable, bound methods held weakly)."""
        with self._lock:
            self._subscribe_locked(self._epoch_listeners, fn)

    def _subscribe_locked(self, listeners: list, fn) -> None:
        if any(r() == fn for r in listeners):
            return
        ref = (weakref.WeakMethod(fn) if hasattr(fn, "__self__")
               else (lambda f: (lambda: f))(fn))
        listeners.append(ref)
        for s in self.segments:
            s._on_swap = self._publish_epoch

    def _publish_epoch(self, segment_ids, kind: str = "update",
                       changed=(), added=()) -> None:
        """Fan one maintenance epoch out to both feeds.  ``changed`` are
        surviving Segment objects whose artifacts swapped (update/drop);
        ``added`` are Segment objects entering the store (seal/replace).
        Always called OUTSIDE the store and segment locks — listeners take
        their own locks and may re-enter column reads."""
        _EPOCH_PUBLISHES.inc()
        telemetry.emit("epoch_publish", plane="store", change=kind,
                       segments=[int(s) for s in segment_ids])
        delta = None
        dead = False
        for r in list(self._epoch_listeners):
            fn = r()
            if fn is None:
                dead = True
                continue
            if delta is None:
                with self._lock:
                    self._epoch_seq += 1
                    seq = self._epoch_seq
                delta = EpochDelta(
                    seq=seq, kind=kind,
                    segment_ids=tuple(int(s) for s in segment_ids),
                    added=tuple(added),
                    tokens={int(s.segment_id): s.meta_token()
                            for s in (*changed, *added)})
            fn(delta)
        # legacy feed: segment ids only, and no seal deliveries (a fresh
        # segment invalidates no arrangement; publishing would spuriously
        # retire unrelated epochs' bookkeeping)
        if kind != "seal":
            for r in list(self._maintenance_listeners):
                fn = r()
                if fn is None:
                    dead = True
                else:
                    fn(tuple(segment_ids))
        if dead:
            with self._lock:
                self._maintenance_listeners = [
                    r for r in self._maintenance_listeners
                    if r() is not None]
                self._epoch_listeners = [
                    r for r in self._epoch_listeners if r() is not None]

    # -- ingestion ---------------------------------------------------------
    def append(self, batch: RecordBatch) -> None:
        sealed = []
        with self._lock:
            self._active.append(batch)
            self._active_count += len(batch)
            while self._active_count >= self.segment_size:
                sealed.append(self._seal_locked(self.segment_size))
        self._publish_seals(sealed)

    def seal(self) -> None:
        """Seal whatever is pending (end of stream)."""
        with self._lock:
            sealed = ([self._seal_locked(self._active_count)]
                      if self._active_count else [])
        self._publish_seals(sealed)

    def _publish_seals(self, sealed: list) -> None:
        """Seal epochs publish AFTER the store lock releases (listeners —
        standing-query folds — take their own locks and read columns)."""
        for seg in sealed:
            self._publish_epoch((seg.segment_id,), "seal", added=(seg,))

    def _seal_locked(self, n: int) -> Segment:
        merged = RecordBatch.concat(self._active)
        head, tail = merged.slice(0, n), merged.slice(n, len(merged))
        self._active = [tail] if len(tail) else []
        self._active_count = len(tail)
        # the watermark advances with the SAME manifest commit that
        # registers the sealed segment (one atomic write): a crash can
        # never observe a registered segment whose rows are not counted,
        # or a watermark covering rows with no registered segment
        self._sealed_rows += n
        seg = self._make_segment(head, ingest_seal=True)
        self.segments.append(seg)
        return seg

    def _make_segment(self, batch: RecordBatch, register: bool = True,
                      ingest_seal: bool = False) -> Segment:
        sid = self._next_id
        self._next_id += 1
        meta = {"columns": {k: (str(v.dtype), list(v.shape))
                            for k, v in batch.columns.items()}}
        seg_postings = None
        if "timestamp" in batch.columns:
            ts = batch.columns["timestamp"]
            meta["ts_min"], meta["ts_max"] = int(ts.min()), int(ts.max())
        if ENRICH_COLUMN in batch.columns:
            bm = batch.columns[ENRICH_COLUMN]
            # zone map + per-rule counts (metadata-only count queries) +
            # sparse posting lists — the enrichment column's inverted index,
            # built once at seal; copy queries touch postings + matched rows
            enrich_meta, seg_postings = derive_enrichment_meta(bm)
            meta.update(enrich_meta)
        if ENGINE_VERSION_COLUMN in batch.columns:
            ev = batch.columns[ENGINE_VERSION_COLUMN]
            meta["engine_version_min"] = int(ev.min())
            meta["engine_version_max"] = int(ev.max())
            if self.version_rules is not None and ENRICH_COLUMN in batch.columns:
                # rule-aware coverage (maintenance plane): exactly which rule
                # identities every record's enriching engine knew
                idents = rules_known_for_versions(self.version_rules,
                                                  np.unique(ev))
                meta["rule_idents"] = idents
                meta["rules_known"] = pack_known_bitmap(
                    idents, batch.columns[ENRICH_COLUMN].shape[1])
        _SEALED.inc()
        seg = Segment(segment_id=sid, num_records=len(batch), meta=meta,
                      _columns=dict(batch.columns),
                      _rule_postings=seg_postings,
                      _on_swap=self._publish_epoch)
        for f in self.index_fields:
            if f in batch.columns:
                seg._text_index[f] = build_text_index(batch.columns[f])
        if self.root is not None:
            # spill FIRST, register second: a crash in between leaves an
            # unregistered dir that a manifest-guarded load simply ignores
            seg.spill(self.root)
            if register:
                self.manifest.commit(
                    add={sid: seg.path.name}, next_id=self._next_id,
                    sealed_rows=self._sealed_rows if ingest_seal else None)
        return seg

    # -- maintenance -------------------------------------------------------
    def make_segment_from_batch(self, batch: RecordBatch) -> Segment:
        """Build (and spill) a sealed segment outside the append path — the
        Compactor uses this to materialize a merged segment before swapping
        it into the segment list.

        The segment is deliberately NOT registered in the manifest: until
        ``replace_segments`` commits "merged in, inputs out" as one atomic
        manifest write, a crash leaves the spilled artifact invisible to
        ``SegmentStore.load`` — never loaded ALONGSIDE its un-retired
        inputs (the double-count window the manifest closes)."""
        with self._lock:
            return self._make_segment(batch, register=False)

    def replace_segments(self, old: list, new: Segment,
                         *, fence=None) -> bool:
        """Atomically substitute a contiguous run of sealed segments with
        one merged segment.  Returns False (no-op) if any of ``old`` is no
        longer present or the run is not contiguous — the caller simply
        retries next cycle.  Readers that grabbed the previous list keep
        querying the old segment objects, which stay fully valid.

        ``fence`` (a zero-arg callable, e.g. the compactor's check over
        every group member's lease) runs INSIDE the store lock before the
        swap: a writer whose leases were superseded mid-merge raises here
        and commits nothing — without it, a long merge outliving its lease
        TTL could install columns read before a newer fenced install,
        silently undoing it."""
        with self._lock:
            if fence is not None:
                fence()     # raises FencedWriteError on a superseded lease
            try:
                idx = [self.segments.index(s) for s in old]
            except ValueError:
                return False
            if idx != list(range(idx[0], idx[0] + len(idx))):
                return False
            self.segments = (self.segments[:idx[0]] + [new]
                             + self.segments[idx[0] + len(idx):])
            if self.manifest is not None:
                # THE commit point: "merged in, inputs out" lands as one
                # atomic manifest write.  A crash before this line leaves
                # the (unregistered) merged dir invisible; a crash after it
                # leaves the inputs excluded even when their RETIRED
                # tombstones below were never written — either way a reload
                # counts every record exactly once.
                self.manifest.commit(
                    add={new.segment_id: new.path.name}
                    if new.path is not None else None,
                    remove=[s.segment_id for s in old],
                    next_id=self._next_id)
        # compactor retire is a maintenance epoch: arrangements over the
        # replaced segments retire (in-flight leases pin them; the old
        # segment objects and spill files stay valid for those readers)
        self._publish_epoch([s.segment_id for s in old], "replace",
                            added=(new,))
        self._tombstone_all(old)
        return True

    def retire_segments(self, old: list, *, fence=None) -> bool:
        """Atomically remove sealed segments with no replacement — the
        retention plane's age-out path.  Same commit discipline as
        ``replace_segments`` (one manifest write is the commit point,
        tombstones are advisory, ``fence`` runs inside the lock before the
        commit); returns False when any of ``old`` is no longer present
        (raced another maintenance action — retry next cycle).  Readers
        holding the previous segment list keep querying the old objects,
        which stay fully valid until the GC collects their drained spill
        dirs."""
        with self._lock:
            if fence is not None:
                fence()     # raises FencedWriteError on a superseded lease
            if any(s not in self.segments for s in old):
                return False
            self.segments = [s for s in self.segments if s not in old]
            if self.manifest is not None:
                self.manifest.commit(
                    remove=[s.segment_id for s in old])
        self._publish_epoch([s.segment_id for s in old], "retire")
        self._tombstone_all(old)
        return True

    def _tombstone_all(self, old: list) -> None:
        failed = [s.segment_id for s in old if not self._retire_spill(s)]
        if failed and self.manifest is None:
            # pre-manifest stores rely on the tombstone alone: a live
            # un-tombstoned input would be double-loaded (and its records
            # double-counted) by the next SegmentStore.load — this must
            # not pass silently.  Manifest-guarded stores are safe either
            # way (membership already committed); the GC just loses the
            # marker it keys on.
            warnings.warn(
                f"segments {failed}: failed to tombstone replaced spill "
                f"dirs; SegmentStore.load would double-count their records",
                RuntimeWarning, stacklevel=2)

    def _retire_spill(self, seg: Segment) -> bool:
        """Tombstone a replaced segment's spill dir so ``load`` skips it.
        The files are NOT moved: in-flight cold readers holding the old
        segment object keep reading them at the same paths (renaming the
        dir would make their next ``np.load`` crash).  A future GC pass
        deletes tombstoned dirs once no reader can hold the old list."""
        if seg.path is None:
            return True
        try:
            (seg.path / RETIRED_MARKER).touch()
            return True
        except OSError as e:
            telemetry.suppressed("store.retire_spill", e)
            return False

    # -- bookkeeping ---------------------------------------------------------
    @property
    def num_records(self) -> int:
        with self._lock:
            return sum(s.num_records for s in self.segments) + self._active_count

    @property
    def sealed_rows(self) -> int:
        """Total rows the ingest path has sealed into registered segments
        — the durability watermark the ingest WAL truncates against.
        Monotonic across the store's lifetime (compaction/retention change
        membership, never this counter)."""
        with self._lock:
            return self._sealed_rows

    def account_skipped_rows(self, n: int) -> None:
        """Advance the ingest durability watermark past ``n`` source rows
        that will never be appended (the pipeline quarantined them after
        both match lanes failed).  Seals any pending rows first so the
        watermark stays prefix-accurate: W always means source rows
        [0, W) are durable — in a registered segment or in quarantine."""
        sealed = []
        with self._lock:
            if self._active_count:
                sealed.append(self._seal_locked(self._active_count))
            self._sealed_rows += int(n)
            if self.manifest is not None:
                self.manifest.commit(sealed_rows=self._sealed_rows)
        self._publish_seals(sealed)

    def drop_caches(self) -> None:
        """Cold-run control: all sealed segments forget in-memory data."""
        for s in self.segments:
            s.drop_caches()

    def refresh(self) -> dict:
        """Converge this (rooted) store onto the on-disk world another
        *process* may have advanced — the read side of the multi-process
        topology, where maintenance workers and the ingest parent hold
        independent ``SegmentStore`` objects over one root.

        Three deltas are reconciled against the persisted manifest and the
        per-segment ``meta.json`` files (each written atomically, so every
        read here sees a consistent before-or-after state):

          * **added** — segments the manifest lists that this store has
            never loaded (another process sealed or compacted them in);
            loaded and published as ``seal`` epochs;
          * **removed** — in-memory segments the manifest no longer lists
            (another process compacted/retired them); dropped from the
            segment list and published as ``retire`` epochs;
          * **updated** — spilled segments whose on-disk ``meta.json``
            differs from the in-memory meta (another process's backfill
            ``apply_update`` swapped enrichment artifacts); the new meta is
            installed under the segment's io lock, caches are purged, the
            meta token bumps, and an ``update`` epoch publishes — exactly
            the invalidation discipline an in-process swap follows.

        Deliberately does NOT touch ``self.manifest``'s in-memory state:
        this store's own pending commits (e.g. a seal racing the refresh)
        must never be rolled back by re-adopting a snapshot.  In the
        supported topology the manifest has a single writer process;
        refresh only reconciles *membership and artifacts* for readers.

        Returns ``{"added": [...], "removed": [...], "updated": [...]}``
        segment-id lists.  No-op (empty deltas) for rootless stores.
        """
        empty = {"added": [], "removed": [], "updated": []}
        if self.root is None:
            return empty
        persisted = Manifest.read(self.root)
        if persisted is None:
            return empty
        on_disk = {int(s): str(name)
                   for s, name in persisted.get("segments", {}).items()}
        added, removed, updated = [], [], []
        with self._lock:
            have = {s.segment_id: s for s in self.segments}
            for sid in sorted(have):
                if sid not in on_disk:
                    removed.append(have[sid])
            for sid, name in sorted(on_disk.items()):
                if sid in have:
                    continue
                d = self.root / name
                if not d.exists():
                    continue    # mid-commit window; next refresh sees it
                seg = Segment.load(d)
                seg._on_swap = self._publish_epoch
                added.append(seg)
            if removed:
                gone = {s.segment_id for s in removed}
                self.segments = [s for s in self.segments
                                 if s.segment_id not in gone]
            self.segments.extend(added)
            self._next_id = max(self._next_id,
                                int(persisted.get("next_id", 0)))
        for sid, seg in sorted(have.items()):
            if sid not in on_disk or seg.path is None:
                continue
            try:
                disk_meta = json.loads((seg.path / "meta.json").read_text())
            except (FileNotFoundError, ValueError):
                continue
            # normalize the in-memory meta through the same JSON round-trip
            # the spill path uses, so an unchanged segment compares equal
            cur = json.loads(json.dumps(
                {**seg.meta, "segment_id": seg.segment_id,
                 "num_records": seg.num_records}, default=_json_np))
            if disk_meta == cur:
                continue
            with seg._io_lock:
                seg.meta = disk_meta
                seg._columns = {}
                seg._text_index = {}
                seg._rule_postings = None
                seg._rule_counts = None
                seg._meta_gen += 1
            updated.append(seg)
        # epoch publication outside every lock, mirroring the in-process
        # paths: seals for arrivals, retire for departures, one update
        # epoch covering every artifact swap
        for seg in added:
            self._publish_epoch((seg.segment_id,), "seal", added=(seg,))
        if removed:
            self._publish_epoch([s.segment_id for s in removed], "retire")
        if updated:
            self._publish_epoch([s.segment_id for s in updated], "update",
                                changed=tuple(updated))
        return {"added": [s.segment_id for s in added],
                "removed": [s.segment_id for s in removed],
                "updated": [s.segment_id for s in updated]}

    def storage_nbytes(self, names=None) -> int:
        return sum(s.nbytes(names) for s in self.segments)

    @staticmethod
    def load(root, *, segment_size: int = 100_000,
             index_fields: tuple = (), version_rules: dict = None
             ) -> "SegmentStore":
        """Reopen a spilled store.  When a root manifest exists it is
        authoritative: exactly the manifest's valid-segment set is loaded
        (closing the compaction double-count window — a crash between
        spilling a merged segment and tombstoning its inputs leaves both
        on disk, but only one side is ever in the manifest).  Pre-manifest
        stores fall back to directory scanning with RETIRED-tombstone
        skipping, and are upgraded: the adopted set is committed as their
        first manifest.

        ``segment_size``/``index_fields``/``version_rules`` configure the
        reopened store's FUTURE seals (persisted segments carry their
        own); an ingest restart must pass the same settings it ingests
        with — constructing a fresh ``SegmentStore`` over a populated
        root instead would start an empty manifest whose first commit
        disowns every already-committed segment."""
        store = SegmentStore(root=root, segment_size=segment_size,
                             index_fields=index_fields,
                             version_rules=version_rules)
        persisted = Manifest.read(root)
        if persisted is not None:
            store.manifest.adopt(persisted)
            dirs = []
            for d in store.manifest.segment_dirs():
                if d.exists():
                    dirs.append(d)
                else:
                    # the manifest is the authority on what SHOULD exist:
                    # a listed dir gone missing is data loss (external
                    # deletion, partial restore) and must not reload as a
                    # silently smaller store — the mirror hazard of the
                    # double-count window the manifest closes
                    _SEGMENTS_MISSING.inc()
                    telemetry.emit("segment_missing", plane="store",
                                   dir=d.name, root=str(root))
                    warnings.warn(
                        f"manifest lists {d.name} but the spill dir is "
                        f"missing; its records are LOST from this load",
                        RuntimeWarning, stacklevel=2)
        elif (Path(root) / INGEST_WAL_DIRNAME).exists():
            # a WAL dir proves this store was born under manifest
            # discipline: no manifest on disk means the process died before
            # the FIRST commit, so any spilled segment dir is an
            # uncommitted orphan whose rows the journal still holds.
            # Adopting it would double-ingest on replay — recovery re-seals
            # (and overwrites) it from the WAL instead.
            dirs = []
        else:
            dirs = [d for d in sorted(Path(root).glob("segment-*"))
                    if not (d / RETIRED_MARKER).exists()]
        for d in dirs:
            seg = Segment.load(d)
            seg._on_swap = store._publish_epoch
            store.segments.append(seg)
        store._next_id = max(
            store.manifest.next_id(),
            1 + max((s.segment_id for s in store.segments), default=-1))
        store._sealed_rows = store.manifest.sealed_rows()
        if persisted is None and store.segments:
            store.manifest.commit(
                add={s.segment_id: s.path.name for s in store.segments},
                next_id=store._next_id)
        return store
