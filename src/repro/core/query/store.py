"""Columnar analytical plane — segments, seal, spill, zone maps, FTS index.

The in-framework analogue of Pinot REALTIME segments / a Parquet data lake:
record batches append into an active (mutable) segment; at ``segment_size``
records the segment **seals** — columns freeze, per-segment metadata (zone
maps) is derived, and an optional **text index** (token -> posting list, the
Pinot FTS analogue) is built.  Sealed segments can **spill** to disk as one
file per column, so queries read only the columns they touch (columnar I/O),
and caches can be dropped per column to measure genuine cold-run behaviour
(paper §4.2).

Zone maps kept per segment:
  * min/max ``timestamp``;
  * OR of all enrichment bitmaps (``rule_bitmap_any``) — a segment whose
    combined bitmap lacks a query's rule bits is **pruned without any I/O**,
    the mechanism behind the paper's cold-run wins ("data pruning possible
    with our approach that avoids I/O bottlenecks", §6.3.1);
  * min/max ``engine_version_id`` — consistency propagation (§3.4 step 4):
    the mapper only uses the enriched path on segments whose records were all
    ingested with an engine that knew the rule.
"""
from __future__ import annotations

import json
import re
import threading
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.records import RecordBatch, decode_texts
from repro.core.stream_processor import ENGINE_VERSION_COLUMN, ENRICH_COLUMN

_TOKEN_RE = re.compile(r"[A-Za-z0-9_\-./:]+")


def tokenize(text: str) -> list:
    return _TOKEN_RE.findall(text)


def build_text_index(data: np.ndarray) -> dict:
    """(N, L) uint8 -> token -> sorted int32 record ids (inverted index)."""
    postings: dict = {}
    for rid, text in enumerate(decode_texts(data)):
        for tok in set(tokenize(text)):
            postings.setdefault(tok, []).append(rid)
    return {t: np.asarray(ids, np.int32) for t, ids in postings.items()}


@dataclass
class Segment:
    segment_id: int
    num_records: int
    meta: dict                      # zone maps + schema
    _columns: dict = field(default_factory=dict)     # name -> array (may be empty when spilled)
    _text_index: dict = field(default_factory=dict)  # field -> {token: ids}
    _rule_postings: dict = None     # str(rule_id) -> int32 ids (None = absent)
    path: Path = None               # spill directory (None = memory only)

    # -- column access ---------------------------------------------------
    @property
    def column_names(self) -> tuple:
        return tuple(self.meta["columns"])

    def column(self, name: str, *, cache: bool = True) -> np.ndarray:
        """Read one column; ``cache=False`` models a cold read (load from
        disk, do not retain)."""
        if name in self._columns:
            return self._columns[name]
        if self.path is None:
            raise KeyError(f"segment {self.segment_id}: column {name} dropped "
                           "with no spill path")
        arr = np.load(self.path / f"{name}.npy")
        if cache:
            self._columns[name] = arr
        return arr

    def column_rows(self, name: str, ids: np.ndarray,
                    *, cache: bool = True) -> np.ndarray:
        """Read only the given rows of a column.  Cold reads memory-map the
        file and touch just the matching pages (row-group reads) instead of
        loading the whole column."""
        if name in self._columns:
            return self._columns[name][ids]
        if self.path is None:
            raise KeyError(f"segment {self.segment_id}: column {name}")
        arr = np.load(self.path / f"{name}.npy", mmap_mode="r")
        out = np.array(arr[ids])
        if cache:  # hot mode retains the full column for later queries
            self._columns[name] = np.array(arr)
        return out

    def text_index(self, fieldname: str, *, cache: bool = True) -> dict:
        if fieldname in self._text_index:
            return self._text_index[fieldname]
        if self.path is None:
            raise KeyError(f"segment {self.segment_id}: no text index for "
                           f"{fieldname}")
        idx = _load_index(self.path / f"{fieldname}.fts.npz")
        if cache:
            self._text_index[fieldname] = idx
        return idx

    def has_text_index(self, fieldname: str) -> bool:
        if fieldname in self._text_index:
            return True
        return (self.path is not None
                and (self.path / f"{fieldname}.fts.npz").exists())

    def rule_postings(self, rule_id: int, *, cache: bool = True):
        """Seal-time inverted index over the enrichment column: int32 ids
        for selective rules.  Returns None when unavailable (dense rule or
        segment without enrichment) — callers fall back to the bitmap."""
        if self._rule_postings is None:
            if self.path is None or not (self.path / "rule_postings.npz").exists():
                return None
            idx = _load_index(self.path / "rule_postings.npz")
            if cache:
                self._rule_postings = idx
            return idx.get(str(rule_id))
        return self._rule_postings.get(str(rule_id))

    def rule_count(self, rule_id: int):
        """Per-segment precomputed match count (None when unavailable)."""
        rc = self.meta.get("rule_counts")
        if rc is None:
            return None
        if not isinstance(rc, dict):
            rc = {int(r): int(c) for r, c in rc}
            self.meta["rule_counts"] = rc
        return rc.get(int(rule_id), 0)

    # -- lifecycle ---------------------------------------------------------
    def spill(self, root: Path) -> None:
        """Write one .npy per column (+ .fts.npz per indexed field)."""
        d = Path(root) / f"segment-{self.segment_id:06d}"
        d.mkdir(parents=True, exist_ok=True)
        for name, arr in self._columns.items():
            np.save(d / f"{name}.npy", arr)
        for fieldname, idx in self._text_index.items():
            _save_index(d / f"{fieldname}.fts.npz", idx)
        if self._rule_postings is not None:
            _save_index(d / "rule_postings.npz", self._rule_postings)
        (d / "meta.json").write_text(json.dumps(
            {**self.meta, "segment_id": self.segment_id,
             "num_records": self.num_records},
            default=_json_np))
        self.path = d

    def drop_caches(self) -> None:
        """Free in-memory columns/indexes (requires a spill path)."""
        if self.path is None:
            raise RuntimeError("cannot drop caches before spill()")
        self._columns = {}
        self._text_index = {}
        self._rule_postings = None

    def nbytes(self, names=None) -> int:
        names = names or self.column_names
        total = 0
        for n in names:
            dtype, shape = self.meta["columns"][n]
            total += int(np.prod(shape)) * np.dtype(dtype).itemsize
        return total

    @staticmethod
    def load(d: Path) -> "Segment":
        meta = json.loads((Path(d) / "meta.json").read_text())
        return Segment(segment_id=meta["segment_id"],
                       num_records=meta["num_records"], meta=meta,
                       path=Path(d))


def _json_np(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def _save_index(path: Path, idx: dict) -> None:
    tokens = sorted(idx)
    lengths = np.asarray([len(idx[t]) for t in tokens], np.int64)
    flat = (np.concatenate([idx[t] for t in tokens]) if tokens
            else np.zeros(0, np.int32))
    np.savez_compressed(path, tokens=np.asarray(tokens), lengths=lengths,
                        flat=flat)


def _load_index(path: Path) -> dict:
    z = np.load(path, allow_pickle=False)
    tokens = [str(t) for t in z["tokens"]]
    offsets = np.concatenate([[0], np.cumsum(z["lengths"])])
    flat = z["flat"]
    return {t: flat[offsets[i]:offsets[i + 1]] for i, t in enumerate(tokens)}


class SegmentStore:
    """Append-only columnar store with sealing + spilling."""

    def __init__(self, *, segment_size: int = 100_000, root=None,
                 index_fields: tuple = ()):
        self.segment_size = segment_size
        self.root = Path(root) if root is not None else None
        self.index_fields = tuple(index_fields)
        self.segments: list = []
        self._active: list = []     # pending RecordBatches
        self._active_count = 0
        self._lock = threading.RLock()

    # -- ingestion ---------------------------------------------------------
    def append(self, batch: RecordBatch) -> None:
        with self._lock:
            self._active.append(batch)
            self._active_count += len(batch)
            while self._active_count >= self.segment_size:
                self._seal_locked(self.segment_size)

    def seal(self) -> None:
        """Seal whatever is pending (end of stream)."""
        with self._lock:
            if self._active_count:
                self._seal_locked(self._active_count)

    def _seal_locked(self, n: int) -> None:
        merged = RecordBatch.concat(self._active)
        head, tail = merged.slice(0, n), merged.slice(n, len(merged))
        self._active = [tail] if len(tail) else []
        self._active_count = len(tail)
        self.segments.append(self._make_segment(head))

    def _make_segment(self, batch: RecordBatch) -> Segment:
        sid = len(self.segments)
        meta = {"columns": {k: (str(v.dtype), list(v.shape))
                            for k, v in batch.columns.items()}}
        seg_postings = None
        if "timestamp" in batch.columns:
            ts = batch.columns["timestamp"]
            meta["ts_min"], meta["ts_max"] = int(ts.min()), int(ts.max())
        if ENRICH_COLUMN in batch.columns:
            bm = batch.columns[ENRICH_COLUMN]
            bm_any = np.bitwise_or.reduce(bm, axis=0)
            meta["rule_bitmap_any"] = bm_any.tolist()
            # per-rule match counts (sparse): count queries on a single rule
            # are answered from segment METADATA, no column I/O — the
            # columnar-engine move of keeping per-segment aggregates
            bits = np.unpackbits(bm.view(np.uint8), axis=1, bitorder="little")
            counts = bits.sum(axis=0)
            meta["rule_counts"] = [[int(r), int(c)]
                                   for r, c in enumerate(counts) if c]
            # sparse per-rule posting lists (selective rules only): the
            # enrichment column's inverted index, built once at seal — copy
            # queries touch postings + matched rows, never the full column
            postings = {}
            dense_cut = max(1, int(0.1 * len(batch)))
            for r, c in meta["rule_counts"]:
                if c <= dense_cut:
                    postings[str(r)] = np.flatnonzero(bits[:, r]).astype(
                        np.int32)
            seg_postings = postings
        if ENGINE_VERSION_COLUMN in batch.columns:
            ev = batch.columns[ENGINE_VERSION_COLUMN]
            meta["engine_version_min"] = int(ev.min())
            meta["engine_version_max"] = int(ev.max())
        seg = Segment(segment_id=sid, num_records=len(batch), meta=meta,
                      _columns=dict(batch.columns),
                      _rule_postings=seg_postings)
        for f in self.index_fields:
            if f in batch.columns:
                seg._text_index[f] = build_text_index(batch.columns[f])
        if self.root is not None:
            seg.spill(self.root)
        return seg

    # -- bookkeeping ---------------------------------------------------------
    @property
    def num_records(self) -> int:
        with self._lock:
            return sum(s.num_records for s in self.segments) + self._active_count

    def drop_caches(self) -> None:
        """Cold-run control: all sealed segments forget in-memory data."""
        for s in self.segments:
            s.drop_caches()

    def storage_nbytes(self, names=None) -> int:
        return sum(s.nbytes(names) for s in self.segments)

    @staticmethod
    def load(root) -> "SegmentStore":
        store = SegmentStore(root=root)
        for d in sorted(Path(root).glob("segment-*")):
            store.segments.append(Segment.load(d))
        return store
