"""Process-backed query sharding — ``ShardedQueryExecutor`` across the GIL.

``ShardedQueryExecutor`` partitions a physical plan's tasks across threads;
useful overlap, but one GIL.  ``ProcessQueryPool`` runs the same
segment-hash sharding (``lease.shard_of``, the read-side analogue of the
maintenance pool's shard map) as N spawn *processes*:

  * each shard process opens the store via ``SegmentStore.load`` and keeps
    only its hash shard of the segment list;
  * each shard builds its own ``QueryEngine`` — and therefore **leases its
    own arrangements**: the Shared-Arrangements guarantee (each word column
    crosses H2D once per maintenance epoch) holds *per process*, so the
    per-column upload multiplicity a process contributes is exactly 1 per
    epoch regardless of how many queries it serves;
  * a query broadcast returns counts (count mode) or per-segment matched
    row ids (ids mode) over the pipe; the parent sums counts / unions ids.

Failure semantics mirror the thread sharder's graceful degradation: a
shard that errors, stalls, or dies contributes a *failed* shard (the
merged result is marked partial with its segments accounted as failed)
and is respawned for the next query — never a poisoned pool.
"""
from __future__ import annotations

import multiprocessing as mp
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core import telemetry
from repro.core.maintenance.lease import shard_of

_SHARD_DEATHS = telemetry.counter(
    "fluxsieve_query_shard_process_deaths_total",
    help="Query shard processes that died or timed out mid-query.")


def _shard_main(cfg: dict, conn) -> None:
    """Shard child: store (this shard's segments only) + private engine +
    private arrangement store.  Serves query commands until EOF."""
    from repro.core import faults
    from repro.core.query.engine import QueryEngine, Query, filter_expired
    from repro.core.query.mapper import QueryMapper
    from repro.core.query.store import SegmentStore

    store = SegmentStore.load(cfg["root"], segment_size=cfg["segment_size"],
                              index_fields=tuple(cfg["index_fields"]))
    index, shards = cfg["shard_index"], cfg["num_shards"]
    store.segments = [s for s in store.segments
                      if shard_of(s.segment_id, shards) == index]
    engine = QueryEngine(store, mapper=QueryMapper(cfg["ruleset"]),
                         backend=cfg["backend"], block_n=cfg["block_n"],
                         interpret=cfg["interpret"])
    ident = f"{cfg['worker_id']}/shard-{index}"

    while True:
        try:
            cmd = conn.recv()
        except (EOFError, OSError, KeyboardInterrupt):
            break
        try:
            op = cmd[0]
            if op == "stop":
                conn.send(("bye", None))
                break
            elif op == "query":
                terms, mode, path = cmd[1], cmd[2], cmd[3]
                faults.fire("query.shard", shard=index, worker=ident)
                # ids mode plans as a copy query (id-producing path
                # classes); count mode may legally answer from metadata
                q = Query(terms=tuple(tuple(t) for t in terms),
                          mode="copy" if mode == "ids" else "count")
                if mode == "ids":
                    plan = engine.plan(q, path=path)
                    per_seg = engine.executor.execute(plan, engine.planner)
                    count, ids_by_seg = 0, {}
                    for task, (ids, stats) in zip(plan.tasks, per_seg):
                        if ids is None:
                            continue
                        if isinstance(ids, (int, np.integer)):
                            count += int(ids)
                            continue
                        ids, _ = filter_expired(task, ids, True)
                        count += len(ids)
                        if len(ids):
                            ids_by_seg[int(task.seg.segment_id)] = \
                                np.asarray(ids, np.int64)
                    reply = ("result", {"count": count,
                                        "ids": ids_by_seg,
                                        "segments": len(plan.tasks)})
                else:
                    r = engine.execute(q, path=path)
                    reply = ("result", {
                        "count": int(r.count), "ids": None,
                        "segments": r.segments_total,
                        "scanned": r.segments_scanned,
                        "pruned": r.segments_pruned,
                        "fallback": r.segments_fallback,
                        "failed": r.segments_failed})
            elif op == "refresh":
                deltas = store.refresh()
                # refresh may have pulled in segments of other shards
                # (new seals land wherever the manifest says) — re-filter
                store.segments = [s for s in store.segments
                                  if shard_of(s.segment_id, shards) == index]
                reply = ("ok", deltas)
            elif op == "stats":
                reply = ("stats", {
                    "uploads_per_column": dict(
                        engine.arrangements.upload_counts()),
                    "h2d_bytes": int(engine.arrangements.h2d_bytes),
                    "device_bytes_peak": int(
                        engine.arrangements.device_bytes_peak),
                    "segments": len(store.segments)})
            elif op == "reset_stats":
                engine.arrangements.uploads.clear()
                engine.arrangements.h2d_bytes = 0
                engine.arrangements.device_bytes_peak = \
                    engine.arrangements.device_bytes
                reply = ("ok", None)
            else:
                reply = ("error", f"unknown command {cmd[0]!r}")
        except faults.InjectedCrash:
            import os
            import signal
            os.kill(os.getpid(), signal.SIGKILL)
        except BaseException as e:  # noqa: BLE001 — report, keep serving
            reply = ("error", f"{type(e).__name__}: {e}")
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            break


@dataclass
class ProcessQueryResult:
    """Merged result of one query fanned across shard processes."""
    count: int = 0
    ids: dict = field(default_factory=dict)     # segment_id -> row ids
    segments_total: int = 0
    segments_failed: int = 0
    shards_served: int = 0
    shards_failed: int = 0
    latency_s: float = 0.0

    @property
    def partial(self) -> bool:
        return self.shards_failed > 0


class ProcessQueryPool:
    """N query shards as spawn processes over one spilled store root.

    ``ruleset`` is the active (picklable) RuleSet the shard mappers serve;
    queries broadcast as ``(terms, mode)`` where mode is ``"count"``
    (merged count) or ``"ids"`` (merged per-segment matched row ids).
    ``stats()`` reads each shard's private arrangement accounting — the
    bench's per-process upload-multiplicity evidence.
    """

    def __init__(self, root, ruleset, *, shards: int = 2,
                 backend: str = "ref", block_n: int = 1024,
                 interpret: bool = True, segment_size: int = 100_000,
                 index_fields: tuple = (), worker_id: str = "query-proc",
                 recv_timeout: float = 120.0):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.root = Path(root)
        self.shards = shards
        self.recv_timeout = float(recv_timeout)
        self._ctx = mp.get_context("spawn")
        self._cfg_base = {
            "root": str(self.root), "ruleset": ruleset, "backend": backend,
            "block_n": block_n, "interpret": interpret,
            "segment_size": int(segment_size),
            "index_fields": tuple(index_fields),
            "num_shards": shards, "worker_id": worker_id,
        }
        self._workers = [self._spawn(i) for i in range(shards)]

    def _spawn(self, index: int) -> dict:
        parent_conn, child_conn = self._ctx.Pipe()
        cfg = {**self._cfg_base, "shard_index": index}
        proc = self._ctx.Process(
            target=_shard_main, args=(cfg, child_conn),
            name=f"{self._cfg_base['worker_id']}-{index}", daemon=True)
        proc.start()
        child_conn.close()
        return {"index": index, "proc": proc, "conn": parent_conn,
                "alive": True}

    def _ensure_workers(self) -> None:
        for i, w in enumerate(self._workers):
            if w["alive"] and w["proc"].is_alive():
                continue
            self._mark_dead(w)
            self._workers[i] = self._spawn(w["index"])

    def _mark_dead(self, w: dict) -> None:
        if not w["alive"]:
            return
        w["alive"] = False
        try:
            w["conn"].close()
        except OSError:
            pass
        if w["proc"].is_alive():
            w["proc"].kill()
        w["proc"].join(timeout=5.0)

    def _request(self, w: dict, cmd: tuple):
        if not w["alive"]:
            return None
        try:
            w["conn"].send(cmd)
            deadline = time.monotonic() + self.recv_timeout
            while True:
                if w["conn"].poll(0.05):
                    return w["conn"].recv()
                if not w["proc"].is_alive() and not w["conn"].poll(0.05):
                    raise EOFError("shard process died")
                if time.monotonic() > deadline:
                    raise TimeoutError("shard command timed out")
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError,
                TimeoutError):
            self._mark_dead(w)
            _SHARD_DEATHS.inc()
            telemetry.emit("query_shard_death", plane="query",
                           shard=w["index"], command=cmd[0])
            return None

    def close(self) -> None:
        for w in self._workers:
            if w["alive"]:
                try:
                    w["conn"].send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
        for w in self._workers:
            if w["alive"]:
                w["proc"].join(timeout=5.0)
            self._mark_dead(w)

    def __del__(self):
        try:
            self.close()
        except Exception:   # noqa: BLE001 — interpreter teardown
            pass

    # -- query surface -----------------------------------------------------
    def execute(self, terms, *, mode: str = "count",
                path: str = "fluxsieve") -> ProcessQueryResult:
        """Fan one query out to every shard and merge.  ``terms`` is the
        ``Query.terms`` tuple (picklable); a dead/failed shard degrades the
        result to partial rather than raising — the thread sharder's
        contract, held across processes."""
        self._ensure_workers()
        t0 = time.perf_counter()
        out = ProcessQueryResult()
        # broadcast first, then collect: shards execute concurrently
        inflight = []
        for w in self._workers:
            try:
                w["conn"].send(("query", tuple(terms), mode, path))
                inflight.append(w)
            except (BrokenPipeError, OSError):
                self._mark_dead(w)
                _SHARD_DEATHS.inc()
                out.shards_failed += 1
        for w in inflight:
            reply = self._collect(w)
            if reply is None or reply[0] != "result":
                out.shards_failed += 1
                continue
            r = reply[1]
            out.count += r["count"]
            out.segments_total += r["segments"]
            out.segments_failed += r.get("failed", 0)
            if r["ids"]:
                out.ids.update(r["ids"])
            out.shards_served += 1
        out.latency_s = time.perf_counter() - t0
        return out

    def _collect(self, w: dict):
        try:
            deadline = time.monotonic() + self.recv_timeout
            while True:
                if w["conn"].poll(0.05):
                    reply = w["conn"].recv()
                    if reply[0] == "error":
                        telemetry.emit("query_shard_error", plane="query",
                                       shard=w["index"], error=reply[1])
                        return None
                    return reply
                if not w["proc"].is_alive() and not w["conn"].poll(0.05):
                    raise EOFError("shard process died mid-query")
                if time.monotonic() > deadline:
                    raise TimeoutError("shard query timed out")
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError,
                TimeoutError):
            self._mark_dead(w)
            _SHARD_DEATHS.inc()
            telemetry.emit("query_shard_death", plane="query",
                           shard=w["index"], command="query")
            return None

    def refresh(self) -> None:
        """Every shard re-reads the on-disk world (new seals, maintenance
        installs) and re-filters to its hash shard."""
        self._ensure_workers()
        for w in self._workers:
            self._request(w, ("refresh",))

    def stats(self) -> list:
        """Per-shard arrangement accounting:
        ``[{"uploads_per_column", "h2d_bytes", "device_bytes_peak",
        "segments"}, ...]`` — each shard's PRIVATE arrangement store, so
        ``max(uploads_per_column.values()) == 1`` per epoch per process is
        the Shared-Arrangements invariant held across the GIL boundary."""
        self._ensure_workers()
        out = []
        for w in self._workers:
            reply = self._request(w, ("stats",))
            out.append(reply[1] if reply is not None
                       and reply[0] == "stats" else None)
        return out

    def reset_stats(self) -> None:
        """Zero every shard's upload/H2D accounting (bench lane
        boundaries)."""
        self._ensure_workers()
        for w in self._workers:
            self._request(w, ("reset_stats",))
