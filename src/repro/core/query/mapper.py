"""Query Mapper — rewrites queries onto precomputed enrichment (paper §3.2
module 5): "translates incoming queries into optimized internal queries that
exploit the precomputed fields ... bypassing expensive full-table scans".

A (field, term) predicate maps to a registered rule when the rule's pattern
matches the term exactly and the rule covers the field.  The plan carries one
query-time bitmap mask per predicate (AND semantics across predicates).

Consistency propagation (paper §3.4 step 4): the mapper is notified of every
activated engine version and remembers at which version id each rule first
became active; a segment is covered only if ALL its records were enriched by
an engine that knew every needed rule (checked against the segment's
``engine_version_min`` zone map).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import enrichment
from repro.core.patterns import RuleSet, escape
from repro.core.query.store import Segment


@dataclass(frozen=True)
class FluxSievePlan:
    masks: tuple            # one (W,) uint32 mask per query predicate
    rule_ids: tuple
    min_version_id: int     # newest version id any needed rule was added at

    def covers_segment(self, seg: Segment) -> bool:
        v = seg.meta.get("engine_version_min")
        return v is not None and v >= self.min_version_id


class QueryMapper:
    def __init__(self, ruleset: RuleSet = None, *, version_id: int = 0):
        self._rules_by_key: dict = {}   # (field, pattern) -> rule_id
        self._rule_added_at: dict = {}  # rule_id -> version id
        self._num_rules = 0
        self._version_id = version_id
        if ruleset is not None:
            self.notify(ruleset, version_id)

    # -- updater notification ------------------------------------------------
    def notify(self, ruleset: RuleSet, version_id: int) -> None:
        """Called whenever a new engine version activates (§3.4 step 4)."""
        self._version_id = version_id
        self._num_rules = max(self._num_rules, ruleset.num_rules)
        keys = {}
        for r in ruleset.rules:
            for f in r.fields:
                keys[(f, r.pattern)] = r.rule_id
            if r.rule_id not in self._rule_added_at:
                self._rule_added_at[r.rule_id] = version_id
        # rules removed in this version no longer map
        self._rules_by_key = keys

    @property
    def num_rules(self) -> int:
        return self._num_rules

    # -- planning --------------------------------------------------------
    def lookup(self, fieldname: str, term: str):
        for t in (term, escape(term)):
            rid = self._rules_by_key.get((fieldname, t))
            if rid is None:
                rid = self._rules_by_key.get(("*", t))
            if rid is not None:
                return rid
        return None

    def map(self, query) -> FluxSievePlan:
        """-> plan, or None when any predicate lacks a registered rule."""
        masks, rids = [], []
        min_vid = 0
        for fieldname, term in query.terms:
            rid = self.lookup(fieldname, term)
            if rid is None:
                return None
            masks.append(enrichment.rule_mask([rid], self._num_rules))
            rids.append(rid)
            min_vid = max(min_vid, self._rule_added_at.get(rid, 0))
        return FluxSievePlan(masks=tuple(masks), rule_ids=tuple(rids),
                             min_version_id=min_vid)
