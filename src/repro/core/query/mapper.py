"""Query Mapper — rewrites queries onto precomputed enrichment (paper §3.2
module 5): "translates incoming queries into optimized internal queries that
exploit the precomputed fields ... bypassing expensive full-table scans".

A (field, term) predicate maps to a registered rule when the rule's pattern
matches the term exactly and the rule covers the field.  The plan carries one
query-time bitmap mask per predicate (AND semantics across predicates).

Consistency propagation (paper §3.4 step 4): a segment is covered only if
ALL its records were enriched by an engine that knew every needed rule.
The primary check is **rule-aware**: segments carry a ``rules_known`` bitmap
plus per-rule content identities (``rule_idents``), written at seal and kept
current by the maintenance plane's backfill — so a late-added rule becomes
servable on historical segments the moment they are re-enriched.  Segments
sealed without that metadata fall back to the coarser version-min check
(``engine_version_min`` zone map).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import enrichment
from repro.core.patterns import RuleSet, escape, rule_ident
from repro.core.query.store import Segment


@dataclass(frozen=True)
class FluxSievePlan:
    masks: tuple            # one (W,) uint32 mask per query predicate
    rule_ids: tuple
    rule_idents: tuple      # content identity per rule_id (parallel tuple)
    min_version_id: int     # newest version id any needed rule was added at

    def word_slices(self) -> tuple:
        """``(words, bits)`` — per-predicate bitmap word index plus in-word
        mask, the word-sliced encoding the batched executor ships to the
        device (``bitmap_query_words``).  Every plan predicate is a single
        rule, i.e. a single-bit mask, so one (word, bit) pair per predicate
        is exact — and the device plane only ever gathers the P word
        columns a query touches, not the full (N, W) bitmap.  Coverage
        guarantees each word index lies inside every covered segment's
        bitmap width."""
        words = tuple(int(r) // 32 for r in self.rule_ids)
        bits = np.asarray([np.uint32(1) << np.uint32(int(r) % 32)
                           for r in self.rule_ids], np.uint32)
        return words, bits

    def covers_segment(self, seg: Segment, meta: dict = None) -> bool:
        """``meta`` lets the engine evaluate coverage against a snapshot of
        ``seg.meta`` (concurrent maintenance swaps the meta object; checking
        a snapshot and re-validating its identity after the read makes the
        check-then-read race detectable)."""
        meta = seg.meta if meta is None else meta
        known = meta.get("rules_known")
        if known is not None:
            # rule-aware coverage: every needed rule id must be known AND
            # its content identity must match (a changed pattern reuses the
            # id but yields stale bits until backfill re-matches it)
            idents = meta.get("rule_idents") or {}
            for rid, ident in zip(self.rule_ids, self.rule_idents):
                w = rid // 32
                if w >= len(known) or not (int(known[w]) >> (rid % 32)) & 1:
                    return False
                if idents.get(str(rid)) != ident:
                    return False
            return True
        v = meta.get("engine_version_min")
        return v is not None and v >= self.min_version_id


class QueryMapper:
    def __init__(self, ruleset: RuleSet = None, *, version_id: int = 0):
        self._rules_by_key: dict = {}   # (field, pattern) -> rule_id
        self._rule_added_at: dict = {}  # rule_id -> version id
        self._idents: dict = {}         # rule_id -> content identity
        self._num_rules = 0
        self._version_id = version_id
        if ruleset is not None:
            self.notify(ruleset, version_id)

    # -- updater notification ------------------------------------------------
    def notify(self, ruleset: RuleSet, version_id: int) -> None:
        """Called whenever a new engine version activates (§3.4 step 4)."""
        self._version_id = version_id
        self._num_rules = max(self._num_rules, ruleset.num_rules)
        keys = {}
        idents = {}
        for r in ruleset.rules:
            for f in r.fields:
                keys[(f, r.pattern)] = r.rule_id
            idents[r.rule_id] = rule_ident(r)
            if (r.rule_id not in self._rule_added_at
                    or self._idents.get(r.rule_id) not in (None,
                                                           idents[r.rule_id])):
                # new rule — or same id with CHANGED content: bits enriched
                # before this version are stale, so the version-min fallback
                # (segments without rules_known metadata) must not trust them
                self._rule_added_at[r.rule_id] = version_id
        # rules removed in this version no longer map; forget their added-at
        # too, so a later RE-ADD counts as new (segments sealed during the
        # removal window have no bits for it and must not look covered)
        for rid in list(self._rule_added_at):
            if rid not in idents:
                del self._rule_added_at[rid]
        self._rules_by_key = keys
        self._idents = idents

    @property
    def num_rules(self) -> int:
        return self._num_rules

    # -- planning --------------------------------------------------------
    def lookup(self, fieldname: str, term: str):
        for t in (term, escape(term)):
            rid = self._rules_by_key.get((fieldname, t))
            if rid is None:
                rid = self._rules_by_key.get(("*", t))
            if rid is not None:
                return rid
        return None

    def map(self, query) -> FluxSievePlan:
        """-> plan, or None when any predicate lacks a registered rule."""
        masks, rids = [], []
        min_vid = 0
        for fieldname, term in query.terms:
            rid = self.lookup(fieldname, term)
            if rid is None:
                return None
            masks.append(enrichment.rule_mask([rid], self._num_rules))
            rids.append(rid)
            min_vid = max(min_vid, self._rule_added_at.get(rid, 0))
        return FluxSievePlan(masks=tuple(masks), rule_ids=tuple(rids),
                             rule_idents=tuple(self._idents.get(r, "")
                                               for r in rids),
                             min_version_id=min_vid)
