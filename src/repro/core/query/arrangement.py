"""Shared-arrangement device plane — one refcounted, epoch-versioned device
arrangement per (segment set, word subset), leased by ALL in-flight queries.

Shared Arrangements (McSherry et al.) observes that concurrency over
overlapping data scales only when concurrent readers share ONE maintained
arrangement instead of each materializing a private copy; Functional
Isolation (Zapridou et al.) adds that the shared substrate must still
isolate per-query execution.  Here the arrangement is the stacked device
image of the enrichment-bitmap WORD columns a query family touches:

  * ``ArrangementStore`` pools device word columns keyed by
    ``(Segment.meta_token(), word)`` and assembles them into stacked
    ``Arrangement``s keyed by ``(segment-token tuple, word tuple)`` —
    each word column crosses the H2D link **once per maintenance epoch**,
    no matter how many queries (or shards) are in flight over it;
  * queries access an arrangement only through an RAII-style
    ``ArrangementLease`` (refcount up on acquire, down on release, leaks
    detected at finalization) — per-query execution state stays private,
    only the immutable device image is shared;
  * maintenance (``Segment.apply_update``, ``SegmentStore.
    replace_segments``, compactor retire, cold-run cache drops)
    **publishes a new epoch** instead of invalidating in place: the
    affected arrangements and pooled columns are *retired* — in-flight
    leases pin them (readers never observe a torn swap), new queries bind
    the new epoch's tokens and build fresh entries, and a retired entry
    frees its device memory deterministically the moment its refcount
    drains.

Accounting (``uploads``, ``h2d_bytes``, ``device_bytes`` /
``device_bytes_peak``) is first-class so tests can assert the
once-per-epoch upload discipline and benchmarks can report H2D traffic and
device-memory high-water per sharing regime.
"""
from __future__ import annotations

import threading
import warnings
from collections import Counter
from dataclasses import dataclass

from repro.core import telemetry

# Process-wide telemetry ALONGSIDE the per-object accounting: tests and
# benchmarks keep reading per-store ``uploads``/``h2d_bytes``/... values;
# the registry aggregates across every store in the process.
_T_UPLOADS = telemetry.counter(
    "fluxsieve_arrangement_uploads_total",
    help="Word-column H2D uploads into the shared device pool.")
_T_H2D_BYTES = telemetry.counter(
    "fluxsieve_arrangement_h2d_bytes_total",
    help="Bytes crossing the H2D link for arrangement columns.")
_T_BUILDS = telemetry.counter(
    "fluxsieve_arrangement_builds_total",
    help="Arrangement assemblies (stack builds).")
_T_LEASE_HITS = telemetry.counter(
    "fluxsieve_arrangement_lease_hits_total",
    help="Leases served from an already-live arrangement.")
_T_EVICT_ARR = telemetry.counter(
    "fluxsieve_arrangement_evictions_total",
    labels={"kind": "arrangement"},
    help="Evictions from the shared device plane, by kind.")
_T_EVICT_COL = telemetry.counter(
    "fluxsieve_arrangement_evictions_total", labels={"kind": "column"})
_T_EPOCHS = telemetry.counter(
    "fluxsieve_arrangement_epochs_total",
    help="Maintenance epochs published to the device plane.")
_T_RETIRED = telemetry.counter(
    "fluxsieve_arrangement_epoch_retirements_total",
    help="Arrangements retired by an epoch publication.")
_T_LEAKS = telemetry.counter(
    "fluxsieve_arrangement_lease_leaks_total",
    help="Leases released at finalization instead of by their owner.")
_T_PREFETCH = telemetry.counter(
    "fluxsieve_arrangement_prefetch_total",
    help="Arrangements rebuilt eagerly on epoch publish (off the query "
         "path), so the first post-swap query skips the cold build.")
_DEV_BYTES = telemetry.gauge(
    "fluxsieve_arrangement_device_bytes",
    help="Device bytes resident across all arrangement stores.")
_DEV_PEAK = telemetry.gauge(
    "fluxsieve_arrangement_device_bytes_peak",
    help="High-water mark of resident arrangement device bytes.")


@dataclass(frozen=True)
class ArrangementItem:
    """One segment's contribution to an arrangement build.

    ``token`` is the segment's ``meta_token()`` read at lease-key time —
    BEFORE ``load`` touches the host column — so a racing maintenance swap
    can only pool new data under an already-dead token, never stale data
    under a live one (the same discipline the executor's snapshot
    validation relies on).  ``load`` returns the host ``(N, W)`` bitmap and
    is invoked only on a pool miss, at most once per build per segment."""
    token: tuple
    num_records: int
    load: object


class _DeviceColumn:
    """Pooled device word column: ``refs`` counts live arrangements built
    over it; ``retired`` marks its token dead (freed once refs drain)."""

    __slots__ = ("key", "arr", "nbytes", "refs", "retired")

    def __init__(self, key, arr, nbytes: int):
        self.key = key
        self.arr = arr
        self.nbytes = int(nbytes)
        self.refs = 0
        self.retired = False


class Arrangement:
    """One epoch-stamped stacked device image: ``stack`` is the
    ``(bucket_n(sum lens), P)`` uint32 concatenation of every segment's
    gathered word columns, ``row_seg`` the padded per-row segment-slot
    vector, ``lens`` the unpadded per-segment record counts."""

    __slots__ = ("key", "tokens", "words", "epoch", "stack", "row_seg",
                 "lens", "columns", "nbytes", "refcount", "retired",
                 "block_n")

    def __init__(self, key, epoch, stack, row_seg, lens, columns, nbytes,
                 block_n: int = 1024):
        self.key = key
        self.tokens, self.words = key
        self.epoch = epoch
        self.stack = stack
        self.row_seg = row_seg
        self.lens = lens
        self.columns = columns          # pooled _DeviceColumns we hold refs on
        self.nbytes = nbytes            # stack + row_seg (columns accounted
        self.refcount = 0               # separately in the pool)
        self.retired = False
        self.block_n = block_n          # padding bucket (prefetch rebuilds
                                        # reproduce the family's key shape)


class ArrangementLease:
    """RAII handle on a shared arrangement.  Release exactly once (context
    manager or explicit ``release()``); a lease collected unreleased is a
    bug — it is released at finalization with a ``ResourceWarning`` naming
    the owning worker so leaks are attributable, not silent pins."""

    __slots__ = ("arrangement", "owner", "_store", "_released", "__weakref__")

    def __init__(self, arrangement: Arrangement, owner: str, store):
        self.arrangement = arrangement
        self.owner = owner
        self._store = store
        self._released = False

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        if self._released:
            return
        self._released = True
        if self._store is not None:
            self._store._release(self)

    def __enter__(self) -> "ArrangementLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __del__(self):
        if not self._released:
            if self._store is not None:
                self._store.leaks += 1
            try:    # interpreter teardown may have torn telemetry down
                _T_LEAKS.inc()
                telemetry.emit("lease_leak", plane="arrangement",
                               owner=self.owner,
                               key=repr(self.arrangement.key))
            except Exception as e:  # noqa: BLE001
                telemetry.suppressed("arrangement.lease_leak_emit", e)
            warnings.warn(
                f"ArrangementLease leaked by {self.owner!r} "
                f"(key={self.arrangement.key!r}) — released at finalization",
                ResourceWarning, stacklevel=1)
            self.release()


class ArrangementStore:
    """The shared device plane.  Thread-safe; one instance is shared by
    every executor shard and (typically) every engine over one
    ``SegmentStore`` — wire maintenance with
    ``segment_store.subscribe_epochs(arrangements.on_epoch)`` (the
    kind-aware feed; the legacy
    ``subscribe_maintenance(arrangements.publish)`` wiring still works) so
    swaps publish epochs here instead of invalidating anything in place.

    ``max_live`` bounds the number of DISTINCT live arrangements (query
    families); evicting one only retires it — leased readers keep it alive
    until their refcounts drain.  ``max_pool_columns`` bounds the device
    column pool (LRU over unreferenced columns): the once-per-epoch upload
    guarantee holds while the working set fits the pool; beyond it, the
    coldest unreferenced columns re-upload on next use instead of growing
    device residency monotonically between epochs."""

    def __init__(self, *, max_live: int = 32, max_pool_columns: int = 1024):
        self.max_live = max_live
        self.max_pool_columns = max_pool_columns
        self._lock = threading.Lock()
        self._epoch = 0
        self._live = {}             # key -> Arrangement (insertion-ordered)
        self._building = {}         # key -> threading.Event
        self._doomed_builds = set()  # keys published-over while building
        self._columns = {}          # (token, word) -> _DeviceColumn, in LRU
                                    # order (moved to end on every hit)
        self._pool_index = {}       # (segment_id, word) -> current column
        # accounting
        self.uploads: Counter = Counter()   # (token, word) -> H2D uploads
        self.h2d_bytes = 0
        self.device_bytes = 0
        self.device_bytes_peak = 0
        self.builds = 0
        self.lease_hits = 0
        self.leaks = 0
        self.prefetches = 0
        self._lease_owners: Counter = Counter()
        # prefetch source (set via set_prefetch_source): segment_id ->
        # ArrangementItem with the segment's CURRENT token, or None when
        # the segment left the store.  Enables eager post-swap rebuilds.
        self._prefetch_source = None

    # -- epoch plane -------------------------------------------------------
    @property
    def epoch(self) -> int:
        return self._epoch

    def publish(self, segment_ids=None) -> int:
        """Maintenance epoch publication: retire every arrangement (and
        pooled column) touching ``segment_ids`` (``None`` = all).  Nothing
        is freed under a reader — retired entries with live refcounts
        survive until they drain; drained ones free immediately.  Returns
        the new epoch."""
        epoch, _ = self._publish_collect(segment_ids)
        return epoch

    def set_prefetch_source(self, fn) -> None:
        """Arm epoch-publish prefetch: ``fn(segment_id)`` must return an
        ``ArrangementItem`` bound to the segment's current token (or None
        when the segment is gone).  With a source set, ``on_epoch`` eagerly
        rebuilds the arrangements an ``update`` epoch retired — off the
        query path, so the first post-swap query leases a hot entry
        instead of paying the cold build."""
        self._prefetch_source = fn

    def on_epoch(self, delta) -> None:
        """Kind-aware epoch feed entry (``store.subscribe_epochs``
        target).  Seals publish nothing here — a new segment invalidates
        no arrangement.  Cache drops retire WITHOUT prefetching (cold-run
        semantics: re-warming device state would un-drop the caches);
        updates retire and, when a prefetch source is armed, rebuild the
        retired live arrangements under the swapped segments' new
        tokens."""
        if delta.kind == "seal":
            return
        _, retired = self._publish_collect(delta.segment_ids)
        if delta.kind == "update" and self._prefetch_source is not None:
            self._prefetch(retired)

    def _publish_collect(self, segment_ids) -> tuple:
        """publish() + the retired live arrangements' rebuild specs
        ``[(tokens, words, block_n)]`` (prefetch input)."""
        ids = None if segment_ids is None else {int(s) for s in segment_ids}

        def touches(tokens):
            return ids is None or any(t[0] in ids for t in tokens)

        retired = []
        with self._lock:
            self._epoch += 1
            _T_EPOCHS.inc()
            for key in [k for k, a in self._live.items()
                        if touches(a.tokens)]:
                arr = self._live.pop(key)
                retired.append((arr.tokens, arr.words, arr.block_n))
                self._retire_locked(arr)
                _T_RETIRED.inc()
            # a build in flight over the published segments must not enter
            # _live as a fresh entry: its key is marked doomed and the
            # finished arrangement installs already-retired (its lease
            # stays readable; the executor's snapshot check governs reuse)
            for key in self._building:
                if touches(key[0]):
                    self._doomed_builds.add(key)
            for ck in [ck for ck, c in self._columns.items()
                       if ids is None or ck[0][0] in ids]:
                col = self._columns[ck]
                col.retired = True
                if col.refs == 0:
                    self._remove_column_locked(col)
            return self._epoch, retired

    def _prefetch(self, retired: list) -> None:
        """Rebuild each retired live arrangement under the current tokens:
        swapped segments resolve fresh items (new token -> fresh upload),
        untouched ones keep their pooled columns, and the lease/release
        installs the entry at refcount 0 — exactly what the next query of
        the new epoch leases without building.  Best-effort: a segment
        that left the store or a failed build skips that family."""
        source = self._prefetch_source
        for tokens, words, block_n in retired:
            try:
                items = [source(t[0]) for t in tokens]
                if any(it is None for it in items):
                    continue        # a member segment left the store
                self.lease(items, words, block_n=block_n,
                           owner="prefetch").release()
                self.prefetches += 1
                _T_PREFETCH.inc()
                telemetry.emit("arrangement_prefetch", plane="arrangement",
                               segments=len(items), words=len(words))
            except Exception as e:  # noqa: BLE001 — prefetch is advisory
                telemetry.suppressed("arrangement.prefetch", e)

    # -- lease plane -------------------------------------------------------
    def lease(self, items, words, *, block_n: int = 1024,
              owner: str = "query") -> ArrangementLease:
        """Acquire (building if absent) the arrangement for these segments
        and word columns.  Concurrent leases of one key coalesce into a
        single build — the others block until it is published, so N
        clients cost one upload per word column, not N."""
        key = (tuple(i.token for i in items), tuple(words))
        while True:
            with self._lock:
                arr = self._live.get(key)
                if arr is not None:
                    arr.refcount += 1
                    self.lease_hits += 1
                    _T_LEASE_HITS.inc()
                    return self._make_lease_locked(arr, owner)
                ev = self._building.get(key)
                if ev is None:
                    self._building[key] = ev = threading.Event()
                    break
            ev.wait()
        try:
            arr = self._build(key, items, words, block_n)
        except BaseException:
            with self._lock:
                self._building.pop(key, None)
                self._doomed_builds.discard(key)
            ev.set()                # waiters retry (one becomes the builder)
            raise
        # install atomically with clearing the build marker, BEFORE waking
        # waiters: a racing client always sees the key in _building or in
        # _live, so a finished build can never be silently overwritten by a
        # duplicate (which would orphan its bytes and column refs)
        with self._lock:
            self._building.pop(key, None)
            doomed = key in self._doomed_builds
            self._doomed_builds.discard(key)
            # a publish raced the build: the lease stays valid (tokens were
            # read before the swap; the executor's snapshot validation
            # decides whether the RESULT is reusable) but the arrangement
            # installs retired — it frees when this lease drains instead of
            # squatting a _live slot under dead tokens
            if doomed:
                arr.retired = True
            else:
                self._live[key] = arr
                self._evict_locked()
            arr.refcount += 1
            lease = self._make_lease_locked(arr, owner)
        ev.set()
        return lease

    def build_ephemeral(self, items, words, *, block_n: int = 1024,
                        owner: str = "cold") -> ArrangementLease:
        """Cold-run build: nothing pooled, nothing counted as shared-plane
        traffic — models a query that must pay the full upload itself."""
        stack, row_seg, lens, nbytes = self._assemble(
            items, words, block_n, pooled=False)
        arr = Arrangement((tuple(i.token for i in items), tuple(words)),
                          self._epoch, stack, row_seg, lens, (), nbytes,
                          block_n)
        arr.retired = True              # frees as soon as the lease drops
        arr.refcount = 1
        with self._lock:
            self._alloc_bytes(nbytes)   # balanced by the release-time free
            return self._make_lease_locked(arr, owner)

    def active_leases(self) -> dict:
        """owner -> live lease count (leak visibility per worker ident)."""
        with self._lock:
            return {o: n for o, n in self._lease_owners.items() if n}

    def live_arrangements(self) -> int:
        with self._lock:
            return len(self._live)

    def upload_counts(self) -> dict:
        """(segment token, word) -> H2D uploads.  The shared-arrangement
        invariant is every value == 1: one upload per word column per
        maintenance epoch (a swap issues a NEW token, hence a new key)."""
        with self._lock:
            return dict(self.uploads)

    def pinned_segment_ids(self) -> set:
        """Segment ids still referenced by in-flight readers — the
        epoch-drain signal the spill GC consults before deleting a RETIRED
        segment's directory.  A pooled column with ``refs > 0`` belongs to
        at least one live arrangement (every pooled build refs its
        columns, and an arrangement's columns drain exactly when its last
        lease releases), so scanning referenced columns covers every
        leased arrangement, retired or live."""
        with self._lock:
            return {ck[0][0] for ck, col in self._columns.items()
                    if col.refs > 0}

    # -- internals ---------------------------------------------------------
    def _make_lease_locked(self, arr, owner):
        self._lease_owners[owner] += 1
        return ArrangementLease(arr, owner, self)

    def _release(self, lease: ArrangementLease) -> None:
        with self._lock:
            self._lease_owners[lease.owner] -= 1
            arr = lease.arrangement
            arr.refcount -= 1
            if arr.refcount == 0 and arr.retired:
                self._free_arrangement_locked(arr)

    def _retire_locked(self, arr: Arrangement) -> None:
        arr.retired = True
        if arr.refcount == 0:
            self._free_arrangement_locked(arr)

    def _free_arrangement_locked(self, arr: Arrangement) -> None:
        self._free_bytes(arr.nbytes)
        arr.stack = arr.row_seg = None      # drop device buffers
        for col in arr.columns:
            col.refs -= 1
            if col.refs == 0 and col.retired:
                self._remove_column_locked(col)
        arr.columns = ()

    def _remove_column_locked(self, col: _DeviceColumn) -> None:
        if self._columns.get(col.key) is col:
            del self._columns[col.key]
        iw = (col.key[0][0], col.key[1])
        if self._pool_index.get(iw) is col:
            del self._pool_index[iw]
        self._free_bytes(col.nbytes)
        col.arr = None

    def _evict_columns_locked(self) -> None:
        """LRU-bound the pool: drop the coldest UNREFERENCED live columns
        (retired ones free on drain; referenced ones belong to live
        arrangements).  An evicted column simply re-uploads on next use."""
        if len(self._columns) <= self.max_pool_columns:
            return
        for ck in list(self._columns):
            if len(self._columns) <= self.max_pool_columns:
                break
            col = self._columns[ck]
            if col.refs == 0 and not col.retired:
                self._remove_column_locked(col)
                _T_EVICT_COL.inc()

    def _evict_locked(self) -> None:
        while len(self._live) > self.max_live:
            # cost-weighted: evict the CHEAPEST-to-rebuild arrangement
            # (device bytes proxy its upload+assembly cost), so expensive
            # families stay resident under pressure.  Ties break on
            # insertion order (oldest first).  Leased readers keep the
            # evicted entry alive until their refcounts drain.
            key = min(self._live, key=lambda k: self._live[k].nbytes)
            self._retire_locked(self._live.pop(key))
            _T_EVICT_ARR.inc()

    def _alloc_bytes(self, n: int) -> None:
        self.device_bytes += int(n)
        self.device_bytes_peak = max(self.device_bytes_peak,
                                     self.device_bytes)
        _DEV_PEAK.track_max(_DEV_BYTES.inc(int(n)))

    def _free_bytes(self, n: int) -> None:
        self.device_bytes -= int(n)
        _DEV_BYTES.dec(int(n))

    def _build(self, key, items, words, block_n) -> Arrangement:
        stack, row_seg, lens, nbytes = self._assemble(
            items, words, block_n, pooled=True)
        with self._lock:
            self.builds += 1
            _T_BUILDS.inc()
            cols = []
            for it in items:
                for w in words:
                    col = self._columns.get((it.token, w))
                    if col is not None:
                        col.refs += 1
                        cols.append(col)
            arr = Arrangement(key, self._epoch, stack, row_seg, lens,
                              tuple(cols), nbytes, block_n)
            self._alloc_bytes(nbytes)
            return arr

    def _assemble(self, items, words, block_n, *, pooled: bool):
        """Gather/upload the word columns and assemble the padded stack.
        All eager device ops in the query plane live HERE, once per
        arrangement — a hot query is one jitted dispatch plus one D2H."""
        import jax.numpy as jnp
        import numpy as np

        from repro.kernels.dfa_scan.ops import bucket_n

        parts, lens = [], []
        for it in items:
            host = None
            cols = []
            for w in words:
                dev = self._pool_get((it.token, w)) if pooled else None
                if dev is None:
                    if host is None:
                        host = np.asarray(it.load())
                    dev = jnp.asarray(np.ascontiguousarray(host[:, w]))
                    if pooled:
                        dev = self._pool_put((it.token, w), dev)
                cols.append(dev)
            parts.append(cols[0][:, None] if len(cols) == 1
                         else jnp.stack(cols, axis=1))
            lens.append(int(it.num_records))
        stack = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        row_seg = np.repeat(np.arange(len(items), dtype=np.int32), lens)
        n_pad = bucket_n(stack.shape[0], block_n)
        if n_pad != stack.shape[0]:
            stack = jnp.pad(stack, ((0, n_pad - stack.shape[0]), (0, 0)))
            row_seg = np.pad(row_seg, (0, n_pad - len(row_seg)))
        row_seg = jnp.asarray(row_seg)
        nbytes = int(stack.size) * 4 + int(row_seg.size) * 4
        return stack, row_seg, tuple(lens), nbytes

    def _pool_get(self, ck):
        with self._lock:
            col = self._columns.get(ck)
            if col is None or col.retired:
                return None
            self._columns.pop(ck)           # LRU bump: move to the end
            self._columns[ck] = col
            return col.arr

    def _pool_put(self, ck, dev):
        """Install an uploaded column; a concurrent build of an overlapping
        key may have won the race — its copy is kept (and only its upload
        counted) so the pool never holds two live copies of one column."""
        nbytes = int(dev.size) * 4
        with self._lock:
            col = self._columns.get(ck)
            if col is not None and not col.retired:
                return col.arr
            # supersede a retired predecessor (older token, same segment +
            # word) still pinned by readers — O(1) via the pool index
            iw = (ck[0][0], ck[1])
            prev = self._pool_index.get(iw)
            if prev is not None and prev.key != ck:
                prev.retired = True
                if prev.refs == 0:
                    self._remove_column_locked(prev)
            col = _DeviceColumn(ck, dev, nbytes)
            self._columns[ck] = col
            self._pool_index[iw] = col
            self.uploads[ck] += 1
            self.h2d_bytes += nbytes
            _T_UPLOADS.inc()
            _T_H2D_BYTES.inc(nbytes)
            self._alloc_bytes(nbytes)
            self._evict_columns_locked()
            return dev
