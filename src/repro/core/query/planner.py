"""Logical query planner — consult metadata ONCE, classify every segment.

The planner is the read-side analogue of the ingest plane's fused dispatch
discipline (PR 2): instead of each physical path re-deriving per-segment
decisions mid-scan, the planner walks the segment list a single time,
evaluating the mapper plan, zone maps, coverage metadata, and index
availability against ONE meta snapshot per segment, and emits a
``PhysicalPlan`` — a first-class object carrying a per-segment
classification into physical path classes:

  ``pruned``      zone-map OR-bitmap lacks a needed bit — zero I/O;
  ``meta_count``  answered from precomputed per-rule counts — zero I/O;
  ``postings``    seal-time rule posting lists, intersected for AND;
  ``bitmap``      enrichment-bitmap scan — the executor batches ALL of
                  these into a single stacked device dispatch;
  ``fallback``    consistency fallback (records predate a rule) -> full
                  scan.  Full scans never read enrichment state, so their
                  results are returned directly, never re-validated;
  ``text_index``  token posting-list lookup (Pinot FTS baseline);
  ``full_scan``   vectorized substring scan (DuckDB baseline).

Each classification pins the ``seg.meta`` snapshot it was derived from; the
executor re-validates the snapshot identity after reading data (the
maintenance plane can swap enrichment mid-query) and re-plans just the
segments that moved.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.query.store import RETENTION_CUTOFF

# physical path classes
PRUNED = "pruned"
META_COUNT = "meta_count"
POSTINGS = "postings"
BITMAP = "bitmap"
FALLBACK = "fallback"
TEXT_INDEX = "text_index"
FULL_SCAN = "full_scan"
PATH_CLASSES = (PRUNED, META_COUNT, POSTINGS, BITMAP, FALLBACK,
                TEXT_INDEX, FULL_SCAN)

# classes that read enrichment state and therefore must be re-validated
# against the meta snapshot after execution (fallback/full scans must NOT:
# they depend only on raw text columns, which never change after seal)
VALIDATED_CLASSES = (PRUNED, META_COUNT, POSTINGS, BITMAP)


@dataclass
class SegmentTask:
    """One segment's classification inside a ``PhysicalPlan``."""
    seg: object                 # Segment
    meta: dict                  # the meta snapshot the classification used
    path_class: str
    count: int = None           # META_COUNT: precomputed match count
    postings: tuple = None      # POSTINGS: one int32 id array per rule
    cutoff: int = None          # retention straddler: rows with
                                # timestamp < cutoff are logically expired
                                # (engine filters returned ids centrally)


@dataclass
class PhysicalPlan:
    """Per-query physical plan: logical path + per-segment classifications.

    ``tasks`` preserves segment order, so copy-mode materialization
    concatenates record batches in the same order as the legacy paths."""
    query: object
    path: str                   # chosen logical path
    flux: object = None         # FluxSievePlan (fluxsieve path only)
    tasks: list = field(default_factory=list)

    def class_counts(self) -> dict:
        out = {}
        for t in self.tasks:
            out[t.path_class] = out.get(t.path_class, 0) + 1
        return out

    def tasks_of(self, path_class: str) -> list:
        return [t for t in self.tasks if t.path_class == path_class]

    # -- sharding (the plan is the unit of distribution) -------------------
    def subplan(self, indices) -> "PhysicalPlan":
        """A shard's view of this plan: same query/logical path/flux, the
        given task subset.  Classifications (and their meta snapshots) are
        shared with the parent, so a shard's snapshot-validate-retry
        re-plans exactly the segments swapped under IT."""
        sub = PhysicalPlan(query=self.query, path=self.path, flux=self.flux)
        sub.tasks = [self.tasks[i] for i in indices]
        return sub

    def shard_tasks(self, shards: int, *,
                    affinity: str = "weighted") -> list:
        """Partition task indices into at most ``shards`` non-empty
        groups.

        ``affinity="weighted"`` (default) balances *cost*, not count:
        greedy longest-processing-time assignment by per-segment record
        count (the read-side analogue of the maintenance plane's
        heat-weighted ``shard_of``), so stacked-dispatch sizes stay even
        under skewed segment sizes.  Deterministic — task order sorts on
        (record count desc, segment id) and ties in shard load break on
        shard index — so repeated queries over an unchanged store produce
        identical groups, keeping each shard's arrangement key hot.

        ``affinity="modulo"`` keys on ``segment_id % shards`` — the
        legacy scheme, stable across seals/compactions of OTHER segments
        (kept for A/B comparison; see bench_standing's shard lanes)."""
        n = max(1, shards)
        groups = [[] for _ in range(n)]
        if affinity == "modulo":
            for i, t in enumerate(self.tasks):
                groups[t.seg.segment_id % n].append(i)
            return [g for g in groups if g]
        if affinity != "weighted":
            raise ValueError(f"unknown shard affinity {affinity!r}")
        order = sorted(range(len(self.tasks)),
                       key=lambda i: (-int(self.tasks[i].seg.num_records),
                                      self.tasks[i].seg.segment_id))
        loads = [0] * n
        for i in order:
            k = loads.index(min(loads))
            groups[k].append(i)
            # +1 keeps empty segments from piling onto one shard
            loads[k] += int(self.tasks[i].seg.num_records) + 1
        for g in groups:
            g.sort()        # preserve plan order inside each shard
        return [g for g in groups if g]


class QueryPlanner:
    """Builds ``PhysicalPlan``s.  The mapper is consulted by the engine
    (its ``FluxSievePlan`` arrives pre-built via ``flux``) so planning cost
    here is pure metadata classification."""

    def __init__(self, mapper=None):
        self.mapper = mapper

    # -- logical path selection (was QueryEngine._fallback_path) -----------
    def logical_path(self, query, segments, *, path: str = "auto",
                     flux=None) -> str:
        if path != "auto":
            return path
        if flux is not None:
            return "fluxsieve"
        if segments and all(s.has_text_index(f) for f, _ in query.terms
                            for s in segments):
            return "text_index"
        return "full_scan"

    # -- planning -----------------------------------------------------------
    def plan(self, query, segments, *, path: str = "auto", flux=None,
             cache: bool = True) -> PhysicalPlan:
        chosen = self.logical_path(query, segments, path=path, flux=flux)
        if chosen == "fluxsieve" and flux is None:
            raise ValueError("query not covered by registered rules; "
                             "no fluxsieve plan")
        plan = PhysicalPlan(query=query, path=chosen,
                            flux=flux if chosen == "fluxsieve" else None)
        for seg in segments:
            if chosen == "fluxsieve":
                plan.tasks.append(self.classify(seg, query, flux, cache))
            else:
                meta = seg.meta
                expired, cutoff = self._expiry(meta)
                cls = (PRUNED if expired
                       else TEXT_INDEX if chosen == "text_index"
                       else FULL_SCAN)
                plan.tasks.append(SegmentTask(seg=seg, meta=meta,
                                              path_class=cls, cutoff=cutoff))
        return plan

    @staticmethod
    def _expiry(meta: dict) -> tuple:
        """Retention visibility at plan time: a segment the retention plane
        stamped with ``retention_cutoff`` is awaiting physical compaction,
        but its expired rows must already be invisible.  ->
        ``(fully_expired, cutoff)`` — fully expired segments (every row
        below the cutoff) classify as PRUNED with zero I/O; straddlers
        carry the cutoff so the engine filters returned ids centrally
        (and the planner refuses metadata shortcuts that would count
        expired rows)."""
        cutoff = meta.get(RETENTION_CUTOFF)
        if cutoff is None:
            return False, None
        ts_max = meta.get("ts_max")
        return (ts_max is not None and ts_max < cutoff), int(cutoff)

    def classify(self, seg, query, flux, cache: bool = True) -> SegmentTask:
        """Classify ONE segment for the fluxsieve path against a single
        ``seg.meta`` snapshot (also the executor's re-plan entry after a
        mid-query maintenance swap invalidates a task)."""
        meta = seg.meta
        # retention: fully expired segments prune outright; straddlers
        # carry the cutoff through every class below
        expired, cutoff = self._expiry(meta)
        if expired:
            return SegmentTask(seg=seg, meta=meta, path_class=PRUNED)
        # consistency: records ingested before a rule existed -> full scan
        if not flux.covers_segment(seg, meta):
            return SegmentTask(seg=seg, meta=meta, path_class=FALLBACK,
                               cutoff=cutoff)
        # zone-map pruning: segment-level OR of bitmaps lacks a needed bit
        zone = meta.get("rule_bitmap_any")
        if zone is not None:
            zone = np.asarray(zone, np.uint32)
            for mask in flux.masks:
                # widths may differ across engine generations; a bit beyond
                # the segment's bitmap width cannot be set in any record
                k = min(len(zone), len(mask))
                if not (zone[:k] & mask[:k]).any():
                    return SegmentTask(seg=seg, meta=meta, path_class=PRUNED)
        # single-rule count: answered from per-segment metadata, zero I/O —
        # but not on straddlers: the precomputed count includes expired rows
        if query.mode == "count" and len(flux.rule_ids) == 1 \
                and cutoff is None:
            c = seg.rule_count(flux.rule_ids[0], meta)
            if c is not None:
                return SegmentTask(seg=seg, meta=meta, path_class=META_COUNT,
                                   count=int(c))
        # seal-time rule postings (sparse inverted index over the bitmap)
        postings = [seg.rule_postings(rid, cache=cache)
                    for rid in flux.rule_ids]
        if all(p is not None for p in postings):
            return SegmentTask(seg=seg, meta=meta, path_class=POSTINGS,
                               postings=tuple(postings), cutoff=cutoff)
        return SegmentTask(seg=seg, meta=meta, path_class=BITMAP,
                           cutoff=cutoff)
