"""Query Profiler — workload analysis inside the analytical plane
(paper §3.2 module 4 / §3.4): "detects frequently executed queries,
recurring filter patterns, and high-cost query segments" and proposes
filtering conditions for in-stream compilation.

Heuristic: a predicate is *hot* once its cumulative scan cost and execution
count cross thresholds while it is not yet covered by a registered rule.
``propose_rules`` turns hot predicates into a new RuleSet for the Updater —
closing the paper's feedback loop (profiler -> updater -> stream processor
-> mapper).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from repro.core import telemetry
from repro.core.patterns import Rule, RuleSet, escape

# the profiler's per-path-class accounting, bridged into the registry so a
# snapshot carries "which physical path burned the time" without asking a
# live profiler object
_CLASS_SEGMENTS = {}        # class -> Counter, created lazily per class
_CLASS_SECONDS = {}         # class -> Histogram of attributed latency share
_BRIDGE_LOCK = threading.Lock()


def _class_metrics(cls: str):
    with _BRIDGE_LOCK:
        seg = _CLASS_SEGMENTS.get(cls)
        if seg is None:
            seg = _CLASS_SEGMENTS[cls] = telemetry.counter(
                "fluxsieve_query_segments_total",
                labels={"path_class": cls},
                help="Segments served, by physical path class.")
            _CLASS_SECONDS[cls] = telemetry.histogram(
                "fluxsieve_query_class_seconds",
                labels={"path_class": cls},
                help="Per-query latency share attributed to a path class.")
        return seg, _CLASS_SECONDS[cls]


@dataclass
class PredicateStats:
    count: int = 0
    total_s: float = 0.0
    slow_path_s: float = 0.0    # time spent off the fluxsieve path
    last_path: str = ""

    @property
    def mean_s(self) -> float:
        return self.total_s / max(self.count, 1)


class QueryProfiler:
    def __init__(self, *, hot_count: int = 3, hot_seconds: float = 0.05):
        self.hot_count = hot_count
        self.hot_seconds = hot_seconds
        self._stats: dict = {}      # (field, term) -> PredicateStats
        self._segment_heat: dict = {}   # segment_id -> fallback seconds
        # physical path-class accounting (planner/executor split): how many
        # segments each class served, across how many queries, and the
        # latency share attributed to it — the observability hook for
        # "which physical path is actually burning time"
        self._class_stats: dict = {}    # class -> {queries, segments, seconds}
        self._lock = threading.Lock()

    # -- ingestion (engine calls this per query) --------------------------
    def record(self, query, result) -> None:
        share = result.latency_s / max(len(query.terms), 1)
        with self._lock:
            for key in query.terms:
                st = self._stats.setdefault(key, PredicateStats())
                st.count += 1
                st.total_s += share
                if result.path != "fluxsieve":
                    st.slow_path_s += share
                st.last_path = result.path
            # per-segment heat: how much query time each segment burned on
            # the consistency-fallback scan path — the MaintenanceScheduler
            # backfills the hottest segments first
            ids = getattr(result, "fallback_ids", ())
            if ids:
                share_seg = result.latency_s / len(ids)
                for sid in ids:
                    self._segment_heat[sid] = (
                        self._segment_heat.get(sid, 0.0) + share_seg)
            # per-path-class accounting: latency attributed by segment share
            classes = getattr(result, "path_classes", None) or {}
            total = sum(classes.values()) or 1
            for cls, nseg in classes.items():
                st = self._class_stats.setdefault(
                    cls, {"queries": 0, "segments": 0, "seconds": 0.0})
                st["queries"] += 1
                st["segments"] += nseg
                st["seconds"] += result.latency_s * (nseg / total)
                seg_ctr, sec_hist = _class_metrics(cls)
                seg_ctr.inc(nseg)
                sec_hist.observe(result.latency_s * (nseg / total))

    def path_class_stats(self) -> dict:
        """class -> {queries, segments, seconds}: how often each physical
        path class served segments and the query-latency share attributed
        to it (by segment count)."""
        with self._lock:
            return {cls: dict(st) for cls, st in self._class_stats.items()}

    def segment_heat(self) -> dict:
        """segment_id -> cumulative seconds spent on fallback scans."""
        with self._lock:
            return dict(self._segment_heat)

    def clear_segment_heat(self, segment_ids) -> None:
        """Backfill-aware pruning stats: a freshly re-enriched segment no
        longer serves fallback scans, so its accumulated heat is stale —
        the BackfillWorker clears it after each install so the
        MaintenanceScheduler stops prioritizing already-covered segments
        over genuinely hot ones."""
        with self._lock:
            for sid in segment_ids:
                self._segment_heat.pop(sid, None)

    # -- analysis ----------------------------------------------------------
    def hot_predicates(self) -> list:
        """Predicates worth precomputing: frequent AND expensive AND still
        executing off the fast path."""
        with self._lock:
            out = []
            for (fieldname, term), st in self._stats.items():
                if (st.count >= self.hot_count
                        and st.slow_path_s >= self.hot_seconds):
                    out.append(((fieldname, term), st))
            out.sort(key=lambda kv: kv[1].slow_path_s, reverse=True)
            return out

    def propose_rules(self, current: RuleSet) -> RuleSet:
        """Extend `current` with rules for every hot uncovered predicate."""
        covered = {(f, r.pattern) for r in current.rules for f in r.fields}
        next_id = current.num_rules
        new_rules = []
        for (fieldname, term), _ in self.hot_predicates():
            keys = {(fieldname, term), ("*", term),
                    (fieldname, escape(term)), ("*", escape(term))}
            if keys & covered:
                continue
            new_rules.append(Rule(rule_id=next_id,
                                  name=f"auto_{fieldname}_{term[:24]}",
                                  pattern=escape(term),
                                  fields=(fieldname,)))
            next_id += 1
        return current.with_rules(new_rules) if new_rules else current

    def stats(self) -> dict:
        with self._lock:
            return dict(self._stats)
