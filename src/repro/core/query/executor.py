"""Physical plan executor — shared-arrangement device plane, batched
dispatch, sharded workers.

The executor turns a ``PhysicalPlan`` into per-segment results with the
same single-dispatch discipline PR 2 brought to ingest, now on the read
side — and, since the shared-arrangement refactor, with ONE device copy of
the data across ALL in-flight queries:

  * ALL ``bitmap``-class segments of a query are matched against the
    query's conjunctive mask set in ONE stacked device dispatch through
    the ``bitmap_filter`` kernels; exactly one counted D2H transfer per
    query brings back the match mask (or, on real accelerators, the
    device-reduced per-segment counts — ``device_counts``);
  * the stacked word-column arrays live in a shared, refcounted,
    epoch-versioned ``ArrangementStore`` (``query.arrangement``): every
    query leases its arrangement RAII-style, concurrent queries over the
    same (segment set, word subset) coalesce onto one device copy — each
    word column is uploaded once per maintenance epoch, not once per
    query — and maintenance swaps *publish a new epoch* instead of
    invalidating anything under a reader;
  * ``fallback``/``full_scan`` segments batch through one fused
    throwaway-DFA dispatch per query (``dfa_scan_fused`` via the ingest
    ``FusedMatcher`` stack) when ``scan_backend`` supports fusion, else
    through the vectorized numpy substring scan per segment;
  * enriched-path results are validated against the meta snapshot their
    classification used; segments swapped mid-query by the maintenance
    plane are re-planned individually.  Full-scan results are returned
    directly — they never read enrichment state, so a concurrent swap
    cannot invalidate them.

``ShardedQueryExecutor`` partitions ``plan.tasks`` by segment identity
across a worker pool: each shard runs its own stacked dispatch against the
shared arrangement plane (leases carry the shard's worker identity, the
same scheme the maintenance plane uses to attribute work) and re-plans
swapped segments independently; the merge step reassembles per-segment
results in plan order, so counters and ``path_class_stats`` aggregate
exactly as in the single-worker path.

``backend="numpy"`` preserves the pre-refactor per-segment numpy execution
(bit tests on single bitmap words, no batching, no sharing) behind the
same planner — the equivalence oracle and the honest baseline lane in
benchmarks.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core import faults, telemetry
from repro.core.faults import InjectedCrash
from repro.core.stream_processor import ENRICH_COLUMN
from repro.core.query.arrangement import ArrangementItem, ArrangementStore
from repro.core.query.planner import (BITMAP, FALLBACK, FULL_SCAN,
                                      META_COUNT, POSTINGS, PRUNED,
                                      TEXT_INDEX)

# -- device->host accounting -------------------------------------------------
# The batched bitmap path performs exactly ONE D2H transfer per query; tests
# assert this via ``transfer_count`` — now an alias over the process-wide
# telemetry registry (mirrors core.matcher.transfer_count).
_D2H = telemetry.counter(
    "fluxsieve_query_d2h_total",
    help="Device-to-host transfers on the query plane (one per query).")
_STACKED_DISPATCH = telemetry.counter(
    "fluxsieve_query_stacked_dispatch_total",
    help="Stacked bitmap-class device dispatches.")


def transfer_count() -> int:
    return int(_D2H.value)


def _to_host(x):
    _D2H.inc()
    import jax
    return jax.device_get(x)


def substring_scan(data: np.ndarray, term: str) -> np.ndarray:
    """(N, L) uint8 contains `term` as a byte substring -> (N,) bool."""
    t = term.encode()
    N, L = data.shape
    m = len(t)
    if m == 0 or m > L:
        return np.zeros(N, bool)
    # vectorized first-byte prefilter, then confirm remaining bytes
    acc = data[:, :L - m + 1] == t[0]
    for i in range(1, m):
        acc &= data[:, i:L - m + 1 + i] == t[i]
    return acc.any(axis=1)


# executor-side outcome (not a planner class): the shard serving this
# segment faulted or overran its deadline — the engine reports a partial
# result with per-segment coverage accounting instead of failing the query
FAILED = "failed"

_SHARDS_FAILED = telemetry.counter(
    "fluxsieve_query_shards_failed_total",
    help="Query shards that faulted or overran the per-shard deadline.")


@dataclass
class TaskStats:
    """Per-segment counters, merged into the QueryResult by the engine."""
    scanned: int = 0
    pruned: int = 0
    fallback: int = 0
    bytes_read: int = 0
    fallback_ids: tuple = ()
    path_class: str = ""
    failed: int = 0             # shard faulted/timed out; segment unserved
    failed_ids: tuple = ()


class PlanExecutor:
    """Executes ``PhysicalPlan``s.  ``backend`` selects the bitmap-class
    physical engine: ``numpy`` (pre-refactor per-segment word tests),
    ``ref`` (stacked jnp dispatch), ``pallas`` (stacked Pallas kernel).
    ``scan_backend`` (e.g. ``"dfa_ref"``/``"dfa"``) routes full scans
    through throwaway compiled matchers instead of the numpy substring
    scan (fused-capable backends batch all scan segments into one
    dispatch).  ``device_counts`` selects the device-side per-segment
    count reduction for count-mode queries: ``"auto"`` enables it on real
    accelerators only (on XLA CPU the scatter reduction measurably costs
    more than transferring the mask — PR 3), ``True``/``False`` force it.
    Thread-safe; ``workers > 1`` scans host-path segments concurrently
    (the intra-query parallelism axis of Figs 6-9)."""

    MAX_SNAPSHOT_RETRIES = 3

    def __init__(self, *, backend: str = "ref", scan_backend: str = None,
                 block_n: int = 1024, interpret: bool = True,
                 workers: int = 1, arrangements: ArrangementStore = None,
                 device_counts="auto"):
        if backend not in ("numpy", "ref", "pallas"):
            raise ValueError(f"unknown executor backend {backend!r}")
        self.backend = backend
        self.scan_backend = scan_backend
        self.block_n = block_n
        self.interpret = interpret
        self.workers = workers
        self.arrangements = arrangements or ArrangementStore()
        self.device_counts = device_counts
        self._masks = {}                # rule_ids -> device word-bit vector
        self._mask_lock = threading.Lock()
        self._scan_engines = {}         # (query key, fields) -> matchers
        self._scan_fused = {}           # (query key, backend) -> FusedMatcher
        self._scan_lock = threading.Lock()

    # -- entry ---------------------------------------------------------------
    def execute(self, plan, planner, *, cache: bool = True,
                owner: str = "query") -> list:
        """-> [(ids, TaskStats)] parallel to ``plan.tasks``; ids is None
        (pruned), an int (metadata count), or an int32 id array.
        ``owner`` tags arrangement leases (shard worker identity)."""
        tasks = plan.tasks
        results = [None] * len(tasks)
        if self.backend != "numpy":
            idx = [i for i, t in enumerate(tasks) if t.path_class == BITMAP]
            if idx:
                for i, r in zip(idx, self._run_stacked(
                        plan, [tasks[i] for i in idx], cache, owner)):
                    results[i] = r      # None -> snapshot swapped, re-plan
            idx = [i for i, t in enumerate(tasks)
                   if results[i] is None
                   and t.path_class in (FALLBACK, FULL_SCAN)]
            if len(idx) > 1 and self._fused_scan_capable(plan.query):
                for i, r in zip(idx, self._run_scans_batched(
                        plan, [tasks[i] for i in idx], cache)):
                    results[i] = r

        remaining = [i for i in range(len(tasks)) if results[i] is None]

        def one(i):
            return self._run_task(plan, planner, tasks[i], cache)

        if self.workers > 1 and len(remaining) > 1:
            from concurrent.futures import ThreadPoolExecutor
            with ThreadPoolExecutor(self.workers) as pool:
                for i, r in zip(remaining, pool.map(one, remaining)):
                    results[i] = r
        else:
            for i in remaining:
                results[i] = one(i)
        return results

    # -- stacked bitmap class (single device dispatch, single D2H) -----------
    def _use_device_counts(self) -> bool:
        if self.device_counts == "auto":
            import jax
            self.device_counts = jax.default_backend() not in ("cpu",)
        return bool(self.device_counts)

    def _run_stacked(self, plan, tasks, cache: bool, owner: str) -> list:
        from repro.kernels.bitmap_filter.ops import bitmap_query_words

        # the plan's word-sliced encoding: one (word, bit) pair per
        # single-rule predicate.  Traffic per hot query is N*P words (what
        # the numpy path reads), not N*W.
        words, bits_np = plan.flux.word_slices()
        stats = [TaskStats(path_class=BITMAP) for _ in tasks]
        # tokens are read here, BEFORE any host column load, so a racing
        # maintenance swap can only pool new data under an already-dead
        # token — the snapshot validation below decides result validity
        items = [ArrangementItem(
            token=t.seg.meta_token(), num_records=int(t.seg.num_records),
            load=self._host_loader(t.seg, cache, st))
            for t, st in zip(tasks, stats)]
        if cache:
            lease = self.arrangements.lease(items, words,
                                            block_n=self.block_n,
                                            owner=owner)
        else:       # cold run: private build, pays (and accounts) its I/O
            lease = self.arrangements.build_ephemeral(
                items, words, block_n=self.block_n, owner=owner)
        try:
            arr = lease.arrangement
            bits = self._device_bits(plan.flux.rule_ids, bits_np)
            copy_mode = plan.query.mode == "copy"
            # retention straddlers need row ids (the engine filters them by
            # timestamp), so device-side count reduction is off for them
            any_cutoff = any(t.cutoff is not None for t in tasks)
            with_counts = (not copy_mode and not any_cutoff
                           and self._use_device_counts())
            with telemetry.span("query/stacked_dispatch", cat="query",
                                segments=len(tasks), owner=owner):
                match_dev, counts_dev = bitmap_query_words(
                    arr.stack, bits, arr.row_seg, num_segments=len(tasks),
                    backend="pallas" if self.backend == "pallas" else "ref",
                    block_n=self.block_n, interpret=self.interpret,
                    with_counts=with_counts)
            _STACKED_DISPATCH.inc()
            # the ONE counted D2H per query: on accelerators the
            # device-side segment_sum shrinks it from N bytes to S ints;
            # on XLA CPU the mask transfer is the measured win
            if with_counts:
                counts = np.asarray(_to_host(counts_dev))[:len(tasks)]
                match = None
            else:
                match = _to_host(match_dev)
            lens = arr.lens
        finally:
            lease.release()
        out, off = [], 0
        for slot, (t, st, n) in enumerate(zip(tasks, stats, lens)):
            if t.seg.meta is not t.meta:
                out.append(None)        # swapped mid-query: re-plan this one
            else:
                st.scanned += 1
                if match is None:
                    ids = int(counts[slot])
                elif copy_mode or t.cutoff is not None:
                    ids = np.flatnonzero(match[off:off + n]).astype(np.int32)
                else:
                    ids = int(np.count_nonzero(match[off:off + n]))
                out.append((ids, st))
            off += n
        return out

    def _host_loader(self, seg, cache: bool, stats: TaskStats):
        """Host bitmap read for an arrangement build, accounting disk bytes
        to the query that actually triggered the upload."""
        def load():
            in_mem = ENRICH_COLUMN in seg._columns
            host = seg.column(ENRICH_COLUMN, cache=cache)
            if not in_mem:
                stats.bytes_read += host.nbytes
            return np.asarray(host)
        return load

    def _device_bits(self, rule_ids: tuple, bits_np: np.ndarray):
        """Device-resident per-predicate word masks, cached per rule-id
        tuple (content is a pure function of it)."""
        import jax.numpy as jnp
        with self._mask_lock:
            bits = self._masks.get(rule_ids)
        if bits is None:
            bits = jnp.asarray(bits_np)
            with self._mask_lock:
                if len(self._masks) > 64:       # bound growth
                    self._masks.clear()
                self._masks[rule_ids] = bits
        return bits

    # -- batched fallback / full scans (one fused DFA dispatch per query) ----
    def _fused_scan_capable(self, query) -> bool:
        from repro.core.matcher import FUSED_BACKENDS
        return (self.scan_backend in FUSED_BACKENDS
                and all(t for _, t in query.terms))

    def _run_scans_batched(self, plan, tasks, cache: bool) -> list:
        """ALL fallback/full-scan segments of one query, stacked on N and
        matched in one throwaway-DFA fused dispatch (the scan-path analogue
        of the stacked bitmap class): per-field text columns concatenate
        across segments, ``dfa_scan_fused`` runs once, and per-segment ids
        slice out of the combined bitmap on the host.  Full scans never
        read enrichment state, so results return directly — no snapshot
        re-validation (same contract as the per-segment path)."""
        from repro.core.enrichment import rule_mask
        query = plan.query
        stats = []
        for t in tasks:
            st = TaskStats(path_class=t.path_class, scanned=1)
            if t.path_class == FALLBACK:
                st.fallback = 1
                st.fallback_ids = (t.seg.segment_id,)
            stats.append(st)
        fused = self._scan_fused_matcher(query)
        fields = tuple(sorted({f for f, _ in query.terms}))
        lens = [int(t.seg.num_records) for t in tasks]
        cols = {}
        for f in fields:
            parts = [np.asarray(self._read(t.seg, f, cache, st))
                     for t, st in zip(tasks, stats)]
            L = max(p.shape[1] for p in parts)
            parts = [np.pad(p, ((0, 0), (0, L - p.shape[1])))
                     if p.shape[1] < L else p for p in parts]
            cols[f] = np.concatenate(parts)
        bm, _ = fused.match_batch(cols, fields, sum(lens)).to_host()
        need = rule_mask(range(len(query.terms)), len(query.terms))
        k = min(bm.shape[1], len(need))
        keep = ((bm[:, :k] & need[None, :k]) == need[None, :k]).all(axis=1)
        out, off = [], 0
        for st, n in zip(stats, lens):
            out.append((np.flatnonzero(keep[off:off + n]).astype(np.int32),
                        st))
            off += n
        return out

    def _scan_fused_matcher(self, query):
        from repro.core.matcher import FusedMatcher
        key = (query.key(), self.scan_backend)
        with self._scan_lock:
            fused = self._scan_fused.get(key)
        if fused is None:
            bundle = self._scan_bundle(query)
            fused = FusedMatcher(bundle, backend=self.scan_backend,
                                 block_n=self.block_n,
                                 interpret=self.interpret)
            with self._scan_lock:
                if len(self._scan_fused) > 64:
                    self._scan_fused.clear()
                self._scan_fused[key] = fused
        return fused

    # -- per-segment paths ---------------------------------------------------
    def _run_task(self, plan, planner, task, cache: bool) -> tuple:
        query = plan.query
        if task.path_class in (TEXT_INDEX, FULL_SCAN):
            stats = TaskStats(path_class=task.path_class)
            if task.path_class == TEXT_INDEX:
                return self._text_index(query, task.seg, cache, stats), stats
            return self._full_scan(query, task.seg, cache, stats), stats
        # enriched-path classes: snapshot-validate-retry.  The maintenance
        # plane can swap a sealed segment's enrichment between classification
        # and our read; everything here was evaluated against ONE meta
        # snapshot, so confirm the segment still carries it, re-plan on a
        # swap, and after repeated swaps fall back to the full scan.
        t = task
        for _ in range(self.MAX_SNAPSHOT_RETRIES):
            stats = TaskStats(path_class=t.path_class)
            if t.path_class == FALLBACK:
                # full scans never read enrichment state: return directly,
                # no re-validation — also the terminal state of a re-plan
                stats.fallback += 1
                stats.fallback_ids += (t.seg.segment_id,)
                return self._full_scan(query, t.seg, cache, stats), stats
            ids = self._enriched(plan, t, cache, stats)
            # non-flux plans only reach here via retention-expired PRUNED
            # tasks, which read nothing — no snapshot to invalidate
            if t.seg.meta is t.meta or plan.flux is None:
                return ids, stats
            t = planner.classify(t.seg, query, plan.flux, cache)
        stats = TaskStats(path_class=FALLBACK, fallback=1,
                          fallback_ids=(t.seg.segment_id,))
        return self._full_scan(query, t.seg, cache, stats), stats

    def _enriched(self, plan, task, cache: bool, stats: TaskStats):
        if task.path_class == PRUNED:
            stats.pruned += 1
            return None
        stats.scanned += 1
        if task.path_class == META_COUNT:
            return task.count
        if task.path_class == POSTINGS:
            ids = task.postings[0]
            for p in task.postings[1:]:
                ids = np.intersect1d(ids, p, assume_unique=True)
                if not len(ids):
                    break
            return ids
        # BITMAP, one segment: the pre-refactor numpy word/bit test — also
        # the retry path after a stacked-batch snapshot invalidation
        bm = self._read(task.seg, ENRICH_COLUMN, cache, stats)
        keep = None
        for rid in plan.flux.rule_ids:
            # test ONE word column + bit, not the full (N, W) mask product
            m = (bm[:, rid // 32] >> np.uint32(rid % 32)) & np.uint32(1)
            keep = m.astype(bool) if keep is None else (keep & m.astype(bool))
        return np.flatnonzero(keep)

    def _text_index(self, query, seg, cache: bool, stats: TaskStats):
        stats.scanned += 1
        ids = None
        for fieldname, term in query.terms:
            idx = seg.text_index(fieldname, cache=cache)
            posting = idx.get(term, np.zeros(0, np.int32))
            ids = posting if ids is None else np.intersect1d(
                ids, posting, assume_unique=True)
            if not len(ids):
                break
        return ids

    # -- full scans ----------------------------------------------------------
    def _full_scan(self, query, seg, cache: bool, stats: TaskStats):
        stats.scanned += 1
        if self.scan_backend is not None and all(t for _, t in query.terms):
            return self._full_scan_dfa(query, seg, cache, stats)
        mask = None
        for fieldname, term in query.terms:
            col = self._read(seg, fieldname, cache, stats)
            m = substring_scan(col, term)
            mask = m if mask is None else (mask & m)
        return np.flatnonzero(mask)

    def _full_scan_dfa(self, query, seg, cache: bool, stats: TaskStats):
        """Consistency-fallback scan through the fused matcher stack: query
        terms compile (once, cached per query key) into throwaway literal
        rules — one bit per term — and the raw text columns run through the
        same DFA machinery the ingest plane uses."""
        from repro.core.enrichment import rule_mask
        matchers = self._scan_matchers(query)
        bm = None
        for fieldname, eng in matchers.items():
            col = self._read(seg, fieldname, cache, stats)
            sub = np.asarray(eng.match(col))
            bm = sub if bm is None else (bm | sub)
        need = rule_mask(range(len(query.terms)), len(query.terms))
        keep = ((bm & need[None, :bm.shape[1]])
                == need[None, :bm.shape[1]]).all(axis=1)
        return np.flatnonzero(keep)

    def _scan_bundle(self, query):
        from repro.core.matcher import compile_bundle
        from repro.core.patterns import Rule, RuleSet, escape
        rules = tuple(Rule(i, f"q{i}", escape(term), fields=(f,))
                      for i, (f, term) in enumerate(query.terms))
        fields = tuple(sorted({f for f, _ in query.terms}))
        return compile_bundle(RuleSet(rules), fields)

    def _scan_matchers(self, query) -> dict:
        from repro.core.matcher import build_matchers
        key = (query.key(), self.scan_backend)
        with self._scan_lock:
            matchers = self._scan_engines.get(key)
        if matchers is None:
            matchers = build_matchers(self._scan_bundle(query),
                                      backend=self.scan_backend,
                                      block_n=self.block_n,
                                      interpret=self.interpret)
            with self._scan_lock:
                if len(self._scan_engines) > 64:    # bound growth: ad-hoc
                    self._scan_engines.clear()      # query shapes are open
                self._scan_engines[key] = matchers
        return matchers

    def _read(self, seg, name: str, cache: bool, stats: TaskStats):
        in_mem = name in seg._columns
        col = seg.column(name, cache=cache)
        if not in_mem:
            stats.bytes_read += col.nbytes
        return col


class ShardedQueryExecutor:
    """Sharded query workers over the shared arrangement plane.

    ``plan.tasks`` partition across shards by record-count-weighted
    greedy assignment (``affinity="weighted"``, deterministic so repeated
    queries keep each shard's arrangement hot; ``"modulo"`` selects the
    legacy ``segment_id % shards`` scheme for A/B comparison)
    onto a persistent worker pool; every shard runs its own stacked
    dispatch — leasing from the SAME ``ArrangementStore``, so sharding
    multiplies concurrency, not device copies — and re-plans segments the
    maintenance plane swapped under it independently of its siblings.  The
    merge step reassembles per-segment ``(ids, TaskStats)`` into plan
    order, so counts, counters, and ``path_class_stats`` aggregate exactly
    as in the single-worker executor.

    Worker identity reuses the maintenance plane's scheme
    (``{worker_id}/shard-{i}``): arrangement leases are attributed per
    shard, so a leak or a pinned epoch names the worker that owes it.

    ``deadline_s`` bounds the whole query's shard joins: a shard that
    faults or has not produced its results by the deadline is marked
    FAILED — its segments return ``(None, TaskStats(failed=1, ...))``
    markers and the engine degrades to a *partial* result with coverage
    accounting, instead of one slow or broken shard wedging the query."""

    def __init__(self, executor: PlanExecutor, *, shards: int = 4,
                 worker_id: str = "query-0", deadline_s: float = None,
                 affinity: str = "weighted"):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.executor = executor
        self.shards = shards
        self.worker_id = worker_id
        self.deadline_s = deadline_s
        self.affinity = affinity    # shard_tasks scheme: weighted | modulo
        self.worker_idents = tuple(f"{worker_id}/shard-{i}"
                                   for i in range(shards))
        from concurrent.futures import ThreadPoolExecutor
        self._pool = ThreadPoolExecutor(
            max_workers=shards, thread_name_prefix=f"{worker_id}-shard")

    def close(self) -> None:
        """Shut the shard worker pool down (idle threads exit).  Called on
        finalization too, so churning engines does not accumulate
        process-lifetime threads."""
        self._pool.shutdown(wait=False)

    def __del__(self):
        self.close()

    # mirror the wrapped executor's tuning surface for callers/tests
    @property
    def backend(self) -> str:
        return self.executor.backend

    @property
    def arrangements(self) -> ArrangementStore:
        return self.executor.arrangements

    def execute(self, plan, planner, *, cache: bool = True,
                owner: str = None) -> list:
        tasks = plan.tasks
        shard_idx = plan.shard_tasks(self.shards, affinity=self.affinity)
        if len(shard_idx) <= 1 and self.deadline_s is None:
            return self.executor.execute(plan, planner, cache=cache,
                                         owner=owner or self.worker_idents[0])

        def run_shard(k, idx):
            faults.fire("query.shard", shard=k,
                        worker=self.worker_idents[k % self.shards])
            sub = plan.subplan(idx)
            return self.executor.execute(
                sub, planner, cache=cache,
                owner=self.worker_idents[k % self.shards])

        futures = [self._pool.submit(run_shard, k, idx)
                   for k, idx in enumerate(shard_idx)]
        results = [None] * len(tasks)
        deadline = (time.monotonic() + self.deadline_s
                    if self.deadline_s is not None else None)
        for k, (idx, fut) in enumerate(zip(shard_idx, futures)):
            try:
                if deadline is None:
                    shard_results = fut.result()
                else:
                    shard_results = fut.result(
                        timeout=max(0.0, deadline - time.monotonic()))
            except InjectedCrash:
                raise           # a simulated kill is never a partial result
            except Exception as e:  # noqa: BLE001 — degrade to partial
                # includes futures.TimeoutError (deadline overrun); the
                # overrunning worker thread finishes in the background —
                # only this query stops waiting for it
                _SHARDS_FAILED.inc()
                telemetry.emit("shard_failed", plane="query", shard=k,
                               segments=len(idx),
                               error=f"{type(e).__name__}: {e}")
                for i in idx:
                    results[i] = (None, TaskStats(
                        path_class=FAILED, failed=1,
                        failed_ids=(tasks[i].seg.segment_id,)))
                continue
            for i, r in zip(idx, shard_results):
                results[i] = r
        return results
