"""Physical plan executor — batched, device-resident query execution.

The executor turns a ``PhysicalPlan`` into per-segment results with the
same single-dispatch discipline PR 2 brought to ingest, now on the read
side:

  * ALL ``bitmap``-class segments of a query are concatenated on N (with a
    per-row segment-slot vector) and matched against the query's
    conjunctive mask set in ONE stacked device dispatch through the
    ``bitmap_filter`` kernels; exactly one counted D2H transfer per query
    brings back the match mask, from which per-segment counts (count
    mode) or ids (copy mode) derive on the host — accelerators can flip
    to the device-side count reduction via
    ``bitmap_query_words(with_counts=True)``;
  * uploaded enrichment columns live in a device-resident
    ``DeviceColumnCache`` keyed by ``Segment.meta_token()``, and the fully
    stacked (concatenated + padded) array is LRU-cached per segment-subset
    key, so hot queries skip the H2D re-upload entirely; maintenance-plane
    swaps and cold-run cache drops bump the token and invalidate both;
  * ``fallback``/``full_scan`` segments route through throwaway DFA
    engines (query terms compiled to literal rules, reusing the ingest
    matcher stack) when ``scan_backend`` is set, else through the
    vectorized numpy substring scan;
  * enriched-path results are validated against the meta snapshot their
    classification used; segments swapped mid-query by the maintenance
    plane are re-planned individually.  Full-scan results are returned
    directly — they never read enrichment state, so a concurrent swap
    cannot invalidate them.

``backend="numpy"`` preserves the pre-refactor per-segment numpy execution
(bit tests on single bitmap words) behind the same planner — the
equivalence oracle and the honest baseline lane in benchmarks.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from repro.core.stream_processor import ENRICH_COLUMN
from repro.core.query.planner import (BITMAP, FALLBACK, FULL_SCAN,
                                      META_COUNT, POSTINGS, PRUNED,
                                      TEXT_INDEX)
from repro.core.query.store import DeviceColumnCache

# -- device->host accounting -------------------------------------------------
# The batched bitmap path performs exactly ONE D2H transfer per query; tests
# assert this via the counter below (mirrors core.matcher.transfer_count).
_TRANSFER_COUNT = 0


def transfer_count() -> int:
    return _TRANSFER_COUNT


def _to_host(x):
    global _TRANSFER_COUNT
    _TRANSFER_COUNT += 1
    import jax
    return jax.device_get(x)


def substring_scan(data: np.ndarray, term: str) -> np.ndarray:
    """(N, L) uint8 contains `term` as a byte substring -> (N,) bool."""
    t = term.encode()
    N, L = data.shape
    m = len(t)
    if m == 0 or m > L:
        return np.zeros(N, bool)
    # vectorized first-byte prefilter, then confirm remaining bytes
    acc = data[:, :L - m + 1] == t[0]
    for i in range(1, m):
        acc &= data[:, i:L - m + 1 + i] == t[i]
    return acc.any(axis=1)


@dataclass
class TaskStats:
    """Per-segment counters, merged into the QueryResult by the engine."""
    scanned: int = 0
    pruned: int = 0
    fallback: int = 0
    bytes_read: int = 0
    fallback_ids: tuple = ()
    path_class: str = ""


class PlanExecutor:
    """Executes ``PhysicalPlan``s.  ``backend`` selects the bitmap-class
    physical engine: ``numpy`` (pre-refactor per-segment word tests),
    ``ref`` (stacked jnp dispatch), ``pallas`` (stacked Pallas kernel).
    ``scan_backend`` (e.g. ``"dfa_ref"``/``"dfa"``) routes full scans
    through throwaway compiled matchers instead of the numpy substring
    scan.  Thread-safe; ``workers > 1`` scans host-path segments
    concurrently (the intra-query parallelism axis of Figs 6-9)."""

    MAX_SNAPSHOT_RETRIES = 3

    def __init__(self, *, backend: str = "ref", scan_backend: str = None,
                 block_n: int = 1024, interpret: bool = True,
                 workers: int = 1, device_cache: DeviceColumnCache = None,
                 stack_cache_size: int = 8):
        if backend not in ("numpy", "ref", "pallas"):
            raise ValueError(f"unknown executor backend {backend!r}")
        self.backend = backend
        self.scan_backend = scan_backend
        self.block_n = block_n
        self.interpret = interpret
        self.workers = workers
        self.device_cache = device_cache or DeviceColumnCache()
        self.stack_cache_size = stack_cache_size
        self._stacks = {}               # (tokens, words) -> (stack, row_seg,
        self._stack_order = []          #                      lens)
        self._stack_lock = threading.Lock()
        self._masks = {}                # rule_ids -> device word-bit vector
        self._scan_engines = {}         # (query key, fields) -> matchers
        self._scan_lock = threading.Lock()

    # -- entry ---------------------------------------------------------------
    def execute(self, plan, planner, *, cache: bool = True) -> list:
        """-> [(ids, TaskStats)] parallel to ``plan.tasks``; ids is None
        (pruned), an int (metadata count), or an int32 id array."""
        tasks = plan.tasks
        results = [None] * len(tasks)
        if self.backend != "numpy":
            idx = [i for i, t in enumerate(tasks) if t.path_class == BITMAP]
            if idx:
                for i, r in zip(idx, self._run_stacked(
                        plan, [tasks[i] for i in idx], cache)):
                    results[i] = r      # None -> snapshot swapped, re-plan

        remaining = [i for i in range(len(tasks)) if results[i] is None]

        def one(i):
            return self._run_task(plan, planner, tasks[i], cache)

        if self.workers > 1 and len(remaining) > 1:
            from concurrent.futures import ThreadPoolExecutor
            with ThreadPoolExecutor(self.workers) as pool:
                for i, r in zip(remaining, pool.map(one, remaining)):
                    results[i] = r
        else:
            for i in remaining:
                results[i] = one(i)
        return results

    # -- stacked bitmap class (single device dispatch, single D2H) -----------
    def _run_stacked(self, plan, tasks, cache: bool) -> list:
        from repro.kernels.bitmap_filter.ops import bitmap_query_words
        import jax.numpy as jnp

        # the plan's word-sliced encoding: one (word, bit) pair per
        # single-rule predicate.  The gather happens once at stack build;
        # traffic per hot query is N*P words (what the numpy path reads),
        # not N*W.
        words, bits_np = plan.flux.word_slices()
        stats = [TaskStats(path_class=BITMAP) for _ in tasks]
        key = (tuple(t.seg.meta_token() for t in tasks), words)
        entry = self._stack_get(key) if cache else None
        if entry is None:
            # stack build (once per segment subset + word set, then
            # device-resident): gather the word columns host-side, upload,
            # concatenate on N, pre-bucket.  All eager device ops live
            # HERE, off the hot path — a hot query is one jitted dispatch
            # plus one D2H.
            parts, lens = [], []
            for t, st in zip(tasks, stats):
                parts.append(self._device_words(t.seg, words, cache, st))
                lens.append(int(t.seg.num_records))
            stack = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
            row_seg = np.repeat(np.arange(len(tasks), dtype=np.int32), lens)
            from repro.kernels.dfa_scan.ops import bucket_n
            n_pad = bucket_n(stack.shape[0], self.block_n)
            if n_pad != stack.shape[0]:
                stack = jnp.pad(stack, ((0, n_pad - stack.shape[0]), (0, 0)))
                row_seg = np.pad(row_seg, (0, n_pad - len(row_seg)))
            entry = (stack, jnp.asarray(row_seg), tuple(lens))
            if cache:
                self._stack_put(key, entry)
        stack, row_seg, lens = entry
        bits = self._device_bits(plan.flux.rule_ids, bits_np)
        copy_mode = plan.query.mode == "copy"
        match_dev, _ = bitmap_query_words(
            stack, bits, row_seg, num_segments=len(tasks),
            backend="pallas" if self.backend == "pallas" else "ref",
            block_n=self.block_n, interpret=self.interpret,
            with_counts=False)
        # the ONE counted D2H per query: the padded match mask; per-segment
        # counts/ids derive from host slices (on XLA CPU a device-side
        # scatter reduction costs more than transferring the mask — see
        # bitmap_query_words(with_counts=...) for the accelerator trade)
        match = _to_host(match_dev)
        out, off = [], 0
        for t, st, n in zip(tasks, stats, lens):
            if t.seg.meta is not t.meta:
                out.append(None)        # swapped mid-query: re-plan this one
            else:
                st.scanned += 1
                if copy_mode:
                    ids = np.flatnonzero(match[off:off + n]).astype(np.int32)
                else:
                    ids = int(np.count_nonzero(match[off:off + n]))
                out.append((ids, st))
            off += n
        return out

    def _device_bits(self, rule_ids: tuple, bits_np: np.ndarray):
        """Device-resident per-predicate word masks, cached per rule-id
        tuple (content is a pure function of it)."""
        import jax.numpy as jnp
        with self._stack_lock:
            bits = self._masks.get(rule_ids)
        if bits is None:
            bits = jnp.asarray(bits_np)
            with self._stack_lock:
                if len(self._masks) > 64:       # bound growth
                    self._masks.clear()
                self._masks[rule_ids] = bits
        return bits

    def _device_words(self, seg, words: tuple, cache: bool,
                      stats: TaskStats):
        """Device-resident gathered word columns of the enrichment bitmap.
        The token is read BEFORE the host column so a racing maintenance
        swap can only file new data under an already-dead token, never
        stale data under a live one."""
        import jax.numpy as jnp
        token = seg.meta_token()
        name = f"{ENRICH_COLUMN}@{','.join(map(str, words))}"
        dev = self.device_cache.get(token, name) if cache else None
        if dev is None:
            in_mem = ENRICH_COLUMN in seg._columns
            host = seg.column(ENRICH_COLUMN, cache=cache)
            if not in_mem:
                stats.bytes_read += host.nbytes
            sub = np.ascontiguousarray(np.asarray(host)[:, list(words)])
            dev = jnp.asarray(sub)                       # the only H2D
            if cache:
                self.device_cache.put(token, name, dev)
        return dev

    def _stack_get(self, key):
        with self._stack_lock:
            entry = self._stacks.get(key)
            if entry is not None:
                self._stack_order.remove(key)
                self._stack_order.append(key)
            return entry

    def _stack_put(self, key, entry) -> None:
        with self._stack_lock:
            if key not in self._stacks:
                self._stack_order.append(key)
            self._stacks[key] = entry
            while len(self._stack_order) > self.stack_cache_size:
                old = self._stack_order.pop(0)
                del self._stacks[old]

    # -- per-segment paths ---------------------------------------------------
    def _run_task(self, plan, planner, task, cache: bool) -> tuple:
        query = plan.query
        if task.path_class in (TEXT_INDEX, FULL_SCAN):
            stats = TaskStats(path_class=task.path_class)
            if task.path_class == TEXT_INDEX:
                return self._text_index(query, task.seg, cache, stats), stats
            return self._full_scan(query, task.seg, cache, stats), stats
        # enriched-path classes: snapshot-validate-retry.  The maintenance
        # plane can swap a sealed segment's enrichment between classification
        # and our read; everything here was evaluated against ONE meta
        # snapshot, so confirm the segment still carries it, re-plan on a
        # swap, and after repeated swaps fall back to the full scan.
        t = task
        for _ in range(self.MAX_SNAPSHOT_RETRIES):
            stats = TaskStats(path_class=t.path_class)
            if t.path_class == FALLBACK:
                # full scans never read enrichment state: return directly,
                # no re-validation — also the terminal state of a re-plan
                stats.fallback += 1
                stats.fallback_ids += (t.seg.segment_id,)
                return self._full_scan(query, t.seg, cache, stats), stats
            ids = self._enriched(plan, t, cache, stats)
            if t.seg.meta is t.meta:
                return ids, stats
            t = planner.classify(t.seg, query, plan.flux, cache)
        stats = TaskStats(path_class=FALLBACK, fallback=1,
                          fallback_ids=(t.seg.segment_id,))
        return self._full_scan(query, t.seg, cache, stats), stats

    def _enriched(self, plan, task, cache: bool, stats: TaskStats):
        if task.path_class == PRUNED:
            stats.pruned += 1
            return None
        stats.scanned += 1
        if task.path_class == META_COUNT:
            return task.count
        if task.path_class == POSTINGS:
            ids = task.postings[0]
            for p in task.postings[1:]:
                ids = np.intersect1d(ids, p, assume_unique=True)
                if not len(ids):
                    break
            return ids
        # BITMAP, one segment: the pre-refactor numpy word/bit test — also
        # the retry path after a stacked-batch snapshot invalidation
        bm = self._read(task.seg, ENRICH_COLUMN, cache, stats)
        keep = None
        for rid in plan.flux.rule_ids:
            # test ONE word column + bit, not the full (N, W) mask product
            m = (bm[:, rid // 32] >> np.uint32(rid % 32)) & np.uint32(1)
            keep = m.astype(bool) if keep is None else (keep & m.astype(bool))
        return np.flatnonzero(keep)

    def _text_index(self, query, seg, cache: bool, stats: TaskStats):
        stats.scanned += 1
        ids = None
        for fieldname, term in query.terms:
            idx = seg.text_index(fieldname, cache=cache)
            posting = idx.get(term, np.zeros(0, np.int32))
            ids = posting if ids is None else np.intersect1d(
                ids, posting, assume_unique=True)
            if not len(ids):
                break
        return ids

    # -- full scans ----------------------------------------------------------
    def _full_scan(self, query, seg, cache: bool, stats: TaskStats):
        stats.scanned += 1
        if self.scan_backend is not None and all(t for _, t in query.terms):
            return self._full_scan_dfa(query, seg, cache, stats)
        mask = None
        for fieldname, term in query.terms:
            col = self._read(seg, fieldname, cache, stats)
            m = substring_scan(col, term)
            mask = m if mask is None else (mask & m)
        return np.flatnonzero(mask)

    def _full_scan_dfa(self, query, seg, cache: bool, stats: TaskStats):
        """Consistency-fallback scan through the fused matcher stack: query
        terms compile (once, cached per query key) into throwaway literal
        rules — one bit per term — and the raw text columns run through the
        same DFA machinery the ingest plane uses."""
        from repro.core.enrichment import rule_mask
        matchers = self._scan_matchers(query)
        bm = None
        for fieldname, eng in matchers.items():
            col = self._read(seg, fieldname, cache, stats)
            sub = np.asarray(eng.match(col))
            bm = sub if bm is None else (bm | sub)
        need = rule_mask(range(len(query.terms)), len(query.terms))
        keep = ((bm & need[None, :bm.shape[1]])
                == need[None, :bm.shape[1]]).all(axis=1)
        return np.flatnonzero(keep)

    def _scan_matchers(self, query) -> dict:
        from repro.core.matcher import build_matchers, compile_bundle
        from repro.core.patterns import Rule, RuleSet, escape
        key = (query.key(), self.scan_backend)
        with self._scan_lock:
            matchers = self._scan_engines.get(key)
        if matchers is None:
            rules = tuple(Rule(i, f"q{i}", escape(term), fields=(f,))
                          for i, (f, term) in enumerate(query.terms))
            fields = tuple(sorted({f for f, _ in query.terms}))
            bundle = compile_bundle(RuleSet(rules), fields)
            matchers = build_matchers(bundle, backend=self.scan_backend,
                                      block_n=self.block_n,
                                      interpret=self.interpret)
            with self._scan_lock:
                if len(self._scan_engines) > 64:    # bound growth: ad-hoc
                    self._scan_engines.clear()      # query shapes are open
                self._scan_engines[key] = matchers
        return matchers

    def _read(self, seg, name: str, cache: bool, stats: TaskStats):
        in_mem = name in seg._columns
        col = seg.column(name, cache=cache)
        if not in_mem:
            stats.bytes_read += col.nbytes
        return col
