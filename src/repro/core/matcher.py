"""MatchEngine — the executable multi-pattern matcher (paper §3.3).

Wraps a compiled automaton (``core.automaton.CompiledEngine``) with device
arrays and a jitted single-pass dispatch.  Engine *backends* select the
TPU-native algorithm (DESIGN.md §2):

    dfa        AC-DFA batch scan — paper-faithful default (Pallas kernel)
    dfa_ref    pure-jnp oracle of the same
    shift_or   bit-parallel shift-AND (literals <= 32 B) — beyond-paper
    parallel   associative-scan DFA (small automata) — beyond-paper

An ``EngineBundle`` groups one engine per record text field (paper §6.1 runs
"one Pattern Matching Engine instance per text field") plus version metadata;
it is the serializable artifact the Updater ships through the object store.
Because table shapes are bucketed (automaton.py), swapping a new bundle into
a running matcher re-uses every jit cache entry — the hot swap is O(bytes).
"""
from __future__ import annotations

import hashlib
import io
import json
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.automaton import CompiledEngine, compile_rules, words_for_rules
from repro.core.patterns import RuleSet
from repro.kernels.dfa_scan.ops import (dfa_scan, dfa_scan_selective,
                                        pack_delta_any)
from repro.kernels.shift_or import ops as shift_or_ops

BACKENDS = ("dfa", "dfa_ref", "dfa_selective", "shift_or", "parallel")


class MatchEngine:
    """One compiled automaton, resident on device, with stable jit shapes."""

    def __init__(self, engine: CompiledEngine, *, backend: str = "dfa_ref",
                 ruleset: RuleSet = None, block_n: int = 256,
                 interpret: bool = True):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret
        self.engine = engine
        self.version = engine.version
        self.num_rules = engine.num_rules
        self.field = engine.field
        self._delta = jnp.asarray(engine.delta)
        self._emit = jnp.asarray(engine.emit)
        self._classes = jnp.asarray(engine.byte_classes)
        self._delta2 = None
        if backend == "dfa_selective":
            # Hyperscan-style confirm path (§Perf hillclimb D): packed
            # any-accept transition table for the prefilter pass
            self._delta2 = pack_delta_any(engine.delta, engine.emit)
        self._shift_or = None
        if backend == "shift_or":
            if ruleset is None:
                raise ValueError("shift_or backend needs the RuleSet to pack literals")
            self._shift_or = shift_or_ops.compile_shift_or(ruleset, engine.field)

    @property
    def words(self) -> int:
        return self.engine.words

    def match(self, data) -> jnp.ndarray:
        """data: (N, L) uint8 -> (N, W) uint32 packed rule bitmaps."""
        if self.backend == "dfa_selective":
            return dfa_scan_selective(np.asarray(data), self.engine.delta,
                                      self.engine.emit,
                                      self.engine.byte_classes,
                                      delta2=self._delta2)
        data = jnp.asarray(data)
        if self.backend == "shift_or":
            bm = shift_or_ops.shift_or_match(data, self._shift_or,
                                             backend="pallas",
                                             block_n=self.block_n,
                                             interpret=self.interpret)
            # shift_or packs exactly ceil(rules/32) words; widen to the bucket
            W = self.words
            if bm.shape[1] < W:
                bm = jnp.pad(bm, ((0, 0), (0, W - bm.shape[1])))
            return bm
        backend = {"dfa": "pallas", "dfa_ref": "ref", "parallel": "parallel"}[self.backend]
        return dfa_scan(data, self._delta, self._emit, self._classes,
                        backend=backend, block_n=self.block_n,
                        interpret=self.interpret)


@dataclass(frozen=True)
class EngineBundle:
    """Versioned set of per-field compiled engines (the deployable artifact)."""
    version: str
    num_rules: int
    engines: dict            # field -> CompiledEngine
    ruleset_json: str = ""   # carried so shift_or backends can re-pack literals

    @property
    def fields(self) -> tuple:
        return tuple(sorted(self.engines))

    @property
    def words(self) -> int:
        return words_for_rules(self.num_rules)

    def checksum(self) -> str:
        h = hashlib.sha256()
        h.update(self.version.encode())
        h.update(str(self.num_rules).encode())
        for f in self.fields:
            h.update(f.encode())
            h.update(self.engines[f].checksum().encode())
        h.update(self.ruleset_json.encode())
        return h.hexdigest()

    def serialize(self) -> bytes:
        arrays = {}
        for f, eng in self.engines.items():
            arrays[f"eng_{f}"] = np.frombuffer(eng.serialize(), np.uint8)
        manifest = json.dumps({
            "version": self.version, "num_rules": self.num_rules,
            "fields": list(self.fields), "checksum": self.checksum(),
            "ruleset_json": self.ruleset_json,
        })
        buf = io.BytesIO()
        np.savez_compressed(buf, manifest=np.array(manifest), **arrays)
        return buf.getvalue()

    @staticmethod
    def deserialize(data: bytes, verify: bool = True) -> "EngineBundle":
        try:
            z = np.load(io.BytesIO(data), allow_pickle=False)
            manifest = json.loads(str(z["manifest"]))
            engines = {f: CompiledEngine.deserialize(z[f"eng_{f}"].tobytes(),
                                                     verify=verify)
                       for f in manifest["fields"]}
        except ValueError:
            raise
        except Exception as e:  # container damage (zlib/zip/json errors)
            raise ValueError(f"corrupt bundle artifact: {e}") from e
        bundle = EngineBundle(version=manifest["version"],
                              num_rules=manifest["num_rules"], engines=engines,
                              ruleset_json=manifest.get("ruleset_json", ""))
        if verify and manifest["checksum"] != bundle.checksum():
            raise ValueError("bundle checksum mismatch — corrupt artifact")
        return bundle

    def ruleset(self) -> RuleSet:
        return RuleSet.from_json(self.ruleset_json)


def compile_bundle(ruleset: RuleSet, fields) -> EngineBundle:
    """Compile one engine per text field (rules select their fields)."""
    engines = {f: compile_rules(ruleset, f) for f in fields}
    return EngineBundle(version=ruleset.version_hash(),
                        num_rules=ruleset.num_rules, engines=engines,
                        ruleset_json=ruleset.to_json())


def build_matchers(bundle: EngineBundle, *, backend: str = "dfa_ref",
                   block_n: int = 256, interpret: bool = True) -> dict:
    """field -> MatchEngine, ready for StreamProcessor hot-swap."""
    rs = bundle.ruleset() if bundle.ruleset_json else None
    return {f: MatchEngine(bundle.engines[f], backend=backend, ruleset=rs,
                           block_n=block_n, interpret=interpret)
            for f in bundle.fields}
