"""MatchEngine — the executable multi-pattern matcher (paper §3.3).

Wraps a compiled automaton (``core.automaton.CompiledEngine``) with device
arrays and a jitted single-pass dispatch.  Engine *backends* select the
TPU-native algorithm (DESIGN.md §2):

    dfa        AC-DFA batch scan — paper-faithful default (Pallas kernel)
    dfa_ref    pure-jnp oracle of the same
    shift_or   bit-parallel shift-AND (literals <= 32 B) — beyond-paper
    parallel   associative-scan DFA (small automata) — beyond-paper

An ``EngineBundle`` groups one engine per record text field (paper §6.1 runs
"one Pattern Matching Engine instance per text field") plus version metadata;
it is the serializable artifact the Updater ships through the object store.
Because table shapes are bucketed (automaton.py), swapping a new bundle into
a running matcher re-uses every jit cache entry — the hot swap is O(bytes).

``FusedMatcher`` is the bundle-level fused dispatcher the enrich hot path
uses: all matched text columns of a batch go to the device in ONE dispatch,
the per-field bitmaps are OR-reduced and the any-match mask computed on
device, and the pair comes back in a single D2H transfer
(``MatchResult.to_host``).  Per-field ``MatchEngine.match`` remains for
tests, the selective/shift_or fallbacks, and the backfill plane.
"""
from __future__ import annotations

import hashlib
import io
import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.automaton import CompiledEngine, compile_rules, words_for_rules
from repro.core.patterns import RuleSet
from repro.kernels.dfa_scan.ops import (dfa_scan, dfa_scan_fused,
                                        dfa_scan_selective, pack_delta_any)
from repro.kernels.shift_or import ops as shift_or_ops

BACKENDS = ("dfa", "dfa_ref", "dfa_selective", "shift_or", "parallel")
# backends whose whole multi-field match can run as one fused device dispatch
FUSED_BACKENDS = ("dfa", "dfa_ref", "parallel")

# -- device->host accounting -------------------------------------------------
# The enrich path must perform exactly ONE D2H transfer per batch; tests
# assert this via ``transfer_count`` (now an alias over the process-wide
# telemetry registry — deltas, which is what the tests take, are unchanged).
_D2H = telemetry.counter(
    "fluxsieve_match_d2h_total",
    help="Device-to-host transfers on the match plane (one per batch).")
_DISPATCH = telemetry.counter(
    "fluxsieve_match_dispatch_total",
    help="Fused device dispatches on the match plane.")
_MATCH_RECORDS = telemetry.counter(
    "fluxsieve_match_records_total",
    help="Records pushed through the fused match path.")


def transfer_count() -> int:
    return int(_D2H.value)


def _to_host(x):
    _D2H.inc()
    return jax.device_get(x)


class MatchResult:
    """Deferred match result: packed bitmap + any-match mask.

    Both stay on device (JAX async dispatch keeps computing behind it) until
    ``to_host`` materializes them in a single counted D2H transfer.  Results
    produced by host-side backends (dfa_selective) carry numpy arrays and
    transfer nothing."""

    __slots__ = ("_bm", "_mask", "_host")

    def __init__(self, bm, mask):
        self._bm = bm
        self._mask = mask
        self._host = isinstance(bm, np.ndarray)

    @property
    def on_device(self) -> bool:
        """True while the result still lives on device (work may be in
        flight); host-backend results were never dispatched."""
        return not self._host

    def to_host(self):
        """-> (bitmap (N, W) uint32, any_match (N,) bool), numpy."""
        if not self._host:
            self._bm, self._mask = _to_host((self._bm, self._mask))
            self._host = True
        return self._bm, self._mask


class MatchEngine:
    """One compiled automaton, resident on device, with stable jit shapes."""

    def __init__(self, engine: CompiledEngine, *, backend: str = "dfa_ref",
                 ruleset: RuleSet = None, block_n: int = 256,
                 interpret: bool = True, confirm_backend: str = "ref"):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret
        self.confirm_backend = confirm_backend   # dfa_selective pass-2 engine
        self.engine = engine
        self.version = engine.version
        self.num_rules = engine.num_rules
        self.field = engine.field
        self._delta = jnp.asarray(engine.delta)
        self._emit = jnp.asarray(engine.emit)
        self._classes = jnp.asarray(engine.byte_classes)
        self._delta2 = None
        if backend == "dfa_selective":
            # Hyperscan-style confirm path (§Perf hillclimb D): packed
            # any-accept transition table for the prefilter pass
            self._delta2 = pack_delta_any(engine.delta, engine.emit)
        self._shift_or = None
        if backend == "shift_or":
            if ruleset is None:
                raise ValueError("shift_or backend needs the RuleSet to pack literals")
            self._shift_or = shift_or_ops.compile_shift_or(ruleset, engine.field)

    @property
    def words(self) -> int:
        return self.engine.words

    def match(self, data) -> jnp.ndarray:
        """data: (N, L) uint8 -> (N, W) uint32 packed rule bitmaps."""
        if self.backend == "dfa_selective":
            return dfa_scan_selective(data, self.engine.delta,
                                      self.engine.emit,
                                      self.engine.byte_classes,
                                      delta2=self._delta2,
                                      backend=self.confirm_backend,
                                      block_n=self.block_n,
                                      interpret=self.interpret)
        data = jnp.asarray(data)
        if self.backend == "shift_or":
            bm = shift_or_ops.shift_or_match(data, self._shift_or,
                                             backend="pallas",
                                             block_n=self.block_n,
                                             interpret=self.interpret)
            # shift_or packs exactly ceil(rules/32) words; widen to the bucket
            W = self.words
            if bm.shape[1] < W:
                bm = jnp.pad(bm, ((0, 0), (0, W - bm.shape[1])))
            return bm
        backend = {"dfa": "pallas", "dfa_ref": "ref", "parallel": "parallel"}[self.backend]
        return dfa_scan(data, self._delta, self._emit, self._classes,
                        backend=backend, block_n=self.block_n,
                        interpret=self.interpret)


@dataclass(frozen=True)
class EngineBundle:
    """Versioned set of per-field compiled engines (the deployable artifact)."""
    version: str
    num_rules: int
    engines: dict            # field -> CompiledEngine
    ruleset_json: str = ""   # carried so shift_or backends can re-pack literals

    @property
    def fields(self) -> tuple:
        return tuple(sorted(self.engines))

    @property
    def words(self) -> int:
        return words_for_rules(self.num_rules)

    def checksum(self) -> str:
        h = hashlib.sha256()
        h.update(self.version.encode())
        h.update(str(self.num_rules).encode())
        for f in self.fields:
            h.update(f.encode())
            h.update(self.engines[f].checksum().encode())
        h.update(self.ruleset_json.encode())
        return h.hexdigest()

    def serialize(self) -> bytes:
        arrays = {}
        for f, eng in self.engines.items():
            arrays[f"eng_{f}"] = np.frombuffer(eng.serialize(), np.uint8)
        manifest = json.dumps({
            "version": self.version, "num_rules": self.num_rules,
            "fields": list(self.fields), "checksum": self.checksum(),
            "ruleset_json": self.ruleset_json,
        })
        buf = io.BytesIO()
        np.savez_compressed(buf, manifest=np.array(manifest), **arrays)
        return buf.getvalue()

    @staticmethod
    def deserialize(data: bytes, verify: bool = True) -> "EngineBundle":
        try:
            z = np.load(io.BytesIO(data), allow_pickle=False)
            manifest = json.loads(str(z["manifest"]))
            engines = {f: CompiledEngine.deserialize(z[f"eng_{f}"].tobytes(),
                                                     verify=verify)
                       for f in manifest["fields"]}
        except ValueError:
            raise
        except Exception as e:  # container damage (zlib/zip/json errors)
            raise ValueError(f"corrupt bundle artifact: {e}") from e
        bundle = EngineBundle(version=manifest["version"],
                              num_rules=manifest["num_rules"], engines=engines,
                              ruleset_json=manifest.get("ruleset_json", ""))
        if verify and manifest["checksum"] != bundle.checksum():
            raise ValueError("bundle checksum mismatch — corrupt artifact")
        return bundle

    def ruleset(self) -> RuleSet:
        return RuleSet.from_json(self.ruleset_json)


def compile_bundle(ruleset: RuleSet, fields) -> EngineBundle:
    """Compile one engine per text field (rules select their fields)."""
    engines = {f: compile_rules(ruleset, f) for f in fields}
    return EngineBundle(version=ruleset.version_hash(),
                        num_rules=ruleset.num_rules, engines=engines,
                        ruleset_json=ruleset.to_json())


def build_matchers(bundle: EngineBundle, *, backend: str = "dfa_ref",
                   block_n: int = 256, interpret: bool = True,
                   confirm_backend: str = "ref") -> dict:
    """field -> MatchEngine, ready for StreamProcessor hot-swap."""
    rs = bundle.ruleset() if bundle.ruleset_json else None
    return {f: MatchEngine(bundle.engines[f], backend=backend, ruleset=rs,
                           block_n=block_n, interpret=interpret,
                           confirm_backend=confirm_backend)
            for f in bundle.fields}


def match_pairs(engine_fields, text_fields):
    """(engine_field, column) routing shared by the fused plan and the
    per-field fallback: a '*' engine applies to every text column, a named
    engine only to its own column (and only when the batch carries it)."""
    for fieldname in engine_fields:
        if fieldname == "*":
            for c in text_fields:
                yield fieldname, c
        elif fieldname in text_fields:
            yield fieldname, fieldname


@dataclass(frozen=True)
class _FusedPlan:
    """Stacked device tables for one batch schema.  Engines shared across
    columns (a '*' engine) are stored once; ``eng_idx`` maps each stacked
    field slot to its table row."""
    cols: tuple              # column names, one per stacked field slot
    eng_idx: tuple           # per-slot row into the unique-engine tables
    luts: object             # (E, 256) int32
    deltas: object           # (E, S, C) int32
    emits: object            # (E, S, W) uint32


class FusedMatcher:
    """EngineBundle-level fused dispatcher: one device dispatch per batch.

    All matched text columns are stacked into one ``(F, N, L)`` input; the
    per-field tables are padded to a common shape bucket and stacked once
    per batch schema (cached per text-field tuple, so hot-swapping a new
    bundle re-uses every jit cache entry exactly like the per-field path).
    The scan, the OR across fields, and the any-match mask all run on
    device; ``MatchResult.to_host`` is the single D2H.
    """

    def __init__(self, bundle: EngineBundle, *, backend: str = "dfa_ref",
                 block_n: int = 256, interpret: bool = True):
        if backend not in FUSED_BACKENDS:
            raise ValueError(f"backend {backend!r} has no fused dispatch "
                             f"(supported: {FUSED_BACKENDS})")
        self.bundle = bundle
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret
        self.words = bundle.words
        self._kernel = {"dfa": "pallas", "dfa_ref": "ref",
                        "parallel": "parallel"}[backend]
        self._plans: dict = {}

    def _plan(self, text_fields: tuple) -> _FusedPlan:
        plan = self._plans.get(text_fields)
        if plan is None:
            plan = self._build_plan(text_fields)
            self._plans[text_fields] = plan
        return plan

    def _build_plan(self, text_fields: tuple) -> _FusedPlan:
        pairs = [(c, self.bundle.engines[f])         # (column, CompiledEngine)
                 for f, c in match_pairs(self.bundle.fields, text_fields)]
        if not pairs:
            return _FusedPlan(cols=(), eng_idx=(), luts=None, deltas=None,
                              emits=None)
        uniq, eng_idx, slot = [], [], {}
        for _, e in pairs:
            if id(e) not in slot:
                slot[id(e)] = len(uniq)
                uniq.append(e)
            eng_idx.append(slot[id(e)])
        E = len(uniq)
        S = max(e.bucket for e in uniq)
        C = max(e.n_classes for e in uniq)
        W = self.words
        luts = np.zeros((E, 256), np.int32)
        deltas = np.zeros((E, S, C), np.int32)      # padded rows unreachable
        emits = np.zeros((E, S, W), np.uint32)
        for i, e in enumerate(uniq):
            luts[i] = e.byte_classes
            deltas[i, :e.bucket, :e.n_classes] = e.delta
            emits[i, :e.bucket] = e.emit
        eng_idx = tuple(eng_idx)
        if self._kernel == "pallas" and eng_idx != tuple(range(E)):
            # pallas on jax 0.4.x can't route the slot->row indirection
            # through BlockSpec index maps; expand shared tables ONCE here
            # (host-side, per plan) rather than per dispatch on device
            idx = list(eng_idx)
            luts, deltas, emits = luts[idx], deltas[idx], emits[idx]
            eng_idx = tuple(range(len(idx)))
        return _FusedPlan(cols=tuple(c for c, _ in pairs),
                          eng_idx=eng_idx,
                          luts=jnp.asarray(luts), deltas=jnp.asarray(deltas),
                          emits=jnp.asarray(emits))

    def match_batch(self, columns: dict, text_fields, n: int) -> MatchResult:
        """columns: name -> (N, L) uint8; -> deferred (bitmap, mask)."""
        plan = self._plan(tuple(text_fields))
        if not plan.cols:
            return MatchResult(np.zeros((n, self.words), np.uint32),
                               np.zeros(n, bool))
        L = max(columns[c].shape[1] for c in plan.cols)
        mats = []
        for c in plan.cols:
            m = columns[c]
            if m.shape[1] < L:
                m = np.pad(np.asarray(m), ((0, 0), (0, L - m.shape[1])))
            mats.append(np.asarray(m))
        data = np.stack(mats)                       # (F, N, L): one H2D
        with telemetry.span("match/dispatch", cat="match", n=int(n),
                            fields=len(plan.cols)):
            bm, mask = dfa_scan_fused(data, plan.luts, plan.deltas,
                                      plan.emits, eng_idx=plan.eng_idx,
                                      backend=self._kernel,
                                      block_n=self.block_n,
                                      interpret=self.interpret)
        _DISPATCH.inc()
        _MATCH_RECORDS.inc(int(n))
        return MatchResult(bm, mask)
