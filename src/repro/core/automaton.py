"""Aho-Corasick multi-pattern automaton: host-side (numpy) construction,
alphabet-class compression, shape-bucket padding, and checksummed
serialization (the artifact the Updater ships through the object store —
the TPU-side analogue of a compiled Hyperscan database, paper §3.3/3.4).

The compiled artifact is pure data (int32/uint32 tables), so a "hot swap"
on the stream processor is just replacing device arrays — the jitted
matcher never recompiles as long as the shape bucket is unchanged.
"""
from __future__ import annotations

import hashlib
import io
from dataclasses import dataclass, replace

import numpy as np

from repro.core.patterns import RuleSet

STATE_BUCKETS = (512, 1024, 2048, 4096, 8192, 16384, 32768, 131072)
CLASS_BUCKETS = (16, 32, 64, 128, 256)
WORD_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)
WORD_BITS = 32


def words_for_rules(num_rules: int) -> int:
    """Bitmap words for `num_rules`, bucketed so the jitted matcher keeps a
    stable shape while the rule set grows (hot swap without retrace)."""
    need = max(1, (num_rules + WORD_BITS - 1) // WORD_BITS)
    for b in WORD_BUCKETS:
        if need <= b:
            return b
    raise ValueError(f"too many rules: {num_rules}")


@dataclass(frozen=True)
class CompiledEngine:
    """Padded DFA tables.

    delta:        (S_pad, n_classes) int32   next-state table
    emit:         (S_pad, W) uint32          rule bitmap emitted when entering a state
    byte_classes: (256,) int32               byte -> alphabet equivalence class
    """
    delta: np.ndarray
    emit: np.ndarray
    byte_classes: np.ndarray
    num_states: int
    num_rules: int
    version: str            # rule-set hash
    field: str = "*"

    @property
    def bucket(self) -> int:
        return self.delta.shape[0]

    @property
    def words(self) -> int:
        return self.emit.shape[1]

    @property
    def n_classes(self) -> int:
        return self.delta.shape[1]

    def checksum(self) -> str:
        h = hashlib.sha256()
        for a in (self.delta, self.emit, self.byte_classes):
            h.update(np.ascontiguousarray(a).tobytes())
        h.update(f"{self.num_states}/{self.num_rules}/{self.version}/{self.field}".encode())
        return h.hexdigest()

    def serialize(self) -> bytes:
        buf = io.BytesIO()
        np.savez_compressed(
            buf, delta=self.delta, emit=self.emit, byte_classes=self.byte_classes,
            meta=np.array([self.num_states, self.num_rules], np.int64),
            version=np.array(self.version), field=np.array(self.field),
            checksum=np.array(self.checksum()))
        return buf.getvalue()

    @staticmethod
    def deserialize(data: bytes, verify: bool = True) -> "CompiledEngine":
        try:
            z = np.load(io.BytesIO(data), allow_pickle=False)
            eng = CompiledEngine(
                delta=z["delta"], emit=z["emit"],
                byte_classes=z["byte_classes"],
                num_states=int(z["meta"][0]), num_rules=int(z["meta"][1]),
                version=str(z["version"]), field=str(z["field"]))
        except ValueError:
            raise
        except Exception as e:  # container damage (zlib/zip/key errors)
            raise ValueError(f"corrupt engine artifact: {e}") from e
        if verify and str(z["checksum"]) != eng.checksum():
            raise ValueError("engine checksum mismatch — corrupt artifact")
        return eng


def compile_rules(ruleset: RuleSet, field: str = "*", *,
                  compress_alphabet: bool = True,
                  bucket: int = 0) -> CompiledEngine:
    """Build the AC DFA for every rule applicable to `field`."""
    rules = ruleset.rules_for_field(field) if field != "*" else list(ruleset.rules)
    pats = []
    ci_any = any(r.case_insensitive for r in rules)
    for r in rules:
        for lit in r.literals():
            b = lit.encode("utf-8", "ignore")
            pats.append((r.rule_id, b))
    num_rules = ruleset.num_rules
    W = words_for_rules(num_rules)

    # --- trie ---
    goto = [dict()]          # state -> {byte: state}
    emit_sets = [set()]
    for rid, pat in pats:
        s = 0
        for ch in pat:
            if ch not in goto[s]:
                goto.append(dict())
                emit_sets.append(set())
                goto[s][ch] = len(goto) - 1
            s = goto[s][ch]
        emit_sets[s].add(rid)

    n = len(goto)
    # --- BFS fail links; flatten into a dense DFA over raw bytes ---
    fail = np.zeros(n, np.int32)
    delta = np.zeros((n, 256), np.int32)
    from collections import deque
    q = deque()
    for ch in range(256):
        nxt = goto[0].get(ch, 0)
        delta[0, ch] = nxt
        if nxt:
            fail[nxt] = 0
            q.append(nxt)
    while q:
        s = q.popleft()
        emit_sets[s] |= emit_sets[fail[s]]
        for ch, t in goto[s].items():
            fail[t] = delta[fail[s], ch]
            q.append(t)
        for ch in range(256):
            if ch in goto[s]:
                delta[s, ch] = goto[s][ch]
            else:
                delta[s, ch] = delta[fail[s], ch]

    emit = np.zeros((n, W), np.uint32)
    for s, rs in enumerate(emit_sets):
        for rid in rs:
            emit[s, rid // WORD_BITS] |= np.uint32(1 << (rid % WORD_BITS))

    # --- case folding: route upper-case bytes through lower-case columns ---
    if ci_any:
        for c in range(ord("A"), ord("Z") + 1):
            delta[:, c] = delta[:, c + 32]

    # --- alphabet equivalence classes (Hyperscan-shufti-flavoured shrink):
    # two byte columns are equivalent if identical over all states ---
    if compress_alphabet:
        class_of: dict = {}
        byte_classes = np.zeros(256, np.int32)
        rep_cols = []
        for c in range(256):
            key = delta[:, c].tobytes()
            if key not in class_of:
                class_of[key] = len(rep_cols)
                rep_cols.append(delta[:, c])
            byte_classes[c] = class_of[key]
        n_classes = len(rep_cols)
        n_classes_pad = _pick(max(n_classes, 8), CLASS_BUCKETS)
        delta_c = np.zeros((n, n_classes_pad), np.int32)
        delta_c[:, :n_classes] = np.stack(rep_cols, axis=1)
        delta = delta_c
    else:
        byte_classes = np.arange(256, dtype=np.int32)

    # --- pad states to a bucket so jit shapes are stable across versions ---
    S_pad = bucket or _pick_bucket(n)
    if n > S_pad:
        raise ValueError(f"{n} states exceed bucket {S_pad}")
    delta_p = np.zeros((S_pad, delta.shape[1]), np.int32)
    delta_p[:n] = delta
    emit_p = np.zeros((S_pad, W), np.uint32)
    emit_p[:n] = emit
    return CompiledEngine(delta=delta_p, emit=emit_p, byte_classes=byte_classes,
                          num_states=n, num_rules=num_rules,
                          version=ruleset.version_hash(), field=field)


def _pick_bucket(n: int) -> int:
    return _pick(n, STATE_BUCKETS)


def _pick(n: int, buckets: tuple) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"no bucket fits {n} (buckets: {buckets})")


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def match_oracle(engine: CompiledEngine, data: np.ndarray) -> np.ndarray:
    """Reference numpy matcher: data (N, L) uint8 -> bitmaps (N, W) uint32."""
    N, L = data.shape
    state = np.zeros(N, np.int32)
    bm = np.zeros((N, engine.words), np.uint32)
    classes = engine.byte_classes
    for i in range(L):
        state = engine.delta[state, classes[data[:, i]]]
        bm |= engine.emit[state]
    return bm
