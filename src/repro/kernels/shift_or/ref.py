"""Pure-jnp oracle for the bit-parallel (shift-AND) multi-pattern matcher."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def shift_or_ref(data, tbl, init_mask, final_mask):
    """data: (N, L) uint8; tbl: (256, Wb) uint32 per-byte position masks;
    init_mask/final_mask: (Wb,) uint32.  Returns match words (N, Wb) uint32
    with a bit set at each pattern's final position iff that pattern occurred.

    Patterns are first-fit packed into independent 32-bit words (no pattern
    spans a word boundary), so the per-word recurrence needs no carries:
        S = ((S << 1) | I) & T[byte];  M |= S & F
    """
    N, L = data.shape
    Wb = tbl.shape[1]

    def step(carry, byte_col):
        S, M = carry
        t = jnp.take(tbl, byte_col.astype(jnp.int32), axis=0)   # (N, Wb)
        S = ((S << jnp.uint32(1)) | init_mask[None]) & t
        M = M | (S & final_mask[None])
        return (S, M), None

    init = (jnp.zeros((N, Wb), jnp.uint32), jnp.zeros((N, Wb), jnp.uint32))
    (S, M), _ = jax.lax.scan(step, init, data.T)
    return M
