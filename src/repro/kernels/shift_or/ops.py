"""Compile + wrap the shift-AND matcher: literal packing, kernel dispatch,
match-word -> rule-bitmap mapping.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.patterns import RuleSet
from repro.kernels.shift_or.ref import shift_or_ref
from repro.kernels.shift_or.shift_or import shift_or_kernel, BLOCK_N

WORD = 32
MAX_LIT = 32


@dataclass(frozen=True)
class ShiftOrTables:
    tbl: np.ndarray          # (256, Wb) uint32
    init_mask: np.ndarray    # (Wb,) uint32
    final_mask: np.ndarray   # (Wb,) uint32
    lit_word: np.ndarray     # (n_lits,) int32 word holding each literal's end bit
    lit_bit: np.ndarray      # (n_lits,) int32 end-bit offset
    lit_rule: np.ndarray     # (n_lits,) int32 rule id
    num_rules: int
    version: str


def compile_shift_or(ruleset: RuleSet, field: str = "*") -> ShiftOrTables:
    rules = ruleset.rules_for_field(field) if field != "*" else list(ruleset.rules)
    lits = []
    for r in rules:
        for lit in r.literals():
            b = lit.encode()
            if len(b) > MAX_LIT:
                raise ValueError(
                    f"shift_or supports literals <= {MAX_LIT} B; "
                    f"rule {r.name!r} has {len(b)} — use dfa_scan")
            lits.append((r.rule_id, b))
    # first-fit pack into 32-bit words
    words: list = []      # remaining free bits per word
    placement = []        # (word, offset) per literal
    for _, b in lits:
        ln = len(b)
        for w, free in enumerate(words):
            if free >= ln:
                placement.append((w, WORD - free))
                words[w] -= ln
                break
        else:
            words.append(WORD - ln)
            placement.append((len(words) - 1, 0))
    Wb = max(1, len(words))
    tbl = np.zeros((256, Wb), np.uint32)
    init = np.zeros(Wb, np.uint32)
    final = np.zeros(Wb, np.uint32)
    lw, lb, lr = [], [], []
    for (rid, b), (w, off) in zip(lits, placement):
        init[w] |= np.uint32(1 << off)
        final[w] |= np.uint32(1 << (off + len(b) - 1))
        for j, ch in enumerate(b):
            tbl[ch, w] |= np.uint32(1 << (off + j))
        lw.append(w)
        lb.append(off + len(b) - 1)
        lr.append(rid)
    return ShiftOrTables(tbl=tbl, init_mask=init, final_mask=final,
                         lit_word=np.array(lw, np.int32),
                         lit_bit=np.array(lb, np.int32),
                         lit_rule=np.array(lr, np.int32),
                         num_rules=ruleset.num_rules,
                         version=ruleset.version_hash())


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("num_rules",))
def _match_words_to_bitmap(M, lit_word, lit_bit, lit_rule, *, num_rules: int):
    """(N, Wb) match words -> (N, W) packed rule bitmaps."""
    W = max(1, (num_rules + WORD - 1) // WORD)
    ew = jnp.take(M, lit_word, axis=1)                          # (N, n_lits)
    hit = (ew >> lit_bit.astype(jnp.uint32)) & jnp.uint32(1)    # per-literal
    # literal -> rule (OR over literals of a rule), then pack; clamp: rules
    # with no literal in this field's engine get int32-min from the empty
    # segment_max, which must read as "no match", not a stray bit
    rule_hit = jax.ops.segment_max(hit.T.astype(jnp.int32), lit_rule,
                                   num_segments=num_rules).T    # (N, num_rules)
    rule_hit = jnp.maximum(rule_hit, 0)
    word_idx = jnp.arange(num_rules) // WORD
    bit = (rule_hit.astype(jnp.uint32) << (jnp.arange(num_rules) % WORD).astype(jnp.uint32))
    bm = jax.ops.segment_sum(bit.T, word_idx, num_segments=W).T  # sum == or (distinct bits)
    return bm.astype(jnp.uint32)


def shift_or_match(data, tables: ShiftOrTables, *, backend: str = "ref",
                   block_n: int = BLOCK_N, interpret: bool = True):
    """data: (N, L) uint8 -> (N, W) uint32 rule bitmaps."""
    N = data.shape[0]
    tbl = jnp.asarray(tables.tbl)
    I = jnp.asarray(tables.init_mask)
    F = jnp.asarray(tables.final_mask)
    if backend == "pallas":
        n_pad = _round_up(max(N, 1), block_n)
        d = jnp.pad(data, ((0, n_pad - N), (0, 0))).astype(jnp.int32)
        M = shift_or_kernel(d, tbl, I[None], F[None], block_n=block_n,
                            interpret=interpret)[:N]
    else:
        M = shift_or_ref(data, tbl, I, F)
    return _match_words_to_bitmap(
        M, jnp.asarray(tables.lit_word), jnp.asarray(tables.lit_bit),
        jnp.asarray(tables.lit_rule), num_rules=tables.num_rules)
