"""Pallas TPU kernel: bit-parallel shift-AND multi-pattern matcher.

Pure VPU workload: one (256, Wb) table row-gather plus shift/or/and per byte
position, advancing BLOCK_N records in lock-step.  Compared to dfa_scan this
trades automaton generality (literals <= 32 B only) for a state representation
that lives entirely in vector registers — the beyond-paper fast path for
short keyword rules (DESIGN.md §2).

VMEM per grid step: bytes tile 256x512 = 128 KiB (uint8->int32 widened
outside), table 256 x Wb x 4 B (Wb=320 for 1000 short patterns ~ 320 KiB),
states 2 x 256 x Wb x 4 B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _kernel(data_ref, tbl_ref, init_ref, final_ref, out_ref):
    blk_n, L = data_ref.shape
    Wb = tbl_ref.shape[1]
    tbl = tbl_ref[...]
    I = init_ref[...][0]                                        # (Wb,)
    F = final_ref[...][0]

    def body(i, carry):
        S, M = carry
        byte = data_ref[:, i]
        t = jnp.take(tbl, byte, axis=0)                         # (blk_n, Wb)
        S = ((S << jnp.uint32(1)) | I[None]) & t
        M = M | (S & F[None])
        return S, M

    S0 = jnp.zeros((blk_n, Wb), jnp.uint32)
    _, M = jax.lax.fori_loop(0, L, body, (S0, S0))
    out_ref[...] = M


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def shift_or_kernel(data, tbl, init_mask, final_mask, *,
                    block_n: int = BLOCK_N, interpret: bool = True):
    """data: (N, L) int32 byte values; tbl: (256, Wb) uint32;
    init_mask/final_mask: (1, Wb) uint32 -> (N, Wb) uint32 match words."""
    N, L = data.shape
    Wb = tbl.shape[1]
    assert N % block_n == 0
    return pl.pallas_call(
        _kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, L), lambda i: (i, 0)),
            pl.BlockSpec((256, Wb), lambda i: (0, 0)),
            pl.BlockSpec((1, Wb), lambda i: (0, 0)),
            pl.BlockSpec((1, Wb), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, Wb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Wb), jnp.uint32),
        interpret=interpret,
    )(data, tbl, init_mask, final_mask)
