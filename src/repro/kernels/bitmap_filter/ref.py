"""Pure-jnp oracle for the query-time bitmap filter."""
from __future__ import annotations

import jax.numpy as jnp


def bitmap_filter_ref(bitmaps, query):
    """bitmaps: (N, W) uint32; query: (W,) uint32.
    Returns match: (N,) bool — record matches ANY rule bit in `query`."""
    return jnp.any(bitmaps & query[None], axis=1)


def bitmap_count_ref(bitmaps, query):
    return bitmap_filter_ref(bitmaps, query).sum(dtype=jnp.int32)
