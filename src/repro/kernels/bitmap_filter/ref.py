"""Pure-jnp oracle for the query-time bitmap filter."""
from __future__ import annotations

import jax.numpy as jnp


def bitmap_filter_ref(bitmaps, query):
    """bitmaps: (N, W) uint32; query: (W,) uint32.
    Returns match: (N,) bool — record matches ANY rule bit in `query`."""
    return jnp.any(bitmaps & query[None], axis=1)


def bitmap_count_ref(bitmaps, query):
    return bitmap_filter_ref(bitmaps, query).sum(dtype=jnp.int32)


def bitmap_query_ref(bitmaps, masks):
    """Conjunctive predicate: bitmaps (N, W) uint32, masks (P, W) uint32.
    A record matches when EVERY mask has at least one set bit in common with
    the record's bitmap (AND across predicates, OR within one mask) — the
    query engine's Q4-style multi-term semantics.  Returns (N,) bool."""
    hit = (bitmaps[:, None, :] & masks[None, :, :]) != 0     # (N, P, W)
    return jnp.all(jnp.any(hit, axis=2), axis=1)


def bitmap_word_query_ref(cols, bits):
    """Word-sliced conjunctive predicate: cols (N, P) uint32 — the P
    pre-gathered bitmap WORD columns a query actually touches — and bits
    (P,) uint32 single-word masks.  Equivalent to ``bitmap_query_ref``
    whenever every predicate mask fits one word (always true for the
    engine's single-rule predicates), at 1/W the memory traffic."""
    return jnp.all((cols & bits[None, :]) != 0, axis=1)
