"""Pallas TPU kernel: fused enrichment-bitmap predicate + count.

The analytical-plane fast path (paper §3.1 "Query Mapper ... bypass expensive
full-table scans"): AND each record's packed rule bitmap with the query mask,
reduce-any per record, and accumulate per-block match counts — one pass over
the enrichment column, no string data touched.  Memory-bound by design; the
roofline term is column bytes / HBM bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _kernel(bm_ref, q_ref, match_ref, count_ref):
    hit = (bm_ref[...] & q_ref[...]) != 0                       # (blk, W)
    any_hit = jnp.any(hit, axis=1)
    match_ref[...] = any_hit.astype(jnp.int32)
    count_ref[0, 0] = jnp.sum(any_hit.astype(jnp.int32))


def _query_kernel(bm_ref, q_ref, match_ref):
    # conjunctive multi-mask predicate: AND over the P masks of "any bit in
    # common".  P is static, so the loop unrolls into 2-D VPU ops (no 3-D
    # broadcast — friendlier to the TPU lowering than a (blk, P, W) tensor).
    bm = bm_ref[...]                                         # (blk, W)
    ok = None
    for p in range(q_ref.shape[0]):
        hit_p = jnp.any((bm & q_ref[p][None, :]) != 0, axis=1)
        ok = hit_p if ok is None else (ok & hit_p)
    match_ref[...] = ok.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bitmap_query_kernel(bitmaps, masks, *, block_n: int = BLOCK_N,
                        interpret: bool = True):
    """bitmaps: (N, W) uint32 (N % block_n == 0); masks: (P, W) uint32.
    Returns match (N,) int32 — 1 where the record satisfies EVERY mask
    (AND across predicates, any-bit within each).  One grid pass over the
    stacked enrichment column; the multi-segment query executor feeds all
    bitmap-scan segments of a query through this in a single dispatch."""
    N, W = bitmaps.shape
    P = masks.shape[0]
    assert N % block_n == 0
    grid = (N // block_n,)
    return pl.pallas_call(
        _query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, W), lambda i: (i, 0)),
            pl.BlockSpec((P, W), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(bitmaps, masks)


def _word_query_kernel(cols_ref, bits_ref, match_ref):
    hit = (cols_ref[...] & bits_ref[...]) != 0               # (blk, P)
    match_ref[...] = jnp.all(hit, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bitmap_word_query_kernel(cols, bits, *, block_n: int = BLOCK_N,
                             interpret: bool = True):
    """cols: (N, P) uint32 pre-gathered bitmap word columns (N % block_n
    == 0); bits: (P,) uint32 single-word masks.  Returns match (N,) int32 —
    the word-sliced fast path of ``bitmap_query_kernel``: the executor
    gathers only the words a query touches, so HBM traffic is N*P words
    instead of N*W."""
    N, P = cols.shape
    assert N % block_n == 0
    grid = (N // block_n,)
    return pl.pallas_call(
        _word_query_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, P), lambda i: (i, 0)),
            pl.BlockSpec((1, P), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(cols, bits[None])


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bitmap_filter_kernel(bitmaps, query, *, block_n: int = BLOCK_N,
                         interpret: bool = True):
    """bitmaps: (N, W) uint32 (N % block_n == 0); query: (1, W) uint32.
    Returns (match (N,) int32, block_counts (N//block_n, 1) int32)."""
    N, W = bitmaps.shape
    assert N % block_n == 0
    grid = (N // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(bitmaps, query)
