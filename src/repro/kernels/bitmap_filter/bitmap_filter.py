"""Pallas TPU kernel: fused enrichment-bitmap predicate + count.

The analytical-plane fast path (paper §3.1 "Query Mapper ... bypass expensive
full-table scans"): AND each record's packed rule bitmap with the query mask,
reduce-any per record, and accumulate per-block match counts — one pass over
the enrichment column, no string data touched.  Memory-bound by design; the
roofline term is column bytes / HBM bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _kernel(bm_ref, q_ref, match_ref, count_ref):
    hit = (bm_ref[...] & q_ref[...]) != 0                       # (blk, W)
    any_hit = jnp.any(hit, axis=1)
    match_ref[...] = any_hit.astype(jnp.int32)
    count_ref[0, 0] = jnp.sum(any_hit.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def bitmap_filter_kernel(bitmaps, query, *, block_n: int = BLOCK_N,
                         interpret: bool = True):
    """bitmaps: (N, W) uint32 (N % block_n == 0); query: (1, W) uint32.
    Returns (match (N,) int32, block_counts (N//block_n, 1) int32)."""
    N, W = bitmaps.shape
    assert N % block_n == 0
    grid = (N // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(bitmaps, query)
