"""Jitted wrappers for bitmap filtering: count and copy (index-compaction)
query modes over enrichment columns."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_filter.bitmap_filter import (bitmap_filter_kernel,
                                                       BLOCK_N)
from repro.kernels.bitmap_filter.ref import bitmap_filter_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def bitmap_match(bitmaps, query, *, backend: str = "ref",
                 block_n: int = BLOCK_N, interpret: bool = True):
    """(N, W) & (W,) -> match (N,) bool."""
    N = bitmaps.shape[0]
    if backend == "pallas":
        n_pad = _round_up(max(N, 1), block_n)
        bm = jnp.pad(bitmaps, ((0, n_pad - N), (0, 0)))
        match, _ = bitmap_filter_kernel(bm, query[None], block_n=block_n,
                                        interpret=interpret)
        return match[:N].astype(bool)
    return bitmap_filter_ref(bitmaps, query)


def bitmap_count(bitmaps, query, *, backend: str = "ref",
                 block_n: int = BLOCK_N, interpret: bool = True):
    """Aggregation (count) query — paper's Q3/Qx-with-count."""
    if backend == "pallas":
        N = bitmaps.shape[0]
        n_pad = _round_up(max(N, 1), block_n)
        bm = jnp.pad(bitmaps, ((0, n_pad - N), (0, 0)))
        _, counts = bitmap_filter_kernel(bm, query[None], block_n=block_n,
                                         interpret=interpret)
        return counts.sum(dtype=jnp.int32)
    return bitmap_filter_ref(bitmaps, query).sum(dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_out",))
def bitmap_select(bitmaps, query, *, max_out: int):
    """Copy mode: compacted indices of matching records (static bound).
    Returns (indices (max_out,) int32 padded with -1, count)."""
    match = bitmap_filter_ref(bitmaps, query)
    count = match.sum(dtype=jnp.int32)
    order = jnp.argsort(~match)                                  # matches first
    idx = jnp.where(jnp.arange(max_out) < count, order[:max_out], -1)
    return idx, count
