"""Jitted wrappers for bitmap filtering: count and copy (index-compaction)
query modes over enrichment columns, plus the multi-segment stacked entry
the query executor dispatches through.

``bitmap_query_stacked`` is the analytical-plane analogue of the ingest
side's ``dfa_scan_fused``: all bitmap-scan segments of one query are
concatenated on N (with a per-row segment-slot vector), matched against the
query's conjunctive mask set in ONE device dispatch, and per-segment match
counts are reduced on device — the caller owns the single D2H transfer.
Batch sizes bucket through ``dfa_scan.ops.bucket_n`` so ragged segment
totals never retrace the jit cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_filter.bitmap_filter import (bitmap_filter_kernel,
                                                       bitmap_query_kernel,
                                                       bitmap_word_query_kernel,
                                                       BLOCK_N)
from repro.kernels.bitmap_filter.ref import (bitmap_filter_ref,
                                             bitmap_query_ref,
                                             bitmap_word_query_ref)
from repro.kernels.dfa_scan.ops import TRACE_COUNTS, bucket_n


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def bitmap_match(bitmaps, query, *, backend: str = "ref",
                 block_n: int = BLOCK_N, interpret: bool = True):
    """(N, W) & (W,) -> match (N,) bool."""
    N = bitmaps.shape[0]
    if backend == "pallas":
        n_pad = _round_up(max(N, 1), block_n)
        bm = jnp.pad(bitmaps, ((0, n_pad - N), (0, 0)))
        match, _ = bitmap_filter_kernel(bm, query[None], block_n=block_n,
                                        interpret=interpret)
        return match[:N].astype(bool)
    return bitmap_filter_ref(bitmaps, query)


def bitmap_count(bitmaps, query, *, backend: str = "ref",
                 block_n: int = BLOCK_N, interpret: bool = True):
    """Aggregation (count) query — paper's Q3/Qx-with-count."""
    if backend == "pallas":
        N = bitmaps.shape[0]
        n_pad = _round_up(max(N, 1), block_n)
        bm = jnp.pad(bitmaps, ((0, n_pad - N), (0, 0)))
        _, counts = bitmap_filter_kernel(bm, query[None], block_n=block_n,
                                         interpret=interpret)
        return counts.sum(dtype=jnp.int32)
    return bitmap_filter_ref(bitmaps, query).sum(dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_out",))
def bitmap_select(bitmaps, query, *, max_out: int):
    """Copy mode: compacted indices of matching records (static bound).
    Returns (indices (max_out,) int32 padded with -1, count).

    Compaction is a cumsum + scatter (stable, ascending ids) instead of a
    full argsort over N — O(N) work and int32 throughout."""
    match = bitmap_filter_ref(bitmaps, query)
    count = match.sum(dtype=jnp.int32)
    N = match.shape[0]
    pos = jnp.cumsum(match.astype(jnp.int32)) - 1            # dest per match
    dest = jnp.where(match & (pos < max_out), pos, max_out)  # max_out = drop
    idx = jnp.full((max_out,), -1, jnp.int32)
    idx = idx.at[dest].set(jnp.arange(N, dtype=jnp.int32), mode="drop")
    return idx, count


# ---------------------------------------------------------------------------
# Multi-segment stacked entry (query executor's single dispatch per query)
# ---------------------------------------------------------------------------

def _seg_bucket(s: int) -> int:
    """Pad the static segment count to a power of two so a growing store
    hits a handful of jit shape buckets, not one trace per segment count."""
    return 1 << (max(s, 1) - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("num_segments", "backend",
                                             "block_n", "interpret"))
def _query_dispatch(bm, masks, row_seg, *, num_segments: int, backend: str,
                    block_n: int, interpret: bool):
    TRACE_COUNTS[("bitmap_query", backend)] += 1
    if backend == "pallas":
        match = bitmap_query_kernel(bm, masks, block_n=block_n,
                                    interpret=interpret).astype(jnp.bool_)
    else:
        match = bitmap_query_ref(bm, masks)
    counts = jax.ops.segment_sum(match.astype(jnp.int32), row_seg,
                                 num_segments=num_segments)
    return match, counts


def bitmap_query_stacked(bitmaps, masks, row_seg, *, num_segments: int,
                         backend: str = "ref", block_n: int = BLOCK_N,
                         interpret: bool = True):
    """bitmaps: (N, W) uint32 — the bitmap-scan segments of one query
    concatenated on N (any N; rows bucket via ``bucket_n``); masks:
    (P, W) uint32 conjunctive predicate masks; row_seg: (N,) int32 mapping
    each row to its segment slot.

    Returns DEVICE arrays ``(match, counts)`` — match over the concatenated
    rows plus per-segment match counts reduced on device — in PADDED form:
    match is ``(bucket_n(N),)`` bool and counts ``(pow2 >= num_segments,)``
    int32.  Zero-padded rows can never match (their bitmaps are empty) and
    padded segment slots stay zero, so callers slice ``[:N]`` /
    ``[:num_segments]`` on the HOST after the D2H transfer they own — the
    hot path stays one jitted dispatch with no eager device ops (an eager
    pad or slice costs more than the whole match at small N)."""
    N = bitmaps.shape[0]
    n_pad = bucket_n(N, block_n)
    if n_pad != N:
        bitmaps = jnp.pad(bitmaps, ((0, n_pad - N), (0, 0)))
        row_seg = jnp.pad(row_seg, (0, n_pad - N))
    return _query_dispatch(
        bitmaps, masks, row_seg, num_segments=_seg_bucket(num_segments),
        backend=backend, block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_segments", "backend",
                                             "block_n", "interpret",
                                             "with_counts"))
def _word_query_dispatch(cols, bits, row_seg, *, num_segments: int,
                         backend: str, block_n: int, interpret: bool,
                         with_counts: bool):
    TRACE_COUNTS[("bitmap_query_words", backend)] += 1
    if backend == "pallas":
        match = bitmap_word_query_kernel(cols, bits, block_n=block_n,
                                         interpret=interpret).astype(jnp.bool_)
    else:
        match = bitmap_word_query_ref(cols, bits)
    if not with_counts:
        return match, None
    # no indices_are_sorted hint: bucket padding appends slot-0 ids after
    # the last segment's run, so the padded row_seg is NOT sorted (padded
    # rows contribute zero either way, but the contract must hold)
    counts = jax.ops.segment_sum(match.astype(jnp.int32), row_seg,
                                 num_segments=num_segments)
    return match, counts


def bitmap_query_words(cols, bits, row_seg, *, num_segments: int,
                       backend: str = "ref", block_n: int = BLOCK_N,
                       interpret: bool = True, with_counts: bool = True):
    """Word-sliced variant of ``bitmap_query_stacked`` — the executor's hot
    path.  cols: (N, P) uint32, the P bitmap WORD columns the query's
    single-rule predicates actually touch, pre-gathered at stack-build
    time; bits: (P,) uint32 single-word masks; row_seg: (N,) int32 segment
    slots.  Same padded device returns ``(match, counts)`` as the stacked
    entry (slice on the host after the D2H); memory traffic per query is
    N*P words instead of N*W.

    ``with_counts=False`` skips the device-side per-segment reduction and
    returns ``(match, None)`` — the right call on backends where a scatter
    reduction costs more than transferring the mask and counting on the
    host (XLA CPU); on accelerators the reduction shrinks the D2H payload
    from N bytes to num_segments ints."""
    N = cols.shape[0]
    n_pad = bucket_n(N, block_n)
    if n_pad != N:
        cols = jnp.pad(cols, ((0, n_pad - N), (0, 0)))
        row_seg = jnp.pad(row_seg, (0, n_pad - N))
    return _word_query_dispatch(
        cols, bits, row_seg, num_segments=_seg_bucket(num_segments),
        backend=backend, block_n=block_n, interpret=interpret,
        with_counts=with_counts)
