"""Pallas TPU kernel: batched Aho-Corasick DFA scan.

Layout: the grid tiles the record batch; each grid step holds a
(BLOCK_N, L) tile of byte-class ids plus the full DFA tables in VMEM and
advances BLOCK_N automata in lock-step with one vectorized table gather per
byte position (Mosaic `dynamic_gather` is the target lowering for the
per-lane `jnp.take`).

VMEM budget per grid step (defaults, 1000-rule engine):
    classes tile 256 x 512 x 4 B   = 0.5 MiB
    delta       4096 x 64 x 4 B    = 1.0 MiB   (alphabet-compressed)
    emit        4096 x 32 x 4 B    = 0.5 MiB
    state/bitmap accumulators      < 0.1 MiB
well under the ~16 MiB v5e VMEM.  The byte->class LUT is applied outside
(it is elementwise and fuses into the surrounding program).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _kernel(cls_ref, delta_ref, emit_ref, out_ref):
    blk_n, L = cls_ref.shape
    S, C = delta_ref.shape
    W = emit_ref.shape[1]
    delta_flat = delta_ref[...].reshape(S * C)
    emit = emit_ref[...]

    def body(i, carry):
        state, bm = carry
        col = cls_ref[:, i]
        state = jnp.take(delta_flat, state * C + col)           # per-lane gather
        bm = bm | jnp.take(emit, state, axis=0)                 # row gather
        return state, bm

    state0 = jnp.zeros((blk_n,), jnp.int32)
    bm0 = jnp.zeros((blk_n, W), jnp.uint32)
    _, bm = jax.lax.fori_loop(0, L, body, (state0, bm0))
    out_ref[...] = bm


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dfa_scan_kernel(cls_ids, delta, emit, *, block_n: int = BLOCK_N,
                    interpret: bool = True):
    """cls_ids: (N, L) int32 byte-class ids (N % block_n == 0);
    delta: (S, C) int32; emit: (S, W) uint32 -> (N, W) uint32."""
    N, L = cls_ids.shape
    S, C = delta.shape
    W = emit.shape[1]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, L), lambda i: (i, 0)),
            pl.BlockSpec((S, C), lambda i: (0, 0)),
            pl.BlockSpec((S, W), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, W), jnp.uint32),
        interpret=interpret,
    )(cls_ids, delta, emit)
