"""Pallas TPU kernel: batched Aho-Corasick DFA scan, fused across fields.

Layout: the grid is ``(N // block_n, F)`` — the major axis tiles the record
batch, the minor (fastest-varying) **field axis** sweeps the per-field
automata while the SAME output block stays resident in VMEM, OR-accumulating
each field's rule bitmap.  F text fields therefore cost one kernel launch
and one (block_n, W) output write per record tile (the fused multi-field
dispatch's device half; matcher.FusedMatcher is the host half).

The byte->class LUT is folded into the kernel: the input tile is the RAW
``(block_n, L) uint8`` bytes — 4x smaller than the int32 class tile the
previous revision streamed through HBM — and each field's 256-entry LUT
rides along in VMEM.  Transition tables are int16 whenever the padded
automaton fits (S < 32768), halving the delta block.

VMEM budget per grid step (defaults, 1000-rule engine):
    byte tile   256 x 512 x 1 B  = 0.125 MiB  (uint8; LUT applied in-kernel)
    lut         256 x 4 B        = 1 KiB
    delta       4096 x 64 x 2 B  = 0.5 MiB    (alphabet-compressed, int16)
    emit        4096 x 32 x 4 B  = 0.5 MiB
    state/bitmap accumulators    < 0.1 MiB
well under the ~16 MiB v5e VMEM.  Each grid step advances block_n automata
in lock-step with one vectorized table gather per byte position (Mosaic
`dynamic_gather` is the target lowering for the per-lane `jnp.take`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _kernel(data_ref, lut_ref, delta_ref, emit_ref, out_ref):
    _, blk_n, L = data_ref.shape
    _, S, C = delta_ref.shape
    W = emit_ref.shape[2]
    f = pl.program_id(1)
    data = data_ref[0]                                   # (blk_n, L) uint8
    lut = lut_ref[0]                                     # (256,) int32
    delta_flat = delta_ref[0].reshape(S * C)             # int16 when S < 2^15
    emit = emit_ref[0]                                   # (S, W) uint32

    def body(i, carry):
        state, bm = carry
        col = jnp.take(lut, data[:, i].astype(jnp.int32))       # LUT gather
        state = jnp.take(delta_flat, state * C + col)           # per-lane gather
        state = state.astype(jnp.int32)
        bm = bm | jnp.take(emit, state, axis=0)                 # row gather
        return state, bm

    state0 = jnp.zeros((blk_n,), jnp.int32)
    bm0 = jnp.zeros((blk_n, W), jnp.uint32)
    _, bm = jax.lax.fori_loop(0, L, body, (state0, bm0))

    # OR-accumulate across the field axis: the out block is revisited on
    # consecutive grid steps (f is the minor grid axis), so it stays in VMEM.
    @pl.when(f == 0)
    def _():
        out_ref[...] = bm

    @pl.when(f != 0)
    def _():
        out_ref[...] = out_ref[...] | bm


@functools.partial(jax.jit,
                   static_argnames=("eng_idx", "block_n", "interpret"))
def dfa_scan_fused_kernel(data, luts, deltas, emits, *, eng_idx: tuple,
                          block_n: int = BLOCK_N, interpret: bool = True):
    """data: (F, N, L) uint8 raw bytes (N % block_n == 0);
    luts: (E, 256) int32 byte->class; deltas: (E, S, C) int; emits:
    (E, S, W) uint32; eng_idx: length-F tuple mapping each field slot to
    its table row.  -> (N, W) uint32, the OR of all per-field bitmaps.

    Note: jax 0.4.x pallas rejects constants in BlockSpec index maps, so a
    non-identity eng_idx cannot be routed through the specs — it is
    expanded to one table row per slot with an on-device gather below.
    Callers on the hot path should pre-expand host-side instead and pass
    identity (FusedMatcher._build_plan does), paying the copy once per
    plan rather than per dispatch."""
    F, N, L = data.shape
    _, S, C = deltas.shape
    W = emits.shape[2]
    assert N % block_n == 0, (N, block_n)
    assert len(eng_idx) == F, (eng_idx, F)
    if S < 2 ** 15:
        deltas = deltas.astype(jnp.int16)    # halve the VMEM delta block
    if tuple(eng_idx) != tuple(range(luts.shape[0])):
        # Expand unique tables to one row per field slot on device (pallas
        # on jax 0.4.x rejects constants in index maps, so the slot->row
        # indirection cannot live in the BlockSpecs; the host still builds
        # and ships each shared engine's tables only once).
        eng = jnp.asarray(eng_idx, jnp.int32)
        luts = jnp.take(luts, eng, axis=0)
        deltas = jnp.take(deltas, eng, axis=0)
        emits = jnp.take(emits, eng, axis=0)
    grid = (N // block_n, F)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, L), lambda i, f: (f, i, 0)),
            pl.BlockSpec((1, 256), lambda i, f: (f, 0)),
            pl.BlockSpec((1, S, C), lambda i, f: (f, 0, 0)),
            pl.BlockSpec((1, S, W), lambda i, f: (f, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, W), lambda i, f: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, W), jnp.uint32),
        interpret=interpret,
    )(data, luts, deltas, emits)
