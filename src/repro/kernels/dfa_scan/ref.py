"""Pure-jnp oracles for the AC-DFA batch scan (single-field and fused)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dfa_scan_fused_ref(data, luts, deltas, emits, *, eng_idx: tuple = None,
                       unroll: int = 4):
    """data: (F, N, L) uint8; luts: (E, 256) int32; deltas: (E, S, C) int;
    emits: (E, S, W) uint32; eng_idx: length-F tuple mapping each field
    slot to its table row (default: identity, E == F).  Returns per-field
    bitmaps (F, N, W) uint32.

    One ``lax.scan`` over byte positions advances all F*N automata in
    lock-step via flat gathers with per-row table offsets: on latency-bound
    hosts the scan-step overhead dominates the gather width, so F fields
    cost roughly one field's scan — the fused dispatch's core win.  The
    small ``unroll`` amortizes per-step loop machinery.

    Records are padded with byte 0; byte 0's class transitions are part of
    the automaton (it never appears in patterns, so it only walks fail links
    — matches already recorded stay recorded)."""
    F, N, L = data.shape
    E, S, C = deltas.shape
    W = emits.shape[2]
    if eng_idx is None:
        eng_idx = tuple(range(F))
    flat = data.reshape(F * N, L).astype(jnp.int32)
    row_e = jnp.repeat(jnp.asarray(eng_idx, jnp.int32), N)  # engine of row
    cls = jnp.take(luts.reshape(-1), row_e[:, None] * 256 + flat)
    delta_flat = deltas.astype(jnp.int32).reshape(-1)
    emit_flat = emits.reshape(E * S, W)
    base_d = row_e * (S * C)
    base_e = row_e * S

    def step(carry, col):
        state, bm = carry
        state = jnp.take(delta_flat, base_d + state * C + col)
        bm = bm | jnp.take(emit_flat, base_e + state, axis=0)
        return (state, bm), None

    init = (jnp.zeros((F * N,), jnp.int32), jnp.zeros((F * N, W), jnp.uint32))
    (_, bm), _ = jax.lax.scan(step, init, cls.T, unroll=unroll)
    return bm.reshape(F, N, W)


def dfa_scan_ref(data, delta, emit, byte_classes):
    """data: (N, L) uint8; delta: (S, C) int32; emit: (S, W) uint32;
    byte_classes: (256,) int32.  Returns bitmaps (N, W) uint32."""
    return dfa_scan_fused_ref(data[None], byte_classes[None], delta[None],
                              emit[None])[0]
